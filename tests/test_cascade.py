"""Vectorized cascade evaluation == naive per-image simulation (accuracy
AND expected cost), across scenarios — the core §V-D/E machinery."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cascade import (KIND_SINGLE, KIND_THREE, KIND_TWO,
                                cascade_time_naive, evaluate_cascades,
                                simulate_cascade, spec_levels)
from repro.core.costs import CostProfile
from repro.core.thresholds import compute_thresholds_batch
from repro.core.transforms import Representation


def _setup(seed, n_models=4, n_img=60, n_targets=2):
    rng = np.random.default_rng(seed)
    truth = rng.integers(0, 2, n_img)
    scores = np.clip(truth[None] * rng.uniform(0.3, 0.7, (n_models, 1))
                     + rng.normal(0.25, 0.2, (n_models, n_img)), 0, 1)
    p_low, p_high = compute_thresholds_batch(scores, truth, [0.9, 0.95][:n_targets])
    reps = [Representation(8 * (1 + i % 3), ["rgb", "gray", "r"][i % 3])
            for i in range(n_models)]
    reps[-1] = Representation(32, "rgb")   # trusted: full rep
    infer = rng.uniform(1e-4, 5e-3, n_models)
    infer[-1] = 0.05                       # trusted is expensive
    profile = CostProfile.modeled({}, list(set(reps)), base_hw=32)
    return scores, truth, p_low, p_high, reps, infer, profile


@pytest.mark.parametrize("scenario",
                         ["INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA"])
@pytest.mark.parametrize("seed", [0, 1])
def test_vectorized_matches_naive(scenario, seed):
    scores, truth, p_low, p_high, reps, infer, profile = _setup(seed)
    space = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                              profile, scenario, trusted=len(reps) - 1)
    rng = np.random.default_rng(seed + 7)
    for i in rng.choice(len(space), size=40, replace=False):
        levels = spec_levels(space, int(i), p_low, p_high)
        acc, _ = simulate_cascade(levels, scores, truth)
        t = cascade_time_naive(levels, scores, reps, infer, profile,
                               scenario)
        assert space.acc[i] == pytest.approx(acc, abs=1e-5), \
            (i, space.kind[i])
        assert space.time_s[i] == pytest.approx(t, rel=1e-5), \
            (i, space.kind[i])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["INFER_ONLY", "CAMERA", "ARCHIVE", "ONGOING"]))
def test_vectorized_matches_naive_hypothesis(seed, scenario):
    scores, truth, p_low, p_high, reps, infer, profile = _setup(
        seed, n_models=3, n_img=40, n_targets=1)
    space = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                              profile, scenario, trusted=len(reps) - 1)
    rng = np.random.default_rng(seed)
    for i in rng.choice(len(space), size=10, replace=False):
        levels = spec_levels(space, int(i), p_low, p_high)
        acc, _ = simulate_cascade(levels, scores, truth)
        t = cascade_time_naive(levels, scores, reps, infer, profile,
                               scenario)
        assert abs(space.acc[i] - acc) < 1e-5
        assert abs(space.time_s[i] - t) < max(1e-9, 1e-5 * t)


def test_enumeration_counts():
    scores, truth, p_low, p_high, reps, infer, profile = _setup(0)
    m, t = scores.shape[0], p_low.shape[1]
    space = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                              profile, "INFER_ONLY", trusted=m - 1)
    expect = m + (m * t) * m + (m * t) * (m * t)
    assert len(space) == expect
    assert (space.kind == KIND_SINGLE).sum() == m
    assert (space.kind == KIND_TWO).sum() == m * t * m
    assert (space.kind == KIND_THREE).sum() == (m * t) ** 2


def test_rep_cost_charged_once():
    """Two levels sharing a representation must be cheaper than the same
    cascade with distinct representations (CAMERA scenario)."""
    scores, truth, p_low, p_high, reps, infer, profile = _setup(3)
    reps_same = list(reps)
    reps_same[1] = reps[0]
    sp_same = evaluate_cascades(scores, truth, p_low, p_high, reps_same,
                                infer, profile, "CAMERA",
                                trusted=len(reps) - 1)
    sp_diff = evaluate_cascades(scores, truth, p_low, p_high, reps,
                                infer, profile, "CAMERA",
                                trusted=len(reps) - 1)
    # cascade: model0@t0 -> model1 (two-level)
    sel = (sp_same.kind == KIND_TWO) & (sp_same.i1 == 0) & (sp_same.i2 == 1)
    i = np.where(sel)[0][0]
    assert sp_same.time_s[i] < sp_diff.time_s[i]


def test_infer_only_fastest_scenario():
    scores, truth, p_low, p_high, reps, infer, profile = _setup(4)
    spi = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                            profile, "INFER_ONLY", trusted=len(reps) - 1)
    for scen in ("ARCHIVE", "ONGOING", "CAMERA"):
        sp = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                               profile, scen, trusted=len(reps) - 1)
        assert np.all(sp.time_s >= spi.time_s - 1e-12)
        assert np.allclose(sp.acc, spi.acc)  # accuracy scenario-invariant
