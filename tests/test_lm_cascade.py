"""LM predicate cascades (paper technique on the assigned archs):
a trained small LM + trusted LM cascade must (a) preserve trusted-level
accuracy at the calibrated precision and (b) route easy inputs early."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.core.lm_cascade import (LMLevel, calibrate, expected_cost,
                                   lm_predicate_score, run_lm_cascade)
from repro.models.factory import build_model
from repro.train.optimizer import adamw

YES, NO = 7, 13


def _make_task(vocab, n, seq, seed=0):
    """Label = whether token YES appears in the sequence body."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, (n, seq)).astype(np.int32)
    toks[toks == YES] = YES + 1
    labels = rng.integers(0, 2, n).astype(np.int32)
    for i in np.where(labels == 1)[0]:
        pos = rng.integers(0, seq - 1, size=3)
        toks[i, pos] = YES
    return toks, labels


def _train_level(arch_name, toks, labels, steps=120, seed=0):
    cfg = smoke_config(arch_name).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, tb, yb):
        def loss_fn(p):
            logits, _, _ = model.forward(p, {"tokens": tb},
                                         remat_policy="none",
                                         logits_last_only=True)
            pair = logits[:, -1, jnp.asarray([YES, NO])]
            logp = jax.nn.log_softmax(pair.astype(jnp.float32), -1)
            return -jnp.mean(jnp.where(yb == 1, logp[:, 0], logp[:, 1]))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, len(toks), 16)
        params, state, loss = step(params, state,
                                   jnp.asarray(toks[idx]),
                                   jnp.asarray(labels[idx]))
    return LMLevel(model=model, params=params, yes_token=YES, no_token=NO)


@pytest.fixture(scope="module")
def cascade():
    vocab = smoke_config("deepseek-7b").vocab_size
    # 400 train / 120 calibration / 80 eval: with fewer train samples the
    # small level saturates (near-0/1 scores at ~0.66 test accuracy), the
    # 80-sample calibration can't see it, and almost everything
    # early-exits confidently wrong — the old deterministic failure mode
    # of this module. More data makes confidence generalize.
    toks, labels = _make_task(vocab, 600, 24)
    # representation knob (paper's F analogue): the cheap level only sees
    # a truncated context, so YES tokens early in the sequence are
    # genuinely invisible to it -> real uncertainty structure. It is
    # trained under the same truncation it serves with.
    small = _train_level("minitron-4b", toks[:400, -12:], labels[:400],
                         steps=150)
    small.max_context = 12
    trusted = _train_level("deepseek-7b", toks[:400], labels[:400],
                           steps=220, seed=1)
    calibrate([small, trusted], toks[400:520], labels[400:520],
              prec_target=0.8)
    return [small, trusted], toks[520:], labels[520:]


def test_levels_learn(cascade):
    levels, toks, labels = cascade
    acc_small = ((lm_predicate_score(levels[0], toks) >= 0.5)
                 == labels).mean()
    acc_big = ((lm_predicate_score(levels[1], toks) >= 0.5)
               == labels).mean()
    assert acc_big > 0.8 and acc_small > 0.6, (acc_small, acc_big)


def test_cascade_accuracy_and_routing(cascade):
    levels, toks, labels = cascade
    preds, used = run_lm_cascade(levels, toks)
    acc_big = ((lm_predicate_score(levels[1], toks) >= 0.5)
               == labels).mean()
    acc = (preds == labels).mean()
    # early exits trade a bounded amount of accuracy (>= calibrated
    # precision target on the routed fraction); 0.15 leaves headroom for
    # backend-dependent training noise without admitting the saturated-
    # small-model failure mode (which lands ~0.3 below trusted)
    assert acc >= acc_big - 0.15, (acc, acc_big)
    # some (but not all) inputs exit at the cheap level
    frac_early = (used == 0).mean()
    assert 0.0 < frac_early < 1.0
    # cascade is cheaper than trusted-only under any cost where the small
    # model is >=10x cheaper (the assigned-arch reality)
    c = expected_cost(levels, used, [1.0, 10.0])
    assert c < 11.0


def test_thresholds_route_uncertain_only(cascade):
    levels, toks, labels = cascade
    scores = lm_predicate_score(levels[0], toks)
    _, used = run_lm_cascade(levels, toks)
    early = used == 0
    assert np.all((scores[early] <= levels[0].p_low)
                  | (scores[early] >= levels[0].p_high))
