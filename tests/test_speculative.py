"""Greedy speculative decoding must EXACTLY reproduce trusted-model greedy
decoding (the cascade analogue of 'no accuracy loss'), while calling the
trusted model fewer times when draft == target."""
import jax
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models.factory import build_model
from repro.serve.speculative import (SpecStats, generate_greedy,
                                     generate_speculative)
from repro.train import checkpoint as ck


@pytest.fixture(scope="module")
def models():
    tgt_cfg = smoke_config("deepseek-7b").replace(dtype="float32")
    drf_cfg = smoke_config("minitron-4b").replace(dtype="float32",
                                                  vocab_size=tgt_cfg
                                                  .vocab_size)
    target = build_model(tgt_cfg)
    draft = build_model(drf_cfg)
    tp = target.init(jax.random.PRNGKey(0))
    dp = draft.init(jax.random.PRNGKey(1))
    return draft, dp, target, tp, tgt_cfg


def test_speculative_exact_vs_target_greedy(models):
    draft, dp, target, tp, cfg = models
    prompt = np.array([5, 9, 2, 17, 33, 8], np.int32)
    ref = generate_greedy(target, tp, prompt, n_tokens=12)
    out, stats = generate_speculative(draft, dp, target, tp, prompt,
                                      n_tokens=12, gamma=3)
    np.testing.assert_array_equal(out, ref)
    assert stats.proposed > 0


def test_speculative_self_draft_accepts_everything(models):
    """Draft == target -> every proposal accepted, target calls ~n/gamma."""
    _, _, target, tp, cfg = models
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    out, stats = generate_speculative(target, tp, target, tp, prompt,
                                      n_tokens=8, gamma=4)
    ref = generate_greedy(target, tp, prompt, n_tokens=8)
    np.testing.assert_array_equal(out, ref)
    assert stats.acceptance_rate == 1.0
    assert stats.target_calls <= 1 + 8 // 4


def test_async_saver_roundtrip(tmp_path):
    import jax.numpy as jnp
    tree = {"w": jnp.arange(16, dtype=jnp.bfloat16)}
    saver = ck.AsyncSaver()
    saver.save(tmp_path, 3, tree)
    saver.save(tmp_path, 4, tree)   # waits for the in-flight save
    saver.wait()
    assert ck.latest_step(tmp_path) == 4
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    back = ck.restore(tmp_path, 4, like)
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
