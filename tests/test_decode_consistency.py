"""prefill + decode_step must reproduce the full-forward logits exactly
(fp32 cache, no MoE token dropping) — validates cache layouts, absorbed
MLA decode, SSD decode recurrence, hybrid shared-attn caches."""
import dataclasses

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from repro.configs.registry import ARCHS, smoke_config
from repro.models.factory import build_model


def _grow_cache(cache, extra=4):
    def growleaf(path, x):
        nm = next((str(e.key) for e in reversed(path)
                   if isinstance(e, jtu.DictKey)), None)
        in_cross = any(isinstance(e, jtu.DictKey) and str(e.key) == "cross"
                       for e in path)
        if nm in ("k", "v", "c_kv", "k_rope", "k_scale", "v_scale") \
                and not in_cross:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, extra)
            return jnp.pad(x, pad)
        return x
    return jtu.tree_map_with_path(growleaf, cache)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(arch).replace(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :s]}
    if cfg.family == "audio":
        ef = jax.random.normal(rng, (b, cfg.encoder.n_frames, cfg.d_model),
                               jnp.float32) * 0.1
        full["enc_frames"] = pre["enc_frames"] = ef
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(s + 1, dtype=jnp.int32)[None, None],
                               (3, b, s + 1))
        full["mrope_positions"] = pos
        pre["mrope_positions"] = pos[:, :, :s]
        ve = jax.random.normal(rng, (b, cfg.vision.n_patches, cfg.d_model),
                               jnp.float32) * 0.1
        full["vision_embeds"] = pre["vision_embeds"] = ve

    logits_full, _, _ = model.forward(params, full, remat_policy="none")
    last, cache = model.prefill(params, pre, kv_dtype="float32")
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, s - 1]),
                               atol=2e-4, rtol=2e-3)
    cache = _grow_cache(cache)
    db = {"tokens": toks[:, s:s + 1]}
    if cfg.family == "vlm":
        db["mrope_positions"] = full["mrope_positions"][:, :, s:s + 1]
    lg, cache2 = model.decode(params, cache, db)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, s]),
                               atol=2e-4, rtol=2e-3)
    assert int(cache2["pos"][0]) == s + 1


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "zamba2-1.2b"])
def test_int8_kv_close(arch):
    """int8 KV (physical representation) stays close to fp32 logits."""
    cfg = smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                              cfg.vocab_size)
    _, cache32 = model.prefill(params, {"tokens": toks[:, :s]},
                               kv_dtype="float32")
    _, cache8 = model.prefill(params, {"tokens": toks[:, :s]},
                              kv_dtype="int8")
    db = {"tokens": toks[:, s:s + 1]}
    l32, _ = model.decode(params, _grow_cache(cache32), db)
    l8, _ = model.decode(params, _grow_cache(cache8), db)
    # int8 with per-(token,head) scales: small relative error on logits
    denom = np.maximum(np.abs(np.asarray(l32)).max(), 1e-6)
    rel = np.abs(np.asarray(l8) - np.asarray(l32)).max() / denom
    assert rel < 0.08, rel
