"""Minimal offline stand-in for `hypothesis`, installed by conftest.py when
the real package cannot be imported (this container has no network access).

It implements just the surface the property tests in this repo use:
``given``, ``settings(max_examples=, deadline=)``, ``assume``, and the
strategies ``integers / floats / booleans / sampled_from / tuples / lists``.
Generation is plain seeded pseudo-random sampling (no shrinking, no
database) — deterministic across runs so failures are reproducible. When
the real hypothesis is installed it always wins (see conftest.py).
"""
from __future__ import annotations

import functools
import math
import random
import sys
import types

_SEED = 0x7A40  # fixed: repeatable example streams


class _Strategy:
    """A strategy is just a draw function rnd -> value."""

    def __init__(self, draw, label="strategy"):
        self._draw = draw
        self._label = label

    def draw(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return _Strategy(lambda rnd: fn(self._draw(rnd)),
                         f"{self._label}.map")

    def filter(self, pred, max_tries: int = 1000):
        def draw(rnd):
            for _ in range(max_tries):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise Unsatisfiable(f"filter on {self._label} never satisfied")
        return _Strategy(draw, f"{self._label}.filter")

    def __repr__(self):
        return f"<shim {self._label}>"


class Unsatisfiable(Exception):
    pass


class _Assumption(Exception):
    """Raised by assume(False); the example is silently discarded."""


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)
    return _Strategy(lambda rnd: rnd.randint(lo, hi),
                     f"integers({lo}, {hi})")


def floats(min_value=None, max_value=None, *, allow_nan=None,
           allow_infinity=None, width=64) -> _Strategy:
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)

    def draw(rnd):
        # mix uniform draws with the boundary values hypothesis loves
        r = rnd.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rnd.uniform(lo, hi)
    return _Strategy(draw, f"floats({lo}, {hi})")


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: rnd.random() < 0.5, "booleans()")


def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty collection")
    return _Strategy(lambda rnd: pool[rnd.randrange(len(pool))],
                     f"sampled_from(<{len(pool)}>)")


def tuples(*strategies) -> _Strategy:
    return _Strategy(lambda rnd: tuple(s.draw(rnd) for s in strategies),
                     f"tuples(<{len(strategies)}>)")


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int | None = None, unique: bool = False) -> _Strategy:
    cap = min_size + 10 if max_size is None else max_size

    def draw(rnd):
        n = rnd.randint(min_size, cap)
        if not unique:
            return [elements.draw(rnd) for _ in range(n)]
        out, seen = [], set()
        for _ in range(1000):
            if len(out) >= n:
                break
            v = elements.draw(rnd)
            if v not in seen:
                seen.add(v)
                out.append(v)
        return out
    return _Strategy(draw, f"lists[{min_size},{cap}]")


def just(value) -> _Strategy:
    return _Strategy(lambda rnd: value, "just")


def one_of(*strategies) -> _Strategy:
    flat = []
    for s in strategies:
        flat.extend(s if isinstance(s, (list, tuple)) else [s])
    return _Strategy(lambda rnd: flat[rnd.randrange(len(flat))].draw(rnd),
                     "one_of")


DEFAULT_MAX_EXAMPLES = 25


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording max_examples; order-independent with @given
    because the attribute rides along __dict__ (functools.wraps copies it)."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


class HealthCheck:
    """Accept any attribute (tests only ever *reference* members)."""
    def __getattr__(self, name):  # pragma: no cover - trivial
        return name

    all = classmethod(lambda cls: [])


def given(*strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            rnd = random.Random(_SEED)
            ran = 0
            attempts = 0
            while ran < n and attempts < n * 50:
                attempts += 1
                args = [s.draw(rnd) for s in strategies]
                kwargs = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except _Assumption:
                    continue
                except Exception:
                    sys.stderr.write(
                        f"[hypothesis-shim] falsifying example "
                        f"(run {ran}): args={args!r} kwargs={kwargs!r}\n")
                    raise
                ran += 1
        # pytest must not try to inject fixtures for the generated params
        wrapper.__signature__ = __import__("inspect").Signature([])
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def note(message):  # pragma: no cover - debugging aid
    sys.stderr.write(f"[hypothesis-shim note] {message}\n")


def install() -> None:
    """Register shim modules as `hypothesis` / `hypothesis.strategies`."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.note = note
    mod.HealthCheck = HealthCheck()
    mod.__version__ = "0.0-shim"
    mod.__is_shim__ = True

    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "tuples",
                 "lists", "just", "one_of"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
