"""Multi-device behaviour (subprocess with forced host devices): sharding
policy on the production mesh, small-mesh lowering of train/prefill/decode,
pipeline parallelism, elastic checkpoint restore across mesh sizes."""
import pytest

from conftest import run_subprocess_jax


def test_sharding_policy_divisibility_production():
    """Every param PartitionSpec must divide its dim on the (16,16) and
    (2,16,16) production meshes, for all 10 assigned archs."""
    out = run_subprocess_jax("""
import jax
from repro.configs.registry import ARCHS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import abstract_params
from repro.models.factory import build_model
from repro.sharding.policy import param_pspecs

for multi in (False, True):
    mesh = make_production_mesh(multi_pod=multi)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name in ARCHS:
        cfg = get_arch(name).replace(head_pad_to=16)
        shapes = abstract_params(build_model(cfg))
        specs = param_pspecs(shapes, mesh)
        for sh, sp in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, '_normalized_spec') or x.__class__.__name__=='PartitionSpec')):
            for dim, part in zip(sh.shape, tuple(sp)):
                if part is None: continue
                axes = (part,) if isinstance(part, str) else part
                prod = 1
                for a in axes: prod *= sizes[a]
                assert dim % prod == 0, (name, sh.shape, tuple(sp))
print("OK")
""", devices=512, timeout=900)
    assert "OK" in out


def test_small_mesh_lower_compile_all_kinds():
    """steps builders lower+compile on a 2x2 host mesh for one dense, one
    MoE and one SSM smoke arch, for train/prefill/decode."""
    out = run_subprocess_jax("""
import jax, dataclasses
from repro.configs.base import ShapeConfig
from repro.configs.registry import smoke_config
from repro.launch import steps
from repro.models.factory import build_model
from repro.train.optimizer import adamw

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2,2), ("data","model"))
for arch in ("deepseek-7b", "phi3.5-moe-42b-a6.6b", "mamba2-130m"):
    cfg = smoke_config(arch).replace(head_pad_to=2)
    model = build_model(cfg)
    p_sds, _ = steps.params_sds(model, mesh)
    for kind, name in (("train","t"), ("prefill","p"), ("decode","d")):
        shape = ShapeConfig(name=name, kind=kind, seq_len=32,
                            global_batch=4)
        batch = steps.input_specs(cfg, shape, mesh)
        with mesh:
            if kind == "train":
                opt = adamw(1e-3)
                fn, _ = steps.make_train_step(model, mesh, shape, opt)
                o_sds, _ = steps.opt_state_sds(opt,
                                               steps.abstract_params(model),
                                               mesh)
                jax.jit(fn).lower(p_sds, o_sds, batch).compile()
            elif kind == "prefill":
                fn = steps.make_prefill_step(model, mesh, shape)
                jax.jit(fn).lower(p_sds, batch).compile()
            else:
                fn = steps.make_decode_step(model, mesh, shape)
                c_sds = steps.cache_specs_sds(model, shape, mesh)
                jax.jit(fn).lower(p_sds, c_sds, batch).compile()
    print(arch, "ok")
print("OK")
""", devices=4, timeout=900)
    assert "OK" in out


def test_pipeline_parallel_exact():
    out = run_subprocess_jax("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline_parallel import pipeline_forward
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2,2), ("pod","data"))
rng = np.random.default_rng(0)
W = jnp.asarray(rng.standard_normal((2, 16, 16)).astype(np.float32)*0.3)
stage_fn = lambda w, h: jnp.tanh(h @ w)
x = jnp.asarray(rng.standard_normal((4, 8, 16)).astype(np.float32))
with mesh:
    out = pipeline_forward(stage_fn, W, x, mesh=mesh)
ref = jnp.stack([stage_fn(W[1], stage_fn(W[0], x[i])) for i in range(4)])
assert float(jnp.max(jnp.abs(out - ref))) < 1e-6
print("OK")
""", devices=4)
    assert "OK" in out


def test_elastic_checkpoint_across_meshes():
    out = run_subprocess_jax("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from repro.train import checkpoint as ck
tree = {"wq": jnp.arange(128, dtype=jnp.bfloat16).reshape(16, 8),
        "scale": jnp.ones(5)}
from repro.launch.mesh import make_mesh_compat
mesh8 = make_mesh_compat((4, 2), ("data", "model"))
mesh2 = make_mesh_compat((2,), ("model",))
d = tempfile.mkdtemp()
ck.save(d, 1, tree, mesh=mesh8)
like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
back = ck.restore(d, 1, like, mesh=mesh2)
np.testing.assert_array_equal(np.asarray(back["wq"], np.float32),
                              np.asarray(tree["wq"], np.float32))
assert "model" in str(back["wq"].sharding.spec)
print("OK")
""", devices=8)
    assert "OK" in out


def test_decode_cache_specs_divisible():
    """Cache PartitionSpecs divide on the production mesh for decode_32k
    and long_500k across families (incl. whisper's 1500-frame cross KV)."""
    out = run_subprocess_jax("""
import jax
from repro.configs.registry import ARCHS, get_arch
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cache_specs_sds
from repro.models.factory import build_model

mesh = make_production_mesh()
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
for name in ARCHS:
    for shape_name in ("decode_32k", "long_500k"):
        cfg = get_arch(name).replace(head_pad_to=16)
        shape = SHAPES[shape_name]
        if not shape_applicable(cfg, shape)[0]:
            continue
        sds = cache_specs_sds(build_model(cfg), shape, mesh)
        for leaf in jax.tree.leaves(sds):
            spec = leaf.sharding.spec
            for dim, part in zip(leaf.shape, tuple(spec)):
                if part is None: continue
                axes = (part,) if isinstance(part, str) else part
                prod = 1
                for a in axes: prod *= sizes[a]
                assert dim % prod == 0, (name, shape_name, leaf.shape,
                                         tuple(spec))
print("OK")
""", devices=512, timeout=900)
    assert "OK" in out
