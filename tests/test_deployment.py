"""Deployment-config rules encode the §Perf measurements."""
from repro.configs.deployment import tuned_shape
from repro.configs.registry import get_arch
from repro.configs.shapes import DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K


def test_decode_rules():
    t = tuned_shape(get_arch("qwen2.5-32b"), DECODE_32K)
    assert t.params_tp_only and t.kv_dtype == "int8"
    # tiny-model long-context keeps baseline (measured regression)
    t = tuned_shape(get_arch("mamba2-130m"), LONG_500K)
    assert not t.params_tp_only and t.kv_dtype == "bfloat16"


def test_prefill_rules():
    t = tuned_shape(get_arch("granite-20b"), PREFILL_32K)
    assert t.params_tp_only and t.prefill_last_only


def test_train_rules():
    moe = tuned_shape(get_arch("deepseek-v2-236b"), TRAIN_4K)
    assert moe.train_attn_chunk and moe.remat_policy == "dots" \
        and moe.microbatch_seqs_per_shard == 4
    dense = tuned_shape(get_arch("qwen2.5-32b"), TRAIN_4K)
    assert dense == TRAIN_4K  # baseline retained (measured better)
