"""Optimizer / compression / checkpoint / FT runtime / pipeline / batcher /
executor / query — the substrate around the model zoo."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.executor import calibrate_capacity, run_cascade_batch
from repro.core.query import BinaryPredicate, Corpus, run_query
from repro.data.pipeline import Prefetcher, batched
from repro.serve.batcher import Batcher, Request
from repro.train import checkpoint as ck
from repro.train.compression import int8_compressor, topk_compressor
from repro.train.optimizer import adamw, cosine_schedule, sgd
from repro.train.runtime import RuntimeConfig, StragglerDetector, TrainRuntime


# -------------------------------------------------------------- optimizer --
@pytest.mark.parametrize("make", [lambda: adamw(0.1),
                                  lambda: sgd(0.05, momentum=0.9)])
def test_optimizer_converges_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule():
    fn = cosine_schedule(1.0, warmup=10, total=100, floor_frac=0.1)
    assert float(fn(jnp.int32(5))) == pytest.approx(0.5)
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


# ------------------------------------------------------------ compression --
@pytest.mark.parametrize("make", [lambda: topk_compressor(0.25),
                                  int8_compressor])
def test_error_feedback_identity(make):
    """decompressed + residual' == grad + residual (nothing is lost)."""
    comp = make()
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((8, 8)).astype(np.float32))}
    state = comp.init(g)
    for _ in range(3):
        dec, state2, _ = comp.apply(g, state)
        np.testing.assert_allclose(
            np.asarray(dec["w"] + state2["w"]),
            np.asarray(g["w"] + state["w"]), atol=1e-5)
        state = state2


def test_compressed_training_still_converges():
    opt = adamw(0.05)
    comp = topk_compressor(0.5)
    params = {"w": jnp.asarray([4.0, -3.0, 2.0, -1.0])}
    state = opt.init(params)
    resid = comp.init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        dec, resid, _ = comp.apply(grads, resid)
        params, state, _ = opt.update(dec, state, params)
    assert float(jnp.abs(params["w"]).max()) < 5e-2


# -------------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.float32(3.5)}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ck.save(d, s, tree, keep=2)
        assert ck.latest_step(d) == 5
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), tree)
        back = ck.restore(d, 5, like)
        assert back["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(back["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        # gc kept only 2
        import pathlib
        assert len(list(pathlib.Path(d).glob("step_*"))) == 2


# ------------------------------------------------------------- FT runtime --
def test_runtime_recovers_and_matches_uninterrupted():
    def step_fn(params, opt, batch):
        p = {"w": params["w"] + batch["x"]}
        return p, opt, {"loss": jnp.sum(p["w"])}

    def batches(step):
        return {"x": jnp.float32(step + 1)}

    with tempfile.TemporaryDirectory() as d1:
        rt = TrainRuntime(step_fn, RuntimeConfig(d1, ckpt_every=3))
        p0 = {"w": jnp.float32(0.0)}
        pA, _, histA = rt.run(p0, {}, batches, num_steps=10)
    with tempfile.TemporaryDirectory() as d2:
        rt = TrainRuntime(step_fn, RuntimeConfig(d2, ckpt_every=3))
        rt.inject_failure_at = {5, 8}
        pB, _, histB = rt.run(p0, {}, batches, num_steps=10)
        assert rt.recoveries == 2
    assert float(pA["w"]) == float(pB["w"])  # recovery is replay-exact


def test_straggler_detector():
    det = StragglerDetector(warmup=3, z_thresh=2.5)
    flagged = [det.observe(i, 0.1 + 0.001 * (i % 2)) for i in range(20)]
    assert not any(flagged)
    assert det.observe(20, 1.5)          # 15x normal -> flagged
    assert det.flagged[0][0] == 20
    assert not det.observe(21, 0.1)      # baseline not poisoned


# -------------------------------------------------------- data pipeline ----
def test_prefetcher_preserves_stream():
    items = list(range(50))
    out = list(Prefetcher(iter(items), depth=4))
    assert out == items


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise ValueError("boom")
    with pytest.raises(ValueError):
        list(Prefetcher(gen()))


def test_batched_epochs():
    x = np.arange(10)[:, None]
    y = np.arange(10)
    batches = list(batched(x, y, 4, epochs=2))
    assert len(batches) == 4  # 2 per epoch (drop remainder)
    assert batches[0]["images"].shape == (4, 1)


# ------------------------------------------------------------- batcher -----
def test_batcher_batches_and_pads():
    calls = []

    def run(payloads):
        calls.append(len(payloads))
        return [p * 2 for p in payloads]

    b = Batcher(run, batch_size=4, max_wait_s=100)
    reqs = [Request(i, i) for i in range(6)]
    for r in reqs:
        b.submit(r)
    b.drain()
    assert [r.result for r in reqs] == [0, 2, 4, 6, 8, 10]
    assert b.stats.batches == 2 and b.stats.padded_slots == 2


# ------------------------------------------------------------- executor ----
def test_batched_executor_matches_sequential():
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.random((32, 8, 8, 3), np.float32))

    def model_a(x):  # uncertain in the middle band
        return jnp.clip(x.mean(axis=(1, 2, 3)) * 2.0, 0, 1)

    def model_b(x):
        return (x.mean(axis=(1, 2, 3)) > 0.5).astype(jnp.float32)

    ident = lambda x: x
    labels, stats = run_cascade_batch(
        imgs, [model_a, model_b], [(0.3, 0.7), (None, None)],
        [ident, ident], capacities=[32])
    # sequential reference
    o = np.asarray(model_a(imgs))
    expect = np.where(o >= 0.7, 1, np.where(o <= 0.3, 0,
                      np.asarray(model_b(imgs))))
    np.testing.assert_array_equal(np.asarray(labels), expect)
    assert int(stats["overflow"]) == 0


def test_batched_executor_overflow_fallback():
    imgs = jnp.asarray(np.full((16, 4, 4, 3), 0.5, np.float32))
    model_a = lambda x: jnp.full((x.shape[0],), 0.5)   # all uncertain
    model_b = lambda x: jnp.ones((x.shape[0],))
    labels, stats = run_cascade_batch(
        imgs, [model_a, model_b], [(0.3, 0.7), (None, None)],
        [lambda x: x] * 2, capacities=[4])
    assert int(stats["overflow"]) == 12
    # overflow items fall back to level-0 forced decision (0.5 -> positive)
    assert int(np.asarray(labels).sum()) == 16
    assert calibrate_capacity(0.25, 64) >= 16


# ---------------------------------------------------------------- query ----
def test_query_combines_metadata_and_predicates():
    rng = np.random.default_rng(0)
    imgs = rng.random((20, 4, 4, 3)).astype(np.float32)
    corpus = Corpus(images=imgs,
                    metadata={"city": np.array(["detroit", "akron"] * 10)})
    pred = BinaryPredicate("bright",
                           lambda x: (x.mean(axis=(1, 2, 3)) > 0.5
                                      ).astype(np.int32))
    ids = run_query(corpus, metadata_eq={"city": "detroit"},
                    binary_preds=[pred])
    bright = imgs.mean(axis=(1, 2, 3)) > 0.5
    expect = [i for i in range(20) if i % 2 == 0 and bright[i]]
    assert list(ids) == expect
    assert "bright" in corpus.virtual_columns  # cached
