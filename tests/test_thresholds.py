"""Algorithm 1: faithful port vs vectorized batch implementation, plus the
precision guarantee the thresholds exist to provide."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.thresholds import (PRECISION_TARGETS, compute_thresholds,
                                   compute_thresholds_batch)


def _rand_scores(rng, n):
    """Scores loosely correlated with truth (a plausible classifier)."""
    truth = rng.integers(0, 2, n)
    scores = np.clip(truth * 0.55 + rng.normal(0.25, 0.25, n), 0, 1)
    return scores, truth


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("target", PRECISION_TARGETS)
def test_batch_matches_faithful(seed, target):
    rng = np.random.default_rng(seed)
    scores, truth = _rand_scores(rng, 300)
    lo, hi = compute_thresholds(lambda _: scores, None, truth, target)
    blo, bhi = compute_thresholds_batch(scores[None], truth, [target])
    assert lo == pytest.approx(blo[0, 0])
    assert hi == pytest.approx(bhi[0, 0])


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(PRECISION_TARGETS))
def test_batch_matches_faithful_hypothesis(seed, target):
    rng = np.random.default_rng(seed)
    scores, truth = _rand_scores(rng, 120)
    lo, hi = compute_thresholds(lambda _: scores, None, truth, target)
    blo, bhi = compute_thresholds_batch(scores[None], truth, [target])
    assert lo == blo[0, 0] and hi == bhi[0, 0]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_precision_guarantee(seed):
    """If a non-trivial p_high was chosen, the positive-certain precision
    at p_high exceeds the target on the calibration data (resp. >= for the
    negative side at p_low) — Algorithm 1's contract."""
    rng = np.random.default_rng(seed)
    scores, truth = _rand_scores(rng, 250)
    target = 0.95
    lo, hi = compute_thresholds(lambda _: scores, None, truth, target)
    if hi < 1.0:
        pred = scores >= hi
        prec = (pred & (truth == 1)).sum() / max(pred.sum(), 1)
        assert prec > target
    if lo > 0.0:
        pred = scores <= lo
        prec = (pred & (truth == 0)).sum() / max(pred.sum(), 1)
        assert prec >= target


def test_degenerate_models():
    """Constant scorers never satisfy a high target -> full-uncertain."""
    truth = np.array([0, 1] * 50)
    scores = np.full(100, 0.5)
    lo, hi = compute_thresholds(lambda _: scores, None, truth, 0.99)
    assert lo == 0.0 and hi == 1.0  # nothing certain

    perfect = truth.astype(float)
    lo, hi = compute_thresholds(lambda _: perfect, None, truth, 0.95)
    assert hi <= 0.95 and lo >= 0.05  # everything certain
