import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.alc import alc, average_throughput, best_matching, speedup
from repro.core.costs import CostProfile, rep_cost_s
from repro.core.transforms import (Representation, apply_transform,
                                   color_transform, representation_space,
                                   resize_area)


# ------------------------------------------------------------ transforms ---
def test_resize_area_box_filter():
    img = jnp.arange(16.0).reshape(1, 4, 4, 1)
    out = resize_area(img, 2)
    expect = np.array([[2.5, 4.5], [10.5, 12.5]])
    np.testing.assert_allclose(np.asarray(out)[0, :, :, 0], expect)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_resize_preserves_mean(seed):
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.random((2, 16, 16, 3), np.float32))
    for res in (2, 4, 8, 16):
        out = resize_area(img, res)
        np.testing.assert_allclose(np.asarray(out).mean(),
                                   np.asarray(img).mean(), atol=1e-6)


def test_color_transforms():
    img = jnp.asarray(np.random.default_rng(0).random((1, 4, 4, 3),
                                                      np.float32))
    assert color_transform(img, "rgb").shape[-1] == 3
    for c in ("r", "g", "b", "gray"):
        assert color_transform(img, c).shape[-1] == 1
    np.testing.assert_allclose(
        np.asarray(color_transform(img, "g"))[..., 0],
        np.asarray(img)[..., 1])


def test_representation_values():
    r = Representation(30, "rgb")
    assert r.values == 2700      # paper §VII-D: 30x30x3 = 2,700 values
    assert Representation(224, "rgb").values == 150528
    space = representation_space([30, 60, 120, 224])
    assert len(space) == 20      # 4 resolutions x 5 color reps


def test_apply_transform_shapes():
    img = jnp.zeros((2, 64, 64, 3))
    assert apply_transform(img, Representation(16, "gray")).shape \
        == (2, 16, 16, 1)


# ------------------------------------------------------------------ costs --
def test_scenario_cost_semantics():
    reps = [Representation(8, "gray"), Representation(32, "rgb")]
    prof = CostProfile.modeled({}, reps, base_hw=32)
    r = reps[0]
    assert rep_cost_s(prof, r, "INFER_ONLY", True) == 0.0
    camera = rep_cost_s(prof, r, "CAMERA", True)
    ongoing = rep_cost_s(prof, r, "ONGOING", True)
    archive_first = rep_cost_s(prof, r, "ARCHIVE", True)
    archive_later = rep_cost_s(prof, r, "ARCHIVE", False)
    assert camera == prof.transform_s[r.name]
    assert ongoing == prof.load_rep_s[r.name]
    assert archive_first == prof.load_full_s + prof.transform_s[r.name]
    assert archive_later == prof.transform_s[r.name]
    # smaller representation loads faster under ONGOING
    assert prof.load_rep_s[reps[0].name] < prof.load_rep_s[reps[1].name]


# -------------------------------------------------------------------- ALC --
def test_alc_rectangle():
    # single point (acc=1, thr=5) over [0, 1] -> area 5
    assert alc([1.0], [5.0], 0.0, 1.0) == pytest.approx(5.0)
    assert average_throughput([1.0], [5.0], 0.0, 1.0) == pytest.approx(5.0)


def test_alc_step():
    acc = [0.5, 1.0]
    thr = [10.0, 2.0]
    # [0,0.5] at 10 fps, (0.5,1.0] at 2 fps
    assert alc(acc, thr, 0.0, 1.0) == pytest.approx(0.5 * 10 + 0.5 * 2)


def test_speedup_identity_and_ratio():
    acc = [0.6, 0.9]
    thr = [8.0, 1.0]
    assert speedup(acc, thr, acc, thr) == pytest.approx(1.0)
    thr2 = [4.0, 0.5]
    assert speedup(acc, thr, acc, thr2) == pytest.approx(2.0)


def test_best_matching():
    acc = np.array([0.95, 0.90, 0.85])
    thr = np.array([1.0, 5.0, 50.0])
    i = best_matching(acc, thr, 0.9)
    assert acc[i] >= 0.9 and thr[i] == 5.0
    assert best_matching(acc, thr, 0.99) is None
