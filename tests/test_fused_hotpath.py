"""Fused Pallas pyramid+stage-0 hot path and lazy level materialization
(DESIGN.md §13, PR 7).

Covers, per the tentpole acceptance list:
* kernel bit-exactness property tests: fused_pyramid_stage0 vs the
  unfused reference composition across dyadic base sizes and interpret
  modes — pooled levels BIT-exact, f32 scores to float tolerance, int8
  scores within the pinned calibrated tolerance
  (benchmarks/calibrated_int8_stage0.json);
* invocation/materialization-counting regressions: lazy scheduling
  materializes strictly fewer level-rows than eager with bit-identical
  row sets; fused and unfused engines agree; warm reruns build nothing;
* the engine-costing contract: measured ScanStats.level_rows matches
  the level_schedule first-touch prediction exactly on a cold scan;
* sharded lockstep vs serial differentials under lazy scheduling;
* observed-selectivity feedback into shard skew weights (satellite 2).
"""
import json
import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import TahomaCNNConfig
from repro.core.executor import Stage0, make_fused_ingest
from repro.core.transforms import Representation, materialize_pyramid
from repro.engine.scan import (CompiledCascade, ScanEngine,
                               level_schedule, naive_scan)
from repro.kernels.image_transform import fused_pyramid_stage0
from repro.kernels.ref import fused_pyramid_stage0_ref
from repro.models.cnn import (cnn_predict_proba, dequantize_cnn, init_cnn,
                              quantize_cnn)

CAL_PATH = Path(__file__).resolve().parents[1] / "benchmarks" \
    / "calibrated_int8_stage0.json"


def _dyadic_images(n, hw, seed=0):
    """uint8-quantized pixels (k/256): box-filter pooling over dyadic
    windows is EXACT in f32 for these — the bit-exactness precondition
    (core/transforms.materialize_pyramid)."""
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 256, (n, hw, hw, 3))
            .astype(np.float32) / 256.0)


def _stage0(seed, res, color="gray", n_conv=2):
    cfg = TahomaCNNConfig(n_conv_layers=n_conv, conv_nodes=4,
                          dense_nodes=8, input_hw=res,
                          input_channels=1 if color != "rgb" else 3)
    params = init_cnn(jax.random.PRNGKey(seed), cfg)
    rep = Representation(res, color)
    return Stage0(params=params, rep=rep, qparams=quantize_cnn(params))


# ------------------------------------------------ kernel bit-exactness ----
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 32]),
       st.sampled_from([True, None]))
def test_fused_kernel_bit_exact_vs_unfused_reference(seed, base, interpret):
    """Property: one kernel pass == materialize_pyramid + stage-0 CNN.
    Pooled levels are BIT-exact (dyadic pixels); scores match the jnp
    composition to f32 tolerance. interpret=None resolves per backend
    (True off-TPU), True forces interpret mode — both must agree."""
    imgs = _dyadic_images(3, base, seed)
    s0 = _stage0(seed, base // 4)
    out_res = [base // 2, base // 4]
    levels, scores = fused_pyramid_stage0(
        jnp.asarray(imgs), out_res, s0.params, s0.rep,
        interpret=interpret)
    ref_levels, ref_scores = fused_pyramid_stage0_ref(
        jnp.asarray(imgs), out_res, s0.params, s0.rep)
    for r in out_res:
        assert np.array_equal(np.asarray(levels[r]),
                              np.asarray(ref_levels[r])), r
        assert np.array_equal(np.asarray(levels[r]),
                              np.asarray(materialize_pyramid(
                                  jnp.asarray(imgs), [r])[r])), r
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(ref_scores), atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_fused_kernel_int8_matches_ref_and_calibration(seed):
    """int8 weight path: the kernel's dequantize-at-use epilogue matches
    the unfused int8 reference to f32 tolerance, and int8-vs-f32 score
    deviation stays inside the PINNED calibrated tolerance — the same
    contract calibrated_infer_costs.json pins for cost estimates."""
    cal = json.loads(CAL_PATH.read_text())
    base = 32
    imgs = _dyadic_images(3, base, seed)
    s0 = _stage0(seed, base // 4)
    _, s_int8 = fused_pyramid_stage0(jnp.asarray(imgs), [base // 4],
                                     s0.params, s0.rep,
                                     qparams=s0.qparams)
    _, ref_int8 = fused_pyramid_stage0_ref(jnp.asarray(imgs), [base // 4],
                                           s0.params, s0.rep,
                                           qparams=s0.qparams)
    _, s_f32 = fused_pyramid_stage0(jnp.asarray(imgs), [base // 4],
                                    s0.params, s0.rep)
    np.testing.assert_allclose(np.asarray(s_int8), np.asarray(ref_int8),
                               atol=1e-5)
    dev = float(np.max(np.abs(np.asarray(s_int8) - np.asarray(s_f32))))
    assert dev <= cal["score_abs_tol"], (dev, cal["score_abs_tol"])


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_int8_quantize_roundtrip_error_bounded(seed):
    """Per-tensor symmetric int8: |w - dequant(quant(w))| <= scale/2,
    with scale = absmax/127 — the rounding bound the calibrated score
    tolerance rests on."""
    cfg = TahomaCNNConfig(n_conv_layers=2, conv_nodes=4, dense_nodes=8,
                          input_hw=8, input_channels=1)
    params = init_cnn(jax.random.PRNGKey(seed), cfg)
    dq = dequantize_cnn(quantize_cnn(params))
    pairs = [(l["w"], m["w"]) for l, m in zip(params["conv"], dq["conv"])]
    pairs += [(params["dense_w"], dq["dense_w"]),
              (params["out_w"], dq["out_w"])]
    for w, w2 in pairs:
        scale = float(jnp.max(jnp.abs(w))) / 127.0
        assert float(jnp.max(jnp.abs(w - w2))) <= scale / 2 + 1e-9
    # biases pass through untouched
    for l, m in zip(params["conv"], dq["conv"]):
        assert np.array_equal(np.asarray(l["b"]), np.asarray(m["b"]))
    assert np.array_equal(np.asarray(params["dense_b"]),
                          np.asarray(dq["dense_b"]))


def test_make_fused_ingest_kernel_flag_validation():
    s0 = _stage0(0, 8)
    casc_fns = [lambda x: jnp.zeros(x.shape[0])]
    with pytest.raises(ValueError):
        make_fused_ingest(casc_fns, [(None, None)],
                          [Representation(8, "gray")], [], [],
                          use_kernel=True, stage0=None)
    with pytest.raises(ValueError):
        make_fused_ingest(casc_fns, [(None, None)],
                          [Representation(8, "gray")], [], [],
                          stage0=Stage0(s0.params, s0.rep), int8=True)


# --------------------------------------------------- scan-engine toys -----
def _linear_cascade(concept, seed, resolutions, thresholds, *,
                    cost_s=1e-4, selectivity=0.5):
    """Linear toy cascade over arbitrary per-level resolutions (rgb), so
    different cascades touch DIFFERENT pyramid levels and the lazy
    schedule has real later-stage-only levels to defer."""
    r = np.random.default_rng(seed)
    reps = [Representation(res, "rgb") for res in resolutions]
    dims = [res * res * 3 for res in resolutions]
    ws = [jnp.asarray(r.standard_normal((d, 1)).astype(np.float32))
          for d in dims]

    def mk(i):
        def f(x):
            z = (x.reshape(x.shape[0], -1) - 0.5) @ ws[i]
            return jax.nn.sigmoid(z[:, 0] * 60.0 / math.sqrt(dims[i]))
        return f
    return CompiledCascade(concept, ("lin", seed), reps,
                           [mk(i) for i in range(len(reps))],
                           list(thresholds), cost_s=cost_s,
                           selectivity=selectivity)


@pytest.fixture(scope="module")
def lazy_setup():
    imgs = _dyadic_images(200, 32, seed=7)
    cascades = [
        _linear_cascade("a", 1, [8], [(None, None)], cost_s=1e-4),
        _linear_cascade("b", 2, [16, 32], [(0.3, 0.7), (None, None)],
                        cost_s=2e-4),
        _linear_cascade("c", 3, [4, 16], [(0.35, 0.65), (None, None)],
                        cost_s=4e-4),
    ]
    metadata = {"cam": np.arange(len(imgs)) % 2}
    return imgs, cascades, metadata


def test_lazy_strictly_fewer_level_rows_same_rows(lazy_setup):
    """Lazy scheduling must materialize STRICTLY fewer level-rows than
    eager while returning a bit-identical row set (tentpole acceptance:
    the §11 estimated-vs-measured gap closes without changing
    results)."""
    imgs, cascades, metadata = lazy_setup
    res_e = ScanEngine(imgs, metadata, chunk=32, lazy=False).execute(
        cascades, {"cam": 0})
    res_l = ScanEngine(imgs, metadata, chunk=32, lazy=True).execute(
        cascades, {"cam": 0})
    assert np.array_equal(res_e.indices, res_l.indices)
    ref = naive_scan(imgs, cascades, metadata, {"cam": 0}, chunk=32)
    assert np.array_equal(res_l.indices, ref)
    eager, lazy = res_e.stats.level_rows, res_l.stats.level_rows
    assert set(lazy) == set(eager)          # same levels get touched
    assert all(lazy[r] <= eager[r] for r in eager)
    assert sum(lazy.values()) < sum(eager.values())
    # the static union set is reported identically either way
    assert res_l.stats.pyramid_levels == res_e.stats.pyramid_levels


def test_fused_and_unfused_engines_identical(lazy_setup):
    """The fused single-program ingest is a pure fusion: labels, row
    sets, and materialization counters all match the unfused
    pyramid-program + stage-0-buffer baseline."""
    imgs, cascades, metadata = lazy_setup
    res_f = ScanEngine(imgs, metadata, chunk=32, fused=True).execute(
        cascades, {"cam": 0})
    res_u = ScanEngine(imgs, metadata, chunk=32, fused=False).execute(
        cascades, {"cam": 0})
    assert np.array_equal(res_f.indices, res_u.indices)
    assert res_f.stats.level_rows == res_u.stats.level_rows
    assert res_f.stats.chunks == res_u.stats.chunks


def test_level_rows_match_schedule_exactly_on_cold_scan(lazy_setup):
    """The engine-costing contract (closes DESIGN.md §11's known gap):
    on a cold scan every ingest level is pooled for exactly the scanned
    rows, and every first-touch level for exactly the rows its stage
    evaluated — ScanStats.level_rows equals the level_schedule
    prediction with NO slack."""
    imgs, cascades, metadata = lazy_setup
    eng = ScanEngine(imgs, metadata, chunk=32, lazy=True)
    res = eng.execute(cascades, {"cam": 0})
    ingest_set, _, derive = level_schedule(cascades, imgs.shape[1], True)
    want = {r: res.stats.rows_scanned for r in ingest_set}
    for s, levels in enumerate(derive):
        for r in levels:
            want[r] = res.stats.stages[s].rows_evaluated
    assert res.stats.level_rows == want


def test_lazy_warm_rerun_builds_nothing(lazy_setup, monkeypatch):
    """Second identical scan against a warm virtual-column store: zero
    chunks, zero pyramid materializations, zero level-rows — and the
    same row set."""
    import repro.engine.scan as scan_mod

    imgs, cascades, metadata = lazy_setup
    eng = ScanEngine(imgs, metadata, chunk=32, jit=False)
    first = eng.execute(cascades, {"cam": 0})
    calls = []
    real = scan_mod.materialize_pyramid

    def counting(img, resolutions):
        calls.append(tuple(resolutions))
        return real(img, resolutions)

    monkeypatch.setattr(scan_mod, "materialize_pyramid", counting)
    again = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(first.indices, again.indices)
    assert again.stats.chunks == 0
    assert again.stats.level_rows == {}
    assert calls == []


@pytest.mark.multidevice
@pytest.mark.parametrize("shards", [1, 8])
@pytest.mark.parametrize("parallel", [True, False])
def test_sharded_lazy_bit_identical_and_counters(lazy_setup, shards,
                                                 parallel):
    """Sharded lockstep and serial-fallback backends under lazy
    scheduling: row sets bit-identical to the serial engine, and the
    cross-shard level_rows totals equal the serial counters on a cold
    scan (both engines follow the same first-touch schedule)."""
    from repro.engine.sharded import ShardedScanEngine

    imgs, cascades, metadata = lazy_setup
    ref = ScanEngine(imgs, metadata, chunk=32).execute(
        cascades, {"cam": 0})
    eng = ShardedScanEngine(imgs, metadata, shards=shards, chunk=32)
    res = eng.execute(cascades, {"cam": 0}, parallel=parallel)
    assert np.array_equal(res.indices, ref.indices)
    assert res.stats.level_rows == ref.stats.level_rows


def test_monitor_observed_selectivity_feeds_shard_weights(lazy_setup):
    """Satellite: OnlineReorderer's per-flush observations flow into
    plan_shards skew weights on re-plan — a predicate observed to kill
    everything collapses the expected cost of every later predicate."""
    from repro.engine.planner import OnlineReorderer
    from repro.engine.sharded import ShardedScanEngine

    imgs, cascades, metadata = lazy_setup
    eng = ShardedScanEngine(imgs, metadata, shards=2, chunk=32)
    ids = np.where(eng.metadata_mask({"cam": 0}))[0]
    mon = OnlineReorderer(cascades, min_rows=1)
    mon.observe(cascades[0].key, np.zeros(128, np.int64),
                marginal=True)                             # observed sel 0
    w_static = eng.row_weights(cascades, ids)
    w_refined = eng.row_weights(cascades, ids, monitor=mon)
    # refined: nothing survives predicate 0, so only its own cost remains
    assert np.allclose(w_refined, cascades[0].cost_s)
    assert w_refined.sum() < w_static.sum()
    plan = eng.plan_for(cascades, ids=ids, monitor=mon)
    assert plan.n_shards == 2 and plan.validate(ids) is None
    # executing with the monitor attached keeps feeding it (observe-only
    # on sharded backends: proposals are never applied mid-scan)
    res = eng.execute(cascades, {"cam": 0}, monitor=mon)
    ref = ScanEngine(imgs, metadata, chunk=32).execute(
        cascades, {"cam": 0})
    assert np.array_equal(res.indices, ref.indices)
    assert mon.n[cascades[0].key] > 128      # ingest flushes observed
