"""Relational query algebra (engine/algebra.py, DESIGN.md §15): the
normalize rewrites (double negation, De Morgan, flattening) must
preserve boolean semantics exactly; the cost model's OR-ordering
INVERSION (rank cost/sel — most selective branch LAST, because an OR
branch short-circuits on TRUE) must match brute force over all
permutations; the executor — optimized short-circuit lowering AND the
unoptimized full-evaluation baseline, serial AND sharded, cold AND
index-seeded — must return row sets bit-identical to the per-row naive
oracle for RANDOM trees; the cross-corpus temporal hash join with
window pushdown must emit pairs bit-identical to the nested loop; and
the QuerySpec.where trained-system path plus index-aware joint costing
must compose with all of it."""
import dataclasses
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costs import DecomposedCost
from repro.data.synthetic import make_two_camera_corpus
from repro.engine.algebra import (And, Join, Not, Or, PlanNode, Pred,
                                  _chain_cost, _plan_join, execute_join,
                                  execute_tree, naive_join_pairs,
                                  naive_tree_rows, normalize,
                                  order_children, plan_from_cascades,
                                  temporal_hash_join)
from repro.engine.ingest import CandidateIndex
from repro.engine.scan import ScanEngine, naive_scan
from repro.engine.sharded import ShardedScanEngine
from test_query_engine import _toy_cascade, _uint8_images

CONCEPTS = ("a", "b", "c")


def _cascades():
    """Toy cascades with DISTINCT planner annotations so ordering is
    non-trivial: a is cheap/rare, b mid, c expensive/common."""
    anno = {"a": (1e-4, 0.2), "b": (2e-4, 0.5), "c": (4e-4, 0.7)}
    return {c: dataclasses.replace(_toy_cascade(c, i + 1),
                                   cost_s=anno[c][0],
                                   selectivity=anno[c][1])
            for i, c in enumerate(CONCEPTS)}


@pytest.fixture(scope="module")
def setup():
    """Corpus + cascades + the per-concept naive masks (computed ONCE:
    the oracle for any tree is then pure mask algebra)."""
    n, hw = 160, 32
    images = _uint8_images(n, hw)
    metadata = {"cam": np.arange(n) % 2,
                "t": np.arange(n, dtype=np.int64) * 3}
    cascades = _cascades()
    fn_cache: dict = {}
    masks = {}
    for c, casc in cascades.items():
        rows = naive_scan(images, [casc], chunk=64, _fn_cache=fn_cache)
        m = np.zeros(n, bool)
        m[rows] = True
        masks[c] = m
    return images, metadata, cascades, masks


def _mask_eval(tree, masks, n):
    if isinstance(tree, Pred):
        return masks[tree.concept]
    if isinstance(tree, Not):
        return ~_mask_eval(tree.child, masks, n)
    ms = [_mask_eval(c, masks, n) for c in tree.children]
    out = np.ones(n, bool) if isinstance(tree, And) else np.zeros(n, bool)
    for m in ms:
        out = (out & m) if isinstance(tree, And) else (out | m)
    return out


def _random_tree(rng, depth=3):
    kind = rng.integers(0, 4) if depth > 0 else 0
    if kind == 0:
        return Pred(str(rng.choice(list(CONCEPTS))))
    if kind == 1:
        return Not(_random_tree(rng, depth - 1))
    kids = [_random_tree(rng, depth - 1)
            for _ in range(int(rng.integers(2, 4)))]
    return And(*kids) if kind == 2 else Or(*kids)


# ------------------------------------------------------- normalize -------
def _nnf_ok(t):
    if isinstance(t, Pred):
        return True
    if isinstance(t, Not):
        return isinstance(t.child, Pred)
    if isinstance(t, (And, Or)):
        if len(t.children) < 2:
            return False
        # flattened: no child shares the parent's operator
        return all(not isinstance(c, type(t)) and _nnf_ok(c)
                   for c in t.children)
    return False


def test_normalize_units():
    a, b, c = Pred("a"), Pred("b"), Pred("c")
    assert normalize(Not(Not(a))) == a
    assert normalize(Not(And(a, b))) == Or(Not(a), Not(b))
    assert normalize(Not(Or(a, b))) == And(Not(a), Not(b))
    assert normalize(And(And(a, b), c)) == And(a, b, c)
    assert normalize(And(a)) == a                 # single-child collapse
    assert normalize(Not(And(a, Not(b)))) == Or(Not(a), b)
    with pytest.raises((TypeError, ValueError)):
        normalize(Join(a, b, delta_t=1.0))        # Join is root-only


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**9))
def test_normalize_is_nnf_and_semantics_preserving(seed):
    rng = np.random.default_rng(seed)
    tree = _random_tree(rng, depth=4)
    norm = normalize(tree)
    assert _nnf_ok(norm)
    assert normalize(norm) == norm                # idempotent
    # identical truth table over random assignments
    for _ in range(8):
        masks = {c: rng.random(1) < 0.5 for c in CONCEPTS}
        assert bool(_mask_eval(tree, masks, 1)[0]) == \
            bool(_mask_eval(norm, masks, 1)[0])


# -------------------------------------------------------- ordering -------
def _leaf(sel, cost):
    return PlanNode("pred", est_sel=sel, est_cost=cost)


def test_order_children_matches_brute_force():
    rng = np.random.default_rng(7)
    for op in ("and", "or"):
        for _ in range(25):
            kids = [_leaf(float(rng.uniform(0.05, 0.95)),
                          float(rng.uniform(0.1, 10.0)))
                    for _ in range(int(rng.integers(2, 6)))]
            best = min(_chain_cost(op, list(p))
                       for p in itertools.permutations(kids))
            got = _chain_cost(op, order_children(op, list(kids)))
            assert got == pytest.approx(best)


def test_or_rank_is_inverted():
    """An OR branch short-circuits on TRUE, so the needle-in-haystack
    branch (cheap but RARELY true) goes LAST — the exact opposite of
    its AND position (DESIGN.md §15.2)."""
    needle = _leaf(0.02, 1.0)    # rarely true
    hay = _leaf(0.90, 1.0)       # almost always true
    assert order_children("or", [needle, hay]) == [hay, needle]
    assert order_children("and", [needle, hay]) == [needle, hay]
    # greedy path (> exhaustive limit) ranks by cost/sel ascending
    rng = np.random.default_rng(3)
    kids = [_leaf(float(rng.uniform(0.05, 0.95)),
                  float(rng.uniform(0.1, 10.0))) for _ in range(9)]
    ranks = [k.est_cost / k.est_sel
             for k in order_children("or", list(kids))]
    assert ranks == sorted(ranks)


# ----------------------------------------- differential oracle (tree) ----
@pytest.mark.parametrize("seed", range(8))
def test_random_trees_engine_matches_naive(seed, setup):
    """The load-bearing property: for RANDOM trees, the optimized
    short-circuit lowering and the unoptimized full-evaluation baseline
    both return rows bit-identical to the per-concept mask oracle
    (fixture-bound, so a plain seeded loop instead of @given — the
    offline hypothesis shim can't mix fixtures with drawn args)."""
    images, metadata, cascades, masks = setup
    rng = np.random.default_rng(1000 + seed)
    tree = _random_tree(rng, depth=3)
    eq = {"cam": 0} if rng.random() < 0.5 else None
    keep = (np.asarray(metadata["cam"]) == 0 if eq
            else np.ones(len(images), bool))
    ref = np.where(_mask_eval(tree, masks, len(images)) & keep)[0]
    for optimize in (True, False):
        eng = ScanEngine(images, metadata, chunk=64)
        plan = plan_from_cascades(tree, cascades, metadata=metadata,
                                  metadata_eq=eq, optimize=optimize)
        res = execute_tree(eng, plan)
        assert np.array_equal(res.indices, ref)


def test_naive_tree_rows_agrees_with_mask_oracle(setup):
    images, metadata, cascades, masks = setup
    tree = And(Pred("a"), Not(And(Pred("b"), Not(Pred("c")))))
    ref = np.where(_mask_eval(tree, masks, len(images))
                   & (np.asarray(metadata["cam"]) == 0))[0]
    got = naive_tree_rows(images, tree, cascades, metadata, {"cam": 0},
                          chunk=64)
    assert np.array_equal(got, ref)


def test_contradiction_yields_empty(setup):
    images, metadata, cascades, _ = setup
    tree = And(Pred("a"), Not(Pred("a")), Pred("b"))
    eng = ScanEngine(images, metadata, chunk=64)
    res = execute_tree(eng, plan_from_cascades(tree, cascades,
                                               metadata=metadata))
    assert len(res.indices) == 0
    assert len(naive_tree_rows(images, tree, cascades, metadata)) == 0


def _sharded_case(setup, shards):
    images, metadata, cascades, masks = setup
    tree = Or(And(Pred("a"), Not(Pred("b"))), Pred("c"))
    ref = np.where(_mask_eval(tree, masks, len(images))
                   & (np.asarray(metadata["cam"]) == 0))[0]
    eng = ShardedScanEngine(images, metadata, shards=shards, chunk=64)
    plan = plan_from_cascades(tree, cascades, metadata=metadata,
                              metadata_eq={"cam": 0})
    assert np.array_equal(execute_tree(eng, plan).indices, ref)


def test_sharded_one_shard_matches_naive(setup):
    _sharded_case(setup, 1)


@pytest.mark.multidevice
def test_sharded_eight_shards_matches_naive(setup):
    _sharded_case(setup, 8)


# ------------------------------------------------------ index seeding ----
def test_index_seeding_identical_rows_fewer_evaluations(setup):
    images, metadata, cascades, masks = setup
    n = len(images)
    index = CandidateIndex(n, list(cascades.values()))
    # ingest decided the label of 60% of rows for 'a' and 'b' — EXACT
    # labels (what stage-0 both-threshold decisions guarantee)
    rng = np.random.default_rng(11)
    for c in ("a", "b"):
        decided = np.where(rng.random(n) < 0.6)[0]
        index.decided.record(cascades[c].key, decided,
                             masks[c][decided].astype(np.int8))
    tree = Or(And(Pred("a"), Pred("b")), Not(Pred("c")))
    ref = np.where(_mask_eval(tree, masks, n))[0]
    cold_eng = ScanEngine(images, metadata, chunk=64)
    cold = execute_tree(cold_eng, plan_from_cascades(
        tree, cascades, metadata=metadata))
    seeded_eng = ScanEngine(images, metadata, chunk=64)
    plan = plan_from_cascades(tree, cascades, metadata=metadata,
                              index=index)
    seeded = execute_tree(seeded_eng, plan)
    assert np.array_equal(cold.indices, ref)
    assert np.array_equal(seeded.indices, ref)
    assert seeded.rows_evaluated < cold.rows_evaluated
    assert "index" in plan.explain(n_rows=n).lower()


def test_planning_stats_math():
    cascades = _cascades()
    key = cascades["a"].key
    index = CandidateIndex(10, [cascades["a"]])
    # 3 decided-0, 2 decided-1, 5 undecided
    index.decided.record(key, np.arange(5),
                         np.array([0, 0, 0, 1, 1], np.int8))
    ef, sel = index.planning_stats(key, 0.4, prefilter=True)
    assert ef == pytest.approx(5 / 7)             # und / (n - n0)
    assert sel == pytest.approx((2 + 5 * 0.4) / 7)
    ef, sel = index.planning_stats(key, 0.4, prefilter=False)
    assert ef == pytest.approx(5 / 10)
    assert sel == pytest.approx((2 + 5 * 0.4) / 10)
    # unknown key: untouched estimates AND no column side-effect
    ef, sel = index.planning_stats(("nope", ()), 0.4)
    assert (ef, sel) == (1.0, 0.4)
    assert ("nope", ()) not in set(index.decided.keys())


def test_decomposed_cost_scaled():
    dec = DecomposedCost(infer_s=2.0, rep_s={8: 0.5, 16: 1.5})
    half = dec.scaled(0.5)
    assert half.infer_s == pytest.approx(1.0)
    assert half.rep_s == {8: pytest.approx(0.25), 16: pytest.approx(0.75)}
    assert half.levels == dec.levels              # marginal pricing intact
    assert half.total_s == pytest.approx(dec.total_s * 0.5)


# ---------------------------------------------------------- joins --------
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**9))
def test_temporal_hash_join_matches_nested_loop(seed):
    rng = np.random.default_rng(seed)
    nl, nr = int(rng.integers(0, 12)), int(rng.integers(0, 12))
    tl = rng.uniform(0, 40, 16)
    tr = rng.uniform(0, 40, 16)
    ids_l = rng.choice(16, nl, replace=False).astype(np.int64)
    ids_r = rng.choice(16, nr, replace=False).astype(np.int64)
    delta = float(rng.uniform(0.1, 6.0))
    got = temporal_hash_join(ids_l, tl, ids_r, tr, delta)
    ref = naive_join_pairs((ids_l, tl), (ids_r, tr), delta)
    assert np.array_equal(got, ref)


@pytest.fixture(scope="module")
def join_setup(setup):
    images, metadata, cascades, masks = setup
    n = len(images)
    images_b = _uint8_images(n, 32, seed=99)
    meta_a = {"t": np.arange(n, dtype=np.int64) * 4}
    meta_b = {"t": np.arange(n, dtype=np.int64) * 4 + 1}
    fn_cache: dict = {}
    masks_b = {}
    for c, casc in cascades.items():
        rows = naive_scan(images_b, [casc], chunk=64, _fn_cache=fn_cache)
        m = np.zeros(n, bool)
        m[rows] = True
        masks_b[c] = m
    return (images, meta_a, masks), (images_b, meta_b, masks_b), cascades


def test_join_pushdown_bit_identical_to_naive(setup, join_setup):
    (im_a, meta_a, masks_a), (im_b, meta_b, masks_b), cascades = join_setup
    tree = Join(Pred("a"), Or(Pred("b"), Not(Pred("c"))), delta_t=3)
    rows_l = np.where(_mask_eval(tree.left, masks_a, len(im_a)))[0]
    rows_r = np.where(_mask_eval(tree.right, masks_b, len(im_b)))[0]
    ref = naive_join_pairs((rows_l, meta_a["t"]), (rows_r, meta_b["t"]), 3)
    assert len(ref)                               # non-degenerate case
    for optimize in (True, False):
        eng_a = ScanEngine(im_a, meta_a, chunk=64)
        eng_b = ScanEngine(im_b, meta_b, chunk=64)
        plan = plan_from_cascades(tree, cascades,
                                  metadata=(meta_a, meta_b),
                                  optimize=optimize)
        res = execute_join((eng_a, eng_b), plan)
        assert np.array_equal(res.pairs, ref)
        if optimize:     # the window pushdown actually pruned probes
            assert plan.window_kept is not None
            assert plan.window_kept < len(im_b)
            assert "JOIN" in plan.explain()
    # pushdown is exact even when the build side comes up EMPTY
    empty = Join(And(Pred("a"), Not(Pred("a"))), Pred("b"), delta_t=3)
    eng_a = ScanEngine(im_a, meta_a, chunk=64)
    eng_b = ScanEngine(im_b, meta_b, chunk=64)
    plan = plan_from_cascades(empty, cascades, metadata=(meta_a, meta_b))
    res = execute_join((eng_a, eng_b), plan)
    assert res.pairs.shape == (0, 2)


def test_join_build_side_is_the_cheap_side(join_setup):
    (_, meta_a, _), (_, meta_b, _), cascades = join_setup
    # left = expensive AND-of-everything, right = single cheap pred
    tree = Join(And(Pred("b"), Pred("c")), Pred("a"), delta_t=2)
    plan = plan_from_cascades(tree, cascades, metadata=(meta_a, meta_b))
    assert plan.build_side == 1
    unopt = plan_from_cascades(tree, cascades, metadata=(meta_a, meta_b),
                               optimize=False)
    assert unopt.build_side == 0                  # baseline keeps order


def test_two_camera_generator_contract():
    from repro.data.synthetic import DEFAULT_PREDICATES
    specs = DEFAULT_PREDICATES[:2]
    (xa, la, ta), (xb, lb, tb) = make_two_camera_corpus(
        specs, 48, hw=16, seed=3, corr=0.7, dt_max=2)
    assert xa.shape == (48, 16, 16, 3) and xb.shape == (48, 16, 16, 3)
    assert la.shape == (48, 2) and lb.shape == (48, 2)
    assert np.all(np.diff(ta) >= 0) and np.all(np.diff(tb) >= 0)
    # frames are dyadic-quantized (bit-exact pyramids, DESIGN.md §3.1)
    assert np.array_equal(xa, np.floor(xa * 256.0) / 256.0)
    # the correlation is visible: a solid majority of B rows have an A
    # partner within the window carrying the IDENTICAL label vector
    partnered = sum(
        any(abs(int(tb[j]) - int(ta[i])) <= 2 and
            np.array_equal(lb[j], la[i]) for i in range(48))
        for j in range(48))
    assert partnered >= int(0.5 * 48)


# --------------------------------------------------------- explain -------
def test_explain_renders_annotated_tree(setup):
    images, metadata, cascades, _ = setup
    tree = And(Pred("a"), Or(Pred("b"), Not(Pred("c"))))
    eng = ScanEngine(images, metadata, chunk=64)
    plan = plan_from_cascades(tree, cascades, metadata=metadata,
                              metadata_eq={"cam": 0})
    txt = plan.explain(n_rows=len(images))
    assert "ALGEBRA PLAN" in txt and "AND" in txt and "OR" in txt
    assert "NOT contains(c)" in txt
    assert "sel=" in txt and "cost/row" in txt and "└─" in txt
    execute_tree(eng, plan)
    after = plan.explain(n_rows=len(images))
    assert "actual" in after                      # actuals filled in
