"""System-level invariant (paper §V-E): enlarging the model pool can only
improve (never worsen) the attainable accuracy/throughput frontier —
adding models adds cascades and the Pareto frontier is monotone under
union."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.alc import alc
from repro.core.cascade import evaluate_cascades
from repro.core.costs import CostProfile
from repro.core.thresholds import compute_thresholds_batch
from repro.core.transforms import Representation


def _bank(seed, n_models, n_img=50):
    rng = np.random.default_rng(seed)
    truth = rng.integers(0, 2, n_img)
    scores = np.clip(truth[None] * rng.uniform(0.3, 0.7, (n_models, 1))
                     + rng.normal(0.25, 0.2, (n_models, n_img)), 0, 1)
    p_low, p_high = compute_thresholds_batch(scores, truth, [0.9])
    reps = [Representation(8 * (1 + i % 3), ["rgb", "gray", "r"][i % 3])
            for i in range(n_models)]
    infer = rng.uniform(1e-5, 5e-3, n_models)
    infer[-1] = 0.05
    prof = CostProfile.modeled({}, list(set(reps)), 32)
    return scores, truth, p_low, p_high, reps, infer, prof


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["INFER_ONLY", "CAMERA", "ONGOING"]))
def test_bigger_pool_never_worse(seed, scenario):
    scores, truth, p_low, p_high, reps, infer, prof = _bank(seed, 6)
    # subset pool = models {0,1,trusted}; full pool = all 6
    keep = [0, 1, 5]
    small = evaluate_cascades(scores[keep], truth, p_low[keep],
                              p_high[keep], [reps[i] for i in keep],
                              infer[keep], prof, scenario, trusted=2)
    full = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                             prof, scenario, trusted=5)
    lo, hi = small.acc.min(), small.acc.max()
    if hi <= lo:
        return
    a_small = alc(small.acc, small.throughput, lo, hi)
    a_full = alc(full.acc, full.throughput, lo, hi)
    assert a_full >= a_small - 1e-9
    assert full.acc.max() >= small.acc.max() - 1e-12
