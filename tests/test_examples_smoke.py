"""Examples must keep running end-to-end: each script is executed as a
subprocess at --tiny scale so drift between the library and the examples
can't rot silently. (The heavier full-scale runs stay manual.)"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def _run_example(name: str, args: list[str], timeout: int = 540):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / name), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, \
        f"{name} failed:\n{r.stdout[-2000:]}\n{r.stderr[-3000:]}"
    return r.stdout


def test_quickstart_smoke():
    out = _run_example("quickstart.py", ["--tiny"])
    assert "Pareto frontier" in out
    assert "matches" in out


def test_query_engine_smoke():
    out = _run_example("query_engine.py", ["--tiny", "--adaptive"])
    assert "PHYSICAL PLAN" in out
    assert "[joint, engine costing]" in out       # joint is the default
    assert "shared-representation savings" in out
    assert "identical rows: True" in out
    assert "reused from virtual columns" in out
    assert "adaptive:" in out


def test_query_algebra_smoke():
    out = _run_example("query_algebra.py", ["--tiny"])
    assert "ALGEBRA PLAN" in out
    assert "NOT contains(" in out
    assert "identical rows across all three: True" in out
    assert "actual" in out                        # est-vs-actual EXPLAIN
    assert "JOIN" in out and "build side=" in out
    assert "identical pairs (pushdown, baseline, nested loop): True" in out


@pytest.mark.slow
def test_serve_cascade_async_smoke():
    """Default path: the shard-aware AsyncCascadeService (DESIGN §10)."""
    out = _run_example("serve_cascade.py", ["--tiny", "--shards", "2"])
    assert "serving mode: async" in out
    assert "2 shard queues" in out
    assert "served 48 mixed requests" in out
    assert "store hit rate" in out and "repcache hit rate" in out
    assert "latency p50" in out


@pytest.mark.slow
def test_serve_cascade_sync_fallback_smoke():
    out = _run_example("serve_cascade.py", ["--tiny", "--sync"])
    assert "serving mode: sync" in out
    assert "served 48 mixed requests" in out
    assert "latency p50" in out
