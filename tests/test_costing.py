"""launch/costing.py — the roofline accounting itself (scan-aware jaxpr
FLOPs, HLO collective parsing with trip-count correction, analytic
memory model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import costing


# ------------------------------------------------------------ jaxpr flops --
def test_dot_flops_exact():
    f = lambda a, b: a @ b
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                           jax.ShapeDtypeStruct((32, 16), jnp.float32))
    assert costing.jaxpr_flops(jx) == 2 * 64 * 32 * 16


def test_scan_flops_multiplied():
    def f(h, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), h, ws)[0]
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                           jax.ShapeDtypeStruct((7, 32, 32), jnp.float32))
    expect = 7 * (2 * 32 ** 3 + 32 * 32)  # matmul + tanh per step
    assert costing.jaxpr_flops(jx) == expect


def test_grad_flops_counts_backward():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w))
    g = jax.grad(loss)
    jx = jax.make_jaxpr(g)(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                           jax.ShapeDtypeStruct((8, 32), jnp.float32))
    fwd = 2 * 8 * 32 * 32
    # bwd: dw = x^T @ dy (same flops); elementwise terms on top
    assert costing.jaxpr_flops(jx) >= 2 * fwd


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                           jax.ShapeDtypeStruct((4, 16, 32), jnp.float32))
    assert costing.jaxpr_flops(jx) == 2 * 4 * 8 * 16 * 32


def test_remat_recompute_counted():
    def f(w, x):
        g = jax.checkpoint(lambda xx: jnp.tanh(xx @ w))
        return jnp.sum(g(g(x)))
    base = jax.make_jaxpr(jax.grad(f, argnums=1))(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((8, 32), jnp.float32))
    flops = costing.jaxpr_flops(base)
    # 2 fwd + 2 recompute + 2 bwd dots minimum
    assert flops >= 6 * 2 * 8 * 32 * 32


# ------------------------------------------------- collective text parse ---
SYN_HLO = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%cond (p: (s32[], f32[4,8])) -> pred[] {
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %x = f32[4,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[4,8]{1,0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[4,8]) tuple(%iv2, %ar)
}

ENTRY %main (a: f32[4,8]) -> f32[4,8] {
  %ag = bf16[16,8]{1,0} all-gather(%a2), dimensions={0}
  %w = (s32[], f32[4,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[4,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_trip_corrected():
    res = costing.parse_collectives(SYN_HLO)
    by = res["bytes_by_type"]
    assert by["all-gather"] == 16 * 8 * 2          # bf16, outside loops
    assert by["all-reduce"] == 5 * 4 * 8 * 4       # f32, x5 trip count
    assert res["count_by_type"]["all-reduce"] == 5


def test_parse_collectives_empty():
    assert costing.parse_collectives("ENTRY %m () -> f32[] {\n}\n")[
        "total_bytes"] == 0


# --------------------------------------------------------- memory model ----
def _shape(kind, **kw):
    from repro.configs.base import ShapeConfig
    base = dict(name="t", kind=kind, seq_len=4096, global_batch=8)
    base.update(kw)
    return ShapeConfig(**base)


def test_analytic_bytes_train_scaling():
    from repro.configs.registry import get_arch
    arch = get_arch("deepseek-7b").replace(head_pad_to=16)
    n = 7_000_000_000
    m1 = costing.analytic_bytes("train", arch, _shape("train"), n, 1, 0,
                                256)
    m16 = costing.analytic_bytes("train", arch,
                                 _shape("train"), n, 16, 0, 256)
    # weight streams scale with microbatch count; optimizer traffic not
    assert m16.breakdown["weights"] == 16 * m1.breakdown["weights"]
    assert m16.breakdown["optimizer"] == m1.breakdown["optimizer"]


def test_analytic_bytes_decode_cache_dominates():
    from repro.configs.registry import get_arch
    arch = get_arch("qwen2.5-32b").replace(head_pad_to=16)
    cache = 1.1e12
    m = costing.analytic_bytes("decode", arch,
                               _shape("decode", seq_len=32768,
                                      global_batch=128),
                               33.4e9, 1, cache, 256)
    assert m.breakdown["cache_read"] == cache
    assert m.breakdown["cache_read"] > m.breakdown["weights"]


def test_prefill_last_only_cuts_logit_bytes():
    from repro.configs.registry import get_arch
    arch = get_arch("qwen2.5-32b").replace(head_pad_to=16)
    full = costing.analytic_bytes(
        "prefill", arch, _shape("prefill", seq_len=32768, global_batch=32),
        33.4e9, 1, 0, 256)
    last = costing.analytic_bytes(
        "prefill", arch,
        _shape("prefill", seq_len=32768, global_batch=32,
               prefill_last_only=True), 33.4e9, 1, 0, 256)
    assert last.breakdown["logits"] * 1000 < full.breakdown["logits"]


def test_chunked_attention_removes_score_traffic():
    from repro.configs.registry import get_arch
    arch = get_arch("deepseek-v2-236b").replace(head_pad_to=16)
    dense = costing.analytic_bytes("train", arch,
                                   _shape("train", global_batch=256),
                                   239e9, 16, 0, 256)
    chunked = costing.analytic_bytes(
        "train", arch,
        _shape("train", global_batch=256, train_attn_chunk=1024),
        239e9, 16, 0, 256)
    assert chunked.breakdown["activations"] \
        < 0.5 * dense.breakdown["activations"]
