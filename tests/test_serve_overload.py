"""Overload-hardened serving (DESIGN.md §12): the wall-clock event host
driven by an injected fake timer + ManualClock (deadlines fire without
caller cooperation, zero wall sleeps), admission control with typed
``Shed`` results and queue/in-flight gauges, the Pareto degradation
ladder (step-down under pressure, step-up on recovery, degraded labels
committed under their own cascade key), fault injection + recovery
(transient compute errors, dispatch-time device failure with re-route,
dead devices converted by the per-batch timeout into retry/TimedOut
instead of a hang — including through ``drain()``), per-request
deadline expiry, and the DeadlineWheel stale-entry compaction bound."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.selector import degradation_ladder
from repro.serve import (AsyncCascadeService, DegradeConfig, EventHost,
                         FakeTimer, FaultInjector, FaultPlan, ManualClock,
                         Request, Shed, TimedOut, is_label)
from repro.serve.scheduler import DeadlineWheel
from test_query_engine import _toy_cascade, _uint8_images
from test_serve_async import _reference_labels


@pytest.fixture(scope="module")
def corpus():
    imgs = _uint8_images(180, 32, seed=6)
    cascades = {"a": _toy_cascade("a", 1)}
    return imgs, cascades


def _svc(imgs, cascades, **kw):
    clk = ManualClock()
    kw.setdefault("shards", 1)
    kw.setdefault("batch_size", 8)
    kw.setdefault("max_wait_s", 0.010)
    kw.setdefault("jit", False)
    svc = AsyncCascadeService(imgs, cascades, clock=clk, **kw)
    return clk, svc


def _cheap_rung(concept="a", seed=21):
    """A strictly cheaper physical cascade (distinct cascade id) to
    serve as the concept's degradation rung."""
    casc = _toy_cascade(concept, seed, [(None, None), (None, None),
                                        (None, None)])
    # single-level: only the coarse model runs — the degraded shape
    casc.reps = casc.reps[:1]
    casc.model_fns = casc.model_fns[:1]
    casc.thresholds = [(None, None)]
    casc.cascade_id = ("toy-cheap", seed)
    return casc


# ================================================= wheel compaction =======
def test_deadline_wheel_compaction_bounds_stale_entries():
    """Cancel-heavy load (every size flush cancels) must not accumulate
    stale tuples in future slots: eager compaction keeps total slot
    storage O(live) under unbounded schedule/cancel churn."""
    w = DeadlineWheel(granularity=0.001)
    for i in range(10_000):
        # far-future deadlines: the lazy sweep never reaches the slots
        w.schedule("k", 1e6 + i)
        w.cancel("k")
        assert w.stored_entries <= DeadlineWheel.COMPACT_MIN + \
            DeadlineWheel.COMPACT_FACTOR * max(1, len(w)) + 1
    assert len(w) == 0 and w.compactions > 0
    # correctness survives compaction: live entries still fire exactly
    w.schedule("x", 2.0)
    w.schedule("y", 1.0)
    for i in range(1_000):
        w.schedule(f"churn{i % 3}", 1e6 + i)
        w.cancel(f"churn{i % 3}")
    assert w.pop_due(1.5) == ["y"]
    assert w.pop_due(2.5) == ["x"]
    assert w.next_deadline() is None or w.next_deadline() >= 1e6


def test_deadline_wheel_compaction_preserves_reschedule_semantics():
    w = DeadlineWheel(granularity=0.001)
    for i in range(2_000):
        w.schedule("k", 1e6 - i)                  # re-schedule churn
    assert len(w) == 1
    assert w.stored_entries <= DeadlineWheel.COMPACT_MIN + \
        DeadlineWheel.COMPACT_FACTOR + 1
    assert w.pop_due(1e6) == ["k"]                # latest wins


# ======================================================= event host =======
def test_host_fires_deadline_without_caller_cooperation(corpus):
    """The tentpole hole: with poll() never called by the client, the
    host's own step (timer-driven in production) fires the due flush
    and delivers — a stalled client can no longer rot deadlines."""
    imgs, cascades = corpus
    clk, svc = _svc(imgs, cascades, batch_size=16)
    host = EventHost(svc, timer=FakeTimer(), clock=clk)
    reqs = [Request(i, i) for i in range(3)]
    for r in reqs:
        host.submit("a", r)                       # NO poll from the client
    assert host.timer.wakes == 3                  # submits re-arm the timer
    sleep = host.step()                           # t=0: nothing due yet
    assert sleep == pytest.approx(0.010)          # sleeps UNTIL the deadline
    assert all(r.result is None for r in reqs)
    clk.advance(0.011)
    assert host.step() is None                    # fired, delivered, idle
    assert all(r.result in (0, 1) for r in reqs)
    assert svc.stats["a"].deadline_flushes == 1
    assert host.wait_idle(0) is True


def test_host_sleep_tracks_earliest_event(corpus):
    """step() returns exactly the gap to next_event_time(): flush
    deadlines and (when configured) request deadlines both count."""
    imgs, cascades = corpus
    clk, svc = _svc(imgs, cascades, batch_size=16, max_wait_s=0.020,
                    request_deadline_s=0.050)
    host = EventHost(svc, timer=FakeTimer(), clock=clk)
    host.submit("a", Request(0, 0))
    assert host.step() == pytest.approx(0.020)    # flush deadline first
    clk.advance(0.005)
    assert host.step() == pytest.approx(0.015)    # re-armed, not reset
    assert host.step() is not None
    clk.advance(0.016)                            # past the flush deadline
    assert host.step() is None                    # flushed + delivered -> idle
    assert svc.stats["a"].deadline_flushes == 1


def test_host_threaded_loop_delivers_with_wall_timer(corpus):
    """Integration: a real daemon thread parked on the WallTimer serves
    a sub-batch submit end to end with nobody polling. The caller only
    blocks on the idle event (no sleeps)."""
    import time
    imgs, cascades = corpus
    svc = AsyncCascadeService(imgs, cascades, shards=1, batch_size=16,
                              max_wait_s=0.002, jit=False,
                              clock=time.perf_counter)
    reqs = [Request(i, i) for i in range(3)]
    with EventHost(svc) as host:
        for r in reqs:
            host.submit("a", r)
        assert host.wait_idle(10.0) is True
    assert all(r.result in (0, 1) for r in reqs)
    assert svc.stats["a"].deadline_flushes >= 1
    ref = _reference_labels(imgs, cascades, [("a", i) for i in range(3)])
    assert all(r.result == ref[("a", i)] for i, r in enumerate(reqs))


# ================================================= admission control ======
def test_queue_limit_sheds_with_typed_result(corpus):
    imgs, cascades = corpus
    clk, svc = _svc(imgs, cascades, batch_size=100, queue_limit=4)
    reqs = [Request(i, i) for i in range(10)]
    for r in reqs:
        svc.submit("a", r)
    kept, shed = reqs[:4], reqs[4:]
    assert all(r.result is None for r in kept)    # queued, bounded
    assert all(isinstance(r.result, Shed) for r in shed)
    assert all(not is_label(r.result) and not r.result for r in shed)
    assert shed[0].result.reason == "queue-full"
    st = svc.stats["a"]
    assert st.shed == 6 and st.requests == 10
    summ = svc.summary()
    assert summ["queue_depth"] == {"current": 4, "max": 4}
    assert summ["goodput_requests"] == 4
    svc.drain()                                   # the queued 4 still serve
    ref = _reference_labels(imgs, cascades, [("a", i) for i in range(4)])
    assert all(r.result == ref[("a", i)] for i, r in enumerate(kept))
    assert svc.summary()["queue_depth"]["current"] == 0


def test_degrade_policy_steps_ladder_on_admission_pressure(corpus):
    imgs, cascades = corpus
    cheap = _cheap_rung()
    clk, svc = _svc(imgs, cascades, batch_size=100, queue_limit=2,
                    overload="degrade", ladders={"a": [cheap]})
    for i in range(4):
        svc.submit("a", Request(i, i))
    st = svc.stats["a"]
    assert st.shed == 2 and st.degrade_steps == 1
    assert svc.active_level("a") == 1             # future flushes are cheap
    svc.drain()
    assert st.degraded_rows == 2                  # the queued 2 ran rung 1


# ============================================== degradation ladder ========
def test_ladder_degrades_under_depth_and_recovers(corpus):
    """Queue depth past high_depth steps the active cascade down one
    Pareto rung; calm flushes step back up. Degraded labels commit
    under the DEGRADED cascade's own key — the primary's virtual column
    is untouched — and are counted separately."""
    imgs, cascades = corpus
    cheap = _cheap_rung()
    clk, svc = _svc(imgs, cascades, batch_size=8,
                    ladders={"a": [cheap]},
                    degrade=DegradeConfig(high_depth=6, low_depth=1,
                                          recover_after=2))
    st = svc.stats["a"]
    first = [Request(i, i) for i in range(8)]     # size flush at depth 8
    for r in first:
        svc.submit("a", r)
    svc.drain()
    assert st.degrade_steps == 1 and svc.active_level("a") == 1
    assert st.degraded_rows == 8 and st.degraded_batches == 1
    rows = np.arange(8)
    assert (svc.store.column(cheap.key)[rows] >= 0).all()
    assert (svc.store.column(cascades["a"].key)[rows] == -1).all()
    cheap_ref = _reference_labels(imgs, {"a": cheap},
                                  [("a", i) for i in range(8)])
    assert all(r.result == cheap_ref[("a", i)]
               for i, r in enumerate(first))

    # recovery: two calm deadline flushes (depth 1 <= low_depth)
    for j, row in enumerate((100, 101)):
        svc.submit("a", Request(50 + j, row))
        clk.advance(0.011)
        svc.poll()
    assert svc.active_level("a") == 0 and st.recover_steps == 1

    # back at the primary: the degraded rung's column is no longer
    # consulted, so a degraded-decided row is re-evaluated by the
    # primary (and commits under the primary's key this time)
    again = Request(99, 0)
    svc.submit("a", again)
    svc.drain()
    ref = _reference_labels(imgs, cascades, [("a", 0)])
    assert again.result == ref[("a", 0)]
    assert int(svc.store.column(cascades["a"].key)[0]) >= 0


def test_degraded_store_hit_while_degraded(corpus):
    """While degraded, a rung-decided row re-asked answers from the
    rung's own virtual column with zero invocations."""
    imgs, cascades = corpus
    cheap = _cheap_rung()
    clk, svc = _svc(imgs, cascades, batch_size=8, ladders={"a": [cheap]},
                    degrade=DegradeConfig(high_depth=6, low_depth=0,
                                          recover_after=10**9))
    for i in range(8):
        svc.submit("a", Request(i, i))
    svc.drain()
    assert svc.active_level("a") == 1
    st = svc.stats["a"]
    batches = st.batches
    re_ask = Request(40, 3)
    svc.submit("a", re_ask)                       # decided under rung key
    assert re_ask.result in (0, 1)
    assert st.store_hits == 1 and st.batches == batches


def test_warmup_covers_ladder_rungs(corpus):
    imgs, cascades = corpus
    cheap = _cheap_rung()
    clk, svc = _svc(imgs, cascades, ladders={"a": [cheap]})
    n = svc.warmup(widths=[8])
    assert n > 0
    assert any(k[0] == cheap.key for k in svc._fns)
    assert any(k[0] == cascades["a"].key for k in svc._fns)


def test_degradation_ladder_selector():
    """Ladder = strictly cheaper Pareto points, nearest-cost-first,
    optional accuracy floor and rung cap; primary excluded."""
    space = SimpleNamespace(
        acc=np.array([0.95, 0.90, 0.80, 0.70, 0.60, 0.99]),
        throughput=np.array([10.0, 20.0, 40.0, 80.0, 160.0, 5.0]),
        time_s=np.array([0.10, 0.05, 0.025, 0.0125, 0.00625, 0.2]))
    primary = 0                                   # acc .95 @ .10s
    ladder = degradation_ladder(space, primary)
    assert [s.index for s in ladder] == [1, 2, 3, 4]   # nearest first
    assert all(space.time_s[s.index] < space.time_s[primary]
               for s in ladder)
    floored = degradation_ladder(space, primary, min_accuracy=0.75)
    assert [s.index for s in floored] == [1, 2]
    capped = degradation_ladder(space, primary, max_rungs=1)
    assert [s.index for s in capped] == [1]
    # the cheapest frontier point has nothing to degrade to
    assert degradation_ladder(space, 4) == []


# ================================================== fault injection =======
def test_transient_compute_error_is_retried(corpus):
    imgs, cascades = corpus
    plan = FaultPlan(transient_errors=1)
    clk, svc = _svc(imgs, cascades, faults=FaultInjector(plan))
    svc.faults.clock = svc.clock
    reqs = [Request(i, i) for i in range(8)]
    for r in reqs:
        svc.submit("a", r)                        # size flush -> dispatch
    svc.drain()
    ref = _reference_labels(imgs, cascades, [("a", i) for i in range(8)])
    assert all(r.result == ref[("a", i)] for i, r in enumerate(reqs))
    st = svc.stats["a"]
    assert st.retries == 1 and st.shed == 0 and st.timeouts == 0
    assert svc.summary()["faults_injected"]["transient_errors"] == 1
    assert svc.summary()["failed_devices"] == []  # transient != failed


def test_device_failure_reroutes_to_healthy_device(corpus):
    """A permanently dispatch-failing device is marked failed and every
    dispatch re-routes to a healthy device; labels stay exact."""
    imgs, cascades = corpus
    plan = FaultPlan(fail_dispatch={0: -1})       # device 0 always fails
    clk, svc = _svc(imgs, cascades, shards=2,
                    faults=FaultInjector(plan))
    svc.faults.clock = svc.clock
    assert len(svc._unique_devices) == 2
    reqs = [Request(i, i) for i in range(40)]
    for r in reqs:
        svc.submit("a", r)
    svc.drain()
    ref = _reference_labels(imgs, cascades, [("a", i) for i in range(40)])
    assert all(r.result == ref[("a", i)] for i, r in enumerate(reqs))
    assert svc.summary()["failed_devices"] == [0]
    assert svc.stats["a"].retries >= 1
    # every later dispatch skipped device 0 outright: exactly ONE
    # injected dispatch failure, not one per batch
    assert svc.summary()["faults_injected"]["dispatch_failures"] == 1


def test_dead_device_batch_timeout_retries_on_healthy(corpus):
    """A dead device (dispatch 'succeeds', labels never ready) is
    caught by the per-batch timeout on poll(): the batch re-dispatches
    to a healthy device and completes exactly — no hang, no sleep."""
    imgs, cascades = corpus
    plan = FaultPlan(dead_devices={0})
    clk, svc = _svc(imgs, cascades, shards=2, batch_timeout_s=0.050,
                    faults=FaultInjector(plan))
    svc.faults.clock = svc.clock
    # rows routed to shard 0 (the dead device's shard)
    rows0 = [r for r in range(len(imgs)) if svc.shard_of(r) == 0][:8]
    reqs = [Request(i, r) for i, r in enumerate(rows0)]
    for r in reqs:
        svc.submit("a", r)
    svc.poll()
    assert all(r.result is None for r in reqs)    # stalled in flight
    clk.advance(0.060)                            # past the batch timeout
    svc.poll()                                    # recover: re-route + run
    ref = _reference_labels(imgs, cascades, [("a", r) for r in rows0])
    assert all(req.result == ref[("a", r)]
               for req, r in zip(reqs, rows0))
    st = svc.stats["a"]
    assert st.retries == 1 and st.timeouts == 0
    assert svc.summary()["failed_devices"] == [0]


def test_drain_converts_never_ready_batch_to_timeout(corpus):
    """The satellite regression: drain() used to block unconditionally;
    with a per-batch timeout it recovers instead. With NO healthy
    device left, requests complete with a typed TimedOut — never a
    hang (the dead-device label proxy raises on any blocking read, so
    a regression here fails loudly)."""
    imgs, cascades = corpus
    plan = FaultPlan(dead_devices={0})
    clk, svc = _svc(imgs, cascades, shards=1, batch_timeout_s=0.050,
                    dispatch_retries=0, faults=FaultInjector(plan))
    svc.faults.clock = svc.clock
    reqs = [Request(i, i) for i in range(8)]
    for r in reqs:
        svc.submit("a", r)                        # size flush -> in flight
    assert len(svc._inflight) == 1
    clk.advance(0.060)
    svc.drain()                                   # would hang pre-§12
    assert all(isinstance(r.result, TimedOut) for r in reqs)
    assert reqs[0].result.reason == "batch-timeout"
    assert all(not is_label(r.result) for r in reqs)
    assert svc.stats["a"].timeouts == 8
    assert len(svc._inflight) == 0 and not svc.busy()
    # the device is failed: later submits shed typed instead of queueing
    # onto a dead end
    late = Request(99, 50)
    svc.submit("a", late)
    svc.drain()
    assert isinstance(late.result, Shed)
    assert late.result.reason == "no-healthy-device"


def test_request_deadline_expires_in_queue(corpus):
    imgs, cascades = corpus
    clk, svc = _svc(imgs, cascades, batch_size=100, max_wait_s=0.100,
                    request_deadline_s=0.010)
    old = [Request(i, i) for i in range(3)]
    for r in old:
        svc.submit("a", r)
    clk.advance(0.008)
    fresh = Request(10, 50)
    svc.submit("a", fresh)
    clk.advance(0.004)                            # old past 10ms, fresh not
    svc.poll()
    assert all(isinstance(r.result, TimedOut) for r in old)
    assert old[0].result.reason == "request-deadline"
    assert fresh.result is None                   # still queued
    assert svc.stats["a"].expired == 3
    assert svc.next_event_time() is not None      # fresh still tracked
    svc.drain()
    assert fresh.result in (0, 1)


def test_slow_device_delivers_late_but_exact(corpus):
    """A slowdown delays readiness (dispatch-ahead holds it in flight)
    without corrupting labels or tripping the timeout when the budget
    is generous."""
    imgs, cascades = corpus
    plan = FaultPlan(slow_devices={0: 0.030})
    clk, svc = _svc(imgs, cascades, batch_timeout_s=0.100,
                    faults=FaultInjector(plan))
    svc.faults.clock = svc.clock
    reqs = [Request(i, i) for i in range(8)]
    for r in reqs:
        svc.submit("a", r)
    svc.poll()
    assert all(r.result is None for r in reqs)    # not ready yet
    clk.advance(0.031)
    svc.poll()                                    # ready now: delivered
    ref = _reference_labels(imgs, cascades, [("a", i) for i in range(8)])
    assert all(r.result == ref[("a", i)] for i, r in enumerate(reqs))
    assert svc.stats["a"].retries == 0 and svc.stats["a"].timeouts == 0


# ==================================== sub-saturation exactness + gauges ===
def test_hardened_knobs_do_not_change_sub_saturation_labels(corpus):
    """With every hardening knob armed but never triggered, the service
    answers request-for-request identically to the unhardened default —
    the acceptance criterion's sub-saturation bit-identity, unit-scale."""
    imgs, cascades = corpus
    cheap = _cheap_rung()
    stream = [("a", int(r)) for r in
              np.random.default_rng(5).integers(0, len(imgs), 60)]

    def run(**kw):
        clk, svc = _svc(imgs, cascades, batch_size=8, **kw)
        reqs = []
        for i, (c, row) in enumerate(stream):
            r = Request(i, row)
            svc.submit(c, r)
            reqs.append(r)
            svc.poll()
        svc.drain()
        return [r.result for r in reqs], svc

    plain, _ = run()
    hard, svc = run(queue_limit=10**6, batch_timeout_s=1e9,
                    request_deadline_s=1e9, ladders={"a": [cheap]},
                    degrade=DegradeConfig(high_depth=10**6),
                    faults=FaultInjector(FaultPlan()))
    assert hard == plain
    summ = svc.summary()
    assert summ["shed"] == summ["expired"] == summ["timeouts"] == 0
    assert summ["degraded_rows"] == 0 and summ["retries"] == 0
    assert summ["active_levels"] == {"a": 0}
    assert summ["goodput_requests"] == len(stream)


def test_summary_percentiles_and_gauges(corpus):
    """Satellite: p50/p95/p99 latency percentiles (from the bounded
    latency windows) and queue-depth / in-flight gauges in summary()."""
    imgs, cascades = corpus
    clk, svc = _svc(imgs, cascades, batch_size=8)
    for i in range(20):
        svc.submit("a", Request(i, i))
        clk.advance(0.001)
    svc.drain()
    summ = svc.summary()
    lat = summ["latency_ms"]
    assert set(lat) == {"p50", "p95", "p99"}
    assert 0.0 <= lat["p50"] <= lat["p95"] <= lat["p99"]
    assert summ["queue_depth"]["current"] == 0
    assert summ["queue_depth"]["max"] >= 1
    assert summ["in_flight"]["current"] == 0
    assert summ["in_flight"]["max"] >= 1
    assert summ["goodput_requests"] == 20


def test_typed_results_are_falsy_and_comparable():
    assert not Shed() and not TimedOut()
    assert Shed("x") == Shed("x") and Shed("x") != Shed("y")
    assert not is_label(Shed()) and not is_label(TimedOut())
    assert not is_label(None)
    assert is_label(0) and is_label(1)
