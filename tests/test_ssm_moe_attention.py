"""Layer-level math: SSD vs naive recurrence, SSD decode vs chunked, MoE
capacity routing vs dense per-token loop, head-padding exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.models import attention as attn
from repro.models import ffn
from repro.models.ssm import ssd_chunked, ssd_decode


def _naive_ssd(x, dt, a, bm, cm):
    """Direct O(S) recurrence: state_{t} = state_{t-1} e^{dt_t a} +
    dt_t B_t x_t^T;  y_t = C_t . state_t."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        da = np.exp(dt[:, t] * a[None, :])                    # (b,h)
        upd = np.einsum("bh,bn,bhp->bhpn", dt[:, t], bm[:, t], x[:, t])
        state = state * da[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, cm[:, t])
    return ys, state


def test_ssd_chunked_matches_naive():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 64, 3, 8, 16
    x = rng.standard_normal((b, s, h, p)).astype(np.float32) * 0.5
    dt = rng.random((b, s, h)).astype(np.float32) * 0.1
    a = -rng.random(h).astype(np.float32)
    bm = rng.standard_normal((b, s, n)).astype(np.float32) * 0.3
    cm = rng.standard_normal((b, s, n)).astype(np.float32) * 0.3
    y, st = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                        jnp.asarray(bm), jnp.asarray(cm), chunk=16)
    y_ref, st_ref = _naive_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=1e-4,
                               rtol=1e-3)


def test_ssd_decode_continues_chunked():
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 32, 2, 8, 16
    x = rng.standard_normal((b, s + 1, h, p)).astype(np.float32) * 0.5
    dt = rng.random((b, s + 1, h)).astype(np.float32) * 0.1
    a = -rng.random(h).astype(np.float32)
    bm = rng.standard_normal((b, s + 1, n)).astype(np.float32) * 0.3
    cm = rng.standard_normal((b, s + 1, n)).astype(np.float32) * 0.3
    y_all, _ = ssd_chunked(*(jnp.asarray(v) for v in
                             (x, dt, a, bm, cm)), chunk=11 if False else 33)
    _, st = ssd_chunked(jnp.asarray(x[:, :s]), jnp.asarray(dt[:, :s]),
                        jnp.asarray(a), jnp.asarray(bm[:, :s]),
                        jnp.asarray(cm[:, :s]), chunk=8)
    y1, _ = ssd_decode(jnp.asarray(x[:, s:]), jnp.asarray(dt[:, s:]),
                       jnp.asarray(a), jnp.asarray(bm[:, s:]),
                       jnp.asarray(cm[:, s:]), st)
    np.testing.assert_allclose(np.asarray(y1)[:, 0],
                               np.asarray(y_all)[:, s], atol=1e-4,
                               rtol=1e-3)


# ----------------------------------------------------------------- MoE -----
def _dense_moe_ref(p, x, cfg):
    """Per-token loop over its top-k experts (no capacity limits)."""
    moe = cfg.moe
    b, s, d = x.shape
    xt = np.asarray(x).reshape(-1, d)
    logits = xt @ np.asarray(p["w_router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[: moe.top_k]
        w = probs[t][top] / probs[t][top].sum()
        for e, wi in zip(top, w):
            g = xt[t] @ np.asarray(p["w_gate_e"][e], np.float64)
            u = xt[t] @ np.asarray(p["w_up_e"][e], np.float64)
            h = (g / (1 + np.exp(-g))) * u
            out[t] += wi * (h @ np.asarray(p["w_down_e"][e], np.float64))
    if "shared" in p:
        from repro.models.ffn import apply_mlp
        out += np.asarray(apply_mlp(p["shared"], x, cfg)).reshape(-1, d)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("shared", [0, 2])
def test_moe_matches_dense_reference(shared):
    cfg = ArchConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                      num_shared_experts=shared, d_ff_shared=16,
                      capacity_factor=8.0))  # no dropping
    p = ffn.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    out, aux = ffn.apply_moe(p, x, cfg, n_groups=1)
    ref = _dense_moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-3)
    assert float(aux) >= 0.99  # balance loss >= 1 at perfect balance


def test_moe_capacity_drops_tokens():
    cfg = ArchConfig(
        name="t", family="moe", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab_size=64, head_dim=4, dtype="float32",
        moe=MoEConfig(num_experts=2, top_k=1, d_ff_expert=16,
                      capacity_factor=0.5))
    p = ffn.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8), jnp.float32)
    out, _ = ffn.apply_moe(p, x, cfg, n_groups=1)
    # with capacity 0.5 some tokens get zero expert output (dropped)
    norms = np.linalg.norm(np.asarray(out).reshape(16, 8), axis=-1)
    assert (norms < 1e-6).any()


# -------------------------------------------------------- head padding -----
def _embed_padded(p1, cfg1, cfg2):
    """Map unpadded GQA weights into the padded per-group layout."""
    lo1 = attn.layout_from_cfg(cfg1)
    lo2 = attn.layout_from_cfg(cfg2)
    dh = cfg1.head_dim
    p2 = jax.tree.map(jnp.zeros_like,
                      attn.init_gqa(jax.random.PRNGKey(9), cfg2))
    wq1 = p1["wq"].reshape(cfg1.d_model, lo1.n_q, dh)
    wq2 = np.zeros((cfg1.d_model, lo2.hp, dh), np.float32)
    g1 = lo1.n_q // lo1.n_kv
    for i in range(lo1.n_q):
        kv, j = divmod(i, g1)
        wq2[:, kv * lo2.gp + j] = np.asarray(wq1[:, i])
    wo1 = p1["wo"].reshape(lo1.n_q, dh, cfg1.d_model)
    wo2 = np.zeros((lo2.hp, dh, cfg1.d_model), np.float32)
    for i in range(lo1.n_q):
        kv, j = divmod(i, g1)
        wo2[kv * lo2.gp + j] = np.asarray(wo1[i])
    p2 = dict(p2)
    p2["wq"] = jnp.asarray(wq2.reshape(cfg1.d_model, lo2.hp * dh))
    p2["wo"] = jnp.asarray(wo2.reshape(lo2.hp * dh, cfg1.d_model))
    p2["wk"], p2["wv"] = p1["wk"], p1["wv"]
    return p2


def test_head_padding_exact():
    """Padded-TP attention == unpadded attention bit-for-bit-ish (the
    numerics-preservation claim in DESIGN.md §6)."""
    base = dict(name="t", family="dense", n_layers=1, d_model=24,
                n_heads=6, n_kv_heads=2, d_ff=32, vocab_size=64,
                head_dim=4, dtype="float32")
    cfg1 = ArchConfig(**base, head_pad_to=1)
    cfg2 = ArchConfig(**base, head_pad_to=4)   # 6 q heads -> gp 4 -> hp 8
    lo2 = attn.layout_from_cfg(cfg2)
    assert lo2.hp % 4 == 0 and lo2.hp > cfg2.n_heads
    p1 = attn.init_gqa(jax.random.PRNGKey(0), cfg1)
    p2 = _embed_padded(p1, cfg1, cfg2)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 24), jnp.float32)
    from repro.models.common import rope_for_heads
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    cos, sin = rope_for_heads(pos, 4, 1e4)
    def run(p, cfg):
        lo = attn.layout_from_cfg(cfg)
        q, k, v = attn.gqa_qkv(p, x, cfg, rope=(cos, sin, cos, sin))
        ctx = attn.sdpa(q, k, v, causal=True, gp=lo.gp)
        return attn.gqa_out(p, ctx, cfg)
    np.testing.assert_allclose(np.asarray(run(p1, cfg1)),
                               np.asarray(run(p2, cfg2)),
                               atol=1e-5, rtol=1e-5)


def test_head_layout_assignments():
    """The production (pad_to=16) layouts for every assigned arch."""
    cases = {(40, 8): (48, 8, 6), (24, 8): (32, 8, 4), (6, 6): (16, 16, 1),
             (48, 1): (48, 1, 48), (32, 32): (32, 32, 1),
             (128, 128): (128, 128, 1), (64, 8): (64, 8, 8)}
    for (h, kv), (hp, khp, gp) in cases.items():
        lo = attn.head_layout(h, kv, 16)
        assert (lo.hp, lo.khp, lo.gp) == (hp, khp, gp), (h, kv, lo)
        assert lo.hp % 16 == 0 or lo.hp == h
        # real q heads count preserved by the mask
        assert int(lo.q_mask.sum()) == h
