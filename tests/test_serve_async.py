"""Shard-aware async serving subsystem (DESIGN.md §10): deadline/flush
semantics under an injected fake clock (no wall-clock sleeps anywhere),
the differential oracle async service ≡ sync batcher ≡ ScanEngine over
the same request stream across shard counts, zero-invocation answers for
store-decided rows, the cross-query representation cache (unit + engine
hook + service wiring), the factored slab builder, stationary hash
routing, and the (concept, cascade-id) batcher keying regression."""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.pipeline import build_cascade_service, build_scan_engine
from repro.core.transforms import Representation
from repro.engine.scan import (CompiledCascade, ScanEngine,
                               VirtualColumnStore, naive_scan)
from repro.engine.sharded import SLAB_FLOOR, pad_rows, slab_width
from repro.serve import (AsyncCascadeService, CascadeService, DeadlineWheel,
                         ManualClock, RepresentationCache, Request)
from repro.sharding.policy import plan_shards, shard_route
from test_query_engine import _toy_cascade, _uint8_images


def _counting_cascade(concept, seed, counters, thresholds=None):
    """Toy cascade whose model invocations are observable (jit=False
    paths call the python fns once per dispatched batch)."""
    casc = _toy_cascade(concept, seed, thresholds)
    wrapped = []
    for li, fn in enumerate(casc.model_fns):
        def make(li, fn):
            def f(x):
                counters[concept][li] += 1
                return fn(x)
            return f
        wrapped.append(make(li, fn))
    casc.model_fns = wrapped
    return casc


@pytest.fixture(scope="module")
def corpus():
    imgs = _uint8_images(210, 32, seed=4)
    cascades = {
        "a": _toy_cascade("a", 1),
        "b": _toy_cascade("b", 2, [(0.25, 0.75), (0.3, 0.7),
                                   (None, None)]),
    }
    return imgs, cascades


def _stream(n, n_rows, seed=3, concepts=("a", "b")):
    """Mixed request stream with repeats: (concept, row) pairs."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n_rows, n)
    return [(concepts[i % len(concepts)], int(rows[i])) for i in range(n)]


def _reference_labels(imgs, cascades, stream):
    """Per-(concept, row) ground truth from the scan engine."""
    eng = ScanEngine(imgs, chunk=64)
    out = {}
    for c, casc in cascades.items():
        rows = np.unique([r for cc, r in stream if cc == c])
        eng.scan_rows([casc], rows)
        for r in rows:
            out[(c, int(r))] = int(eng.store.column(casc.key)[r])
    return out


# ======================================================== scheduler =======
def test_manual_clock():
    clk = ManualClock(5.0)
    assert clk() == 5.0
    assert clk.advance(0.25) == 5.25 and clk() == 5.25
    with pytest.raises(ValueError):
        clk.advance(-1.0)


def test_deadline_wheel_due_order_and_cancel():
    w = DeadlineWheel(granularity=0.01)
    w.schedule("x", 1.00)
    w.schedule("y", 0.50)
    w.schedule("z", 2.00)
    assert len(w) == 3 and w.next_deadline() == 0.50
    assert w.pop_due(0.49) == []
    assert w.pop_due(1.5) == ["y", "x"]          # deadline order
    w.cancel("z")
    assert w.pop_due(10.0) == [] and len(w) == 0
    assert w.next_deadline() is None


def test_deadline_wheel_reschedule_latest_wins():
    w = DeadlineWheel(granularity=0.01)
    w.schedule("k", 1.0)
    w.schedule("k", 3.0)                          # stale 1.0 entry dropped
    assert w.pop_due(2.0) == []
    assert w.pop_due(3.0) == ["k"]
    # sub-granularity deadlines within one slot stay exact
    w.schedule("a", 0.0101)
    w.schedule("b", 0.0199)
    assert w.pop_due(0.015) == ["a"]
    assert w.pop_due(0.02) == ["b"]
    with pytest.raises(ValueError):
        DeadlineWheel(granularity=0.0)


# ==================================================== slab builder ========
def test_slab_width_buckets_and_floor():
    assert slab_width(1, 64) == SLAB_FLOOR
    assert slab_width(16, 64) == 16
    assert slab_width(17, 64) == 32
    assert slab_width(33, 64) == 64
    assert slab_width(200, 64) == 64              # capped at chunk
    assert slab_width(3, 64, floor=4) == 4


def test_pad_rows_repeats_last_id():
    out = pad_rows(np.array([7, 9, 11]), 8)
    assert out.tolist() == [7, 9, 11, 11, 11, 11, 11, 11]
    assert pad_rows(np.array([5]), 1).tolist() == [5]


def test_sharded_engine_still_uses_factored_slab_builder(corpus):
    """The lockstep path routes through the module-level slab_width."""
    imgs, cascades = corpus
    from repro.engine.sharded import ShardedScanEngine
    eng = ShardedScanEngine(imgs, shards=2, chunk=64)
    assert eng._slab_width(3) == SLAB_FLOOR
    assert eng._slab_width(40) == 64
    ref = naive_scan(imgs, list(cascades.values()), chunk=64)
    assert np.array_equal(
        eng.execute(list(cascades.values())).indices, ref)


# ===================================================== hash routing =======
def test_shard_route_matches_hash_plan_and_is_stationary():
    ids = np.arange(500)
    for n in (1, 2, 8):
        route = shard_route(ids, n)
        plan = plan_shards(ids, n, strategy="hash")
        for s in range(n):
            assert np.array_equal(plan.shards[s], ids[route == s])
        assert np.array_equal(route, shard_route(ids, n))  # stationary
    assert shard_route(7, 4).shape == (1,)        # scalar row id works
    with pytest.raises(ValueError):
        shard_route(ids, 0)


# ============================================ representation cache ========
def test_repcache_lru_eviction_and_budget():
    lvl = np.ones((4, 4, 3), np.float32)          # 192 bytes
    cache = RepresentationCache(budget_bytes=lvl.nbytes * 3)
    for row in range(3):
        cache.put(row, 4, lvl * row)
    assert len(cache) == 3 and cache.evictions == 0
    cache.get(0, 4)                               # refresh row 0
    cache.put(3, 4, lvl * 3)                      # evicts LRU = row 1
    assert (0, 4) in cache and (1, 4) not in cache
    assert cache.evictions == 1
    assert cache.nbytes == lvl.nbytes * 3
    # an entry larger than the whole budget is refused, not thrashed
    cache.put(9, 64, np.zeros((64, 64, 3), np.float32))
    assert (9, 64) not in cache and len(cache) == 3


def test_repcache_entries_are_copies_and_exact():
    cache = RepresentationCache()
    block = np.arange(2 * 4 * 4 * 3, dtype=np.float32).reshape(2, 4, 4, 3)
    cache.put_rows([10, 11], 4, block)
    block[:] = -1.0                               # caller mutates its block
    got = cache.get(10, 4)
    assert got is not None and float(got[0, 0, 0]) == 0.0
    # overwrite replaces bytes accounting, not duplicates
    before = cache.nbytes
    cache.put(10, 4, np.zeros((4, 4, 3), np.float32))
    assert cache.nbytes == before


def test_repcache_lookup_rows_all_or_none_accounting():
    cache = RepresentationCache()
    lvl = np.zeros((4, 4, 3), np.float32)
    cache.put(0, 4, lvl)
    cache.put(1, 4, lvl)
    assert cache.lookup_rows([0, 1, 2], [4]) is None   # row 2 missing
    # a failed lookup serves nothing: ALL 3 probed entries are misses
    assert cache.misses == 3 and cache.hits == 0
    cache.put(2, 4, lvl)
    out = cache.lookup_rows([0, 1, 2], [4])
    assert out is not None and out[4].shape == (3, 4, 4, 3)
    assert cache.hits == 3
    assert 0.0 < cache.hit_rate < 1.0
    with pytest.raises(ValueError):
        RepresentationCache(budget_bytes=0)


def test_scan_engine_repcache_hook_bit_exact(corpus):
    """A repcache-backed engine skips pyramid materialization on warmed
    chunks and returns the identical row set; the cache is shared
    across engines (cross-query reuse)."""
    imgs, cascades = corpus
    cascades = list(cascades.values())
    ref = naive_scan(imgs, cascades, chunk=64)

    cache = RepresentationCache(64 << 20)
    eng1 = ScanEngine(imgs, chunk=64, repcache=cache)
    r1 = eng1.execute(cascades)
    assert np.array_equal(r1.indices, ref)
    assert r1.stats.rep_rows_cached == 0 and r1.stats.chunks > 0

    # a SECOND engine (fresh store: all labels recomputed) over the same
    # cache: every chunk's pooled levels come from the cache
    eng2 = ScanEngine(imgs, chunk=64, repcache=cache)
    r2 = eng2.execute(cascades)
    assert np.array_equal(r2.indices, ref)
    assert r2.stats.rep_rows_cached == r2.stats.rows_scanned
    assert r2.stats.chunks == 0                   # no pyramids built
    assert cache.hits > 0


# ============================= deadline/flush semantics (fake clock) ======
def _fake_clock_service(imgs, cascades, **kw):
    clk = ManualClock()
    svc = AsyncCascadeService(imgs, cascades, clock=clk, **kw)
    return clk, svc


def test_deadline_triggered_partial_flush(corpus):
    """Requests below batch_size sit in the queue until the oldest
    request's deadline passes, then flush as ONE bucketed partial
    batch; no flush happens a tick before the deadline."""
    imgs, cascades = corpus
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=16, max_wait_s=0.010)
    reqs = [Request(i, i) for i in range(3)]
    for r in reqs:
        svc.submit("a", r)
    st = svc.stats["a"]
    clk.advance(0.009)
    svc.poll()                                    # before deadline: no flush
    assert st.batches == 0 and all(r.result is None for r in reqs)
    clk.advance(0.002)                            # past arrival + 10ms
    svc.poll()
    assert st.batches == 1 and st.deadline_flushes == 1
    assert st.rows_evaluated == 3
    assert st.padded_slots == SLAB_FLOOR - 3      # bucketed, not batch_size
    svc.drain()
    assert all(r.result in (0, 1) for r in reqs)


def test_full_batch_flushes_without_deadline(corpus):
    """batch_size requests flush immediately on submit; the queue's
    deadline entry is cancelled (nothing left to fire)."""
    imgs, cascades = corpus
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=8, max_wait_s=0.010)
    for i in range(8):
        svc.submit("a", Request(i, i))
    st = svc.stats["a"]
    assert st.batches == 1 and st.size_flushes == 1
    assert len(svc.wheel) == 0
    clk.advance(1.0)
    svc.poll()                                    # nothing further to flush
    assert st.batches == 1 and st.deadline_flushes == 0


def test_leftover_requests_keep_their_deadline(corpus):
    """A size-flush of a long queue re-schedules the remaining head's
    ORIGINAL deadline (arrival + max_wait), not a fresh one."""
    imgs, cascades = corpus
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=4, max_wait_s=0.010)
    svc.submit("a", Request(0, 0))                # arrives at t=0
    clk.advance(0.004)
    for i in range(1, 6):                         # arrive at t=0.004
        svc.submit("a", Request(i, i))            # -> size flush of 0..3
    st = svc.stats["a"]
    assert st.size_flushes == 1
    assert svc.wheel.next_deadline() == pytest.approx(0.004 + 0.010)
    clk.advance(0.011)                            # t=0.015 > 0.014
    svc.poll()
    assert st.deadline_flushes == 1 and st.batches == 2


def test_in_order_delivery_per_queue(corpus):
    """Evaluated results are delivered in submission order per (shard,
    concept) queue, across multiple flushes and dispatch-ahead."""
    imgs, cascades = corpus
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=8, max_wait_s=0.010)
    # distinct rows: a repeated row could be answered from the store
    # mid-stream (immediate delivery is documented to overtake queues)
    rows = np.random.default_rng(0).permutation(len(imgs))[:30]
    for i, row in enumerate(rows):
        svc.submit("a", Request(i, int(row)))
    clk.advance(0.011)
    svc.poll()
    svc.drain()
    evaluated = [rid for rid in svc.delivered]
    assert evaluated == sorted(evaluated)         # FIFO delivery
    assert len(evaluated) == 30


def test_store_decided_rows_answered_with_zero_invocations(corpus):
    """Re-submitted decided rows answer from the shard-local virtual
    columns on submit: no queueing, no batch, no model invocation —
    observable through python-side call counters (jit=False)."""
    imgs, _ = corpus
    counters = {"a": [0, 0, 0]}
    cascades = {"a": _counting_cascade("a", 1, counters)}
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=8, max_wait_s=0.010,
                                   jit=False)
    first = [Request(i, i) for i in range(8)]
    for r in first:
        svc.submit("a", r)
    svc.drain()
    calls = [list(v) for v in counters.values()]
    assert counters["a"][0] > 0

    again = [Request(100 + i, i) for i in range(8)]
    for r in again:
        svc.submit("a", r)                        # answered on submit
    assert all(r.result == f.result for r, f in zip(again, first))
    assert [list(v) for v in counters.values()] == calls
    st = svc.stats["a"]
    assert st.store_hits == 8 and st.batches == 1
    svc.drain()                                   # nothing pending
    assert st.batches == 1


def test_store_sharing_with_scan_engine(corpus):
    """A service built over a scan engine's store serves every
    scan-decided row with zero invocations (ROADMAP: shard queue turns
    the store lookup into a local read)."""
    imgs, cascades = corpus
    eng = ScanEngine(imgs, chunk=64)
    eng.execute([cascades["a"]])                  # offline scan decides all
    clk, svc = _fake_clock_service(imgs, cascades, shards=8,
                                   batch_size=8, max_wait_s=0.010,
                                   store=eng.store)
    for i in range(32):
        svc.submit("a", Request(i, i * 3))
    st = svc.stats["a"]
    assert st.store_hits == 32 and st.batches == 0


def test_store_writes_after_construction_are_adopted(corpus):
    """The shard seed is a snapshot: a scan that runs AFTER the service
    is built still serves requests with zero invocations (submit falls
    back to the shared store and adopts the late write shard-locally)."""
    imgs, cascades = corpus
    eng = ScanEngine(imgs, chunk=64)
    clk, svc = _fake_clock_service(imgs, cascades, shards=4,
                                   batch_size=8, max_wait_s=0.010,
                                   store=eng.store)
    eng.execute([cascades["a"]])                  # scan AFTER construction
    for i in range(16):
        svc.submit("a", Request(i, i * 5))
    st = svc.stats["a"]
    assert st.store_hits == 16 and st.batches == 0
    # adopted into the shard's own columns: the corpus-wide fallback is
    # no longer needed for those rows
    for i in range(16):
        row = i * 5
        s = svc.shard_of(row)
        key = cascades["a"].key
        assert svc._shard_stores[s].column(key)[row] >= 0


def test_merge_rows_from_matches_merge_from_on_subset():
    """Row-restricted commit == full merge restricted to those rows;
    rows outside the subset are untouched."""
    rng = np.random.default_rng(3)
    n = 100
    rows = np.array([2, 5, 50, 99])
    key = ("c", (1,))
    a1 = VirtualColumnStore(n)
    a2 = VirtualColumnStore(n)
    src = VirtualColumnStore(n)
    vals = rng.integers(-1, 2, n)
    a1.column(key)[:] = vals
    a2.column(key)[:] = vals
    src.column(key)[:] = rng.integers(-1, 2, n)
    a1.merge_rows_from(src, rows)
    outside = np.setdiff1d(np.arange(n), rows)
    assert np.array_equal(a1.column(key)[outside], vals[outside])
    a2.merge_from(src)
    assert np.array_equal(a1.column(key)[rows], a2.column(key)[rows])


def test_service_repcache_from_pyramid_path_bit_exact(corpus):
    """Once rows' pooled levels are cached (here: warmed by concept a's
    flushes), a different concept's flush over the same rows runs the
    from-pyramid variant — fewer pooling passes, identical labels."""
    imgs, cascades = corpus
    cache = RepresentationCache(64 << 20)
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=8, max_wait_s=0.010,
                                   repcache=cache)
    rows = list(range(16))
    reqs_a = [Request(i, r) for i, r in enumerate(rows)]
    for r in reqs_a:
        svc.submit("a", r)
    svc.drain()                                   # warms (row, 8/16) levels
    assert svc.stats["a"].rep_hit_rows == 0

    reqs_b = [Request(100 + i, r) for i, r in enumerate(rows)]
    for r in reqs_b:
        svc.submit("b", r)
    svc.drain()
    assert svc.stats["b"].rep_hit_rows == len(rows)
    ref = _reference_labels(imgs, cascades,
                            [("b", r) for r in rows])
    assert all(req.result == ref[("b", r)]
               for req, r in zip(reqs_b, rows))


# ================================================ differential oracle =====
@pytest.mark.parametrize("shards", [1, 2, 8])
def test_async_sync_scan_differential(corpus, shards):
    """The acceptance oracle: AsyncCascadeService answers bit-identical
    labels to the synchronous CascadeService and to ScanEngine over the
    same mixed request stream, at every shard count."""
    imgs, cascades = corpus
    stream = _stream(120, len(imgs), seed=11)
    ref = _reference_labels(imgs, cascades, stream)

    # sync batcher (capacities=None -> full-width levels, exact)
    sync = CascadeService.from_cascades(cascades, batch_size=16,
                                        max_wait_s=1e9)
    sync_reqs = []
    for i, (c, row) in enumerate(stream):
        r = Request(i, jnp.asarray(imgs[row]))
        sync.submit(c, r)
        sync_reqs.append(r)
    sync.drain()

    svc = AsyncCascadeService(imgs, cascades, shards=shards,
                              batch_size=16, max_wait_s=0.002,
                              repcache=RepresentationCache())
    async_reqs = []
    for i, (c, row) in enumerate(stream):
        r = Request(i, row)
        svc.submit(c, r)
        async_reqs.append(r)
        svc.poll()
    svc.drain()

    for (c, row), sr, ar in zip(stream, sync_reqs, async_reqs):
        assert ar.result == ref[(c, row)] == int(sr.result), (c, row)

    # the whole stream again: every label is now committed, so the
    # second pass is answered entirely from the store
    before = svc.summary()
    second = [Request(1000 + i, row) for i, (_, row) in enumerate(stream)]
    for (c, _), r in zip(stream, second):
        svc.submit(c, r)
    after = svc.summary()
    assert all(r.result == ref[(c, row)]
               for (c, row), r in zip(stream, second))
    assert after["store_hits"] - before["store_hits"] == len(stream)
    assert after["rows_evaluated"] == before["rows_evaluated"]
    assert after["batches"] == before["batches"]


def test_shared_fn_cache_keyed_by_cascade_identity(corpus):
    """A shared fn_cache (the benchmark idiom) must never serve a
    retrained cascade's labels from a stale compile: keys carry the
    cascade's (concept, cascade-id), not the bare concept name."""
    imgs, _ = corpus
    v1 = {"a": _toy_cascade("a", 1)}
    v2 = {"a": _toy_cascade("a", 7)}               # same concept, new models
    v2["a"].cascade_id = ("toy", 7)
    shared: dict = {}
    rows = list(range(24))

    def serve(cascades):
        svc = AsyncCascadeService(imgs, cascades, shards=1,
                                  batch_size=8, max_wait_s=1e9,
                                  fn_cache=shared)
        reqs = [Request(i, r) for i, r in enumerate(rows)]
        for r in reqs:
            svc.submit("a", r)
        svc.drain()
        return [r.result for r in reqs]

    got1, got2 = serve(v1), serve(v2)
    ref1 = _reference_labels(imgs, v1, [("a", r) for r in rows])
    ref2 = _reference_labels(imgs, v2, [("a", r) for r in rows])
    assert got1 == [ref1[("a", r)] for r in rows]
    assert got2 == [ref2[("a", r)] for r in rows]
    assert got1 != got2                            # genuinely different models


def test_repcache_refuses_a_second_corpus(corpus):
    """One cache per corpus: (row, resolution) keys carry no corpus
    identity, so attaching a different corpus raises instead of
    serving another corpus's pixels."""
    imgs, cascades = corpus
    cache = RepresentationCache()
    ScanEngine(imgs, chunk=64, repcache=cache)
    # same pixel data in a different buffer is the SAME corpus
    AsyncCascadeService(imgs.copy(), cascades, shards=1,
                        repcache=cache)
    other = _uint8_images(64, 32, seed=99)
    with pytest.raises(ValueError):
        ScanEngine(other, chunk=64, repcache=cache)
    with pytest.raises(ValueError):
        AsyncCascadeService(other, cascades, shards=1, repcache=cache)


def test_service_observability_is_bounded(corpus):
    """Delivery log and latency windows are bounded deques — a
    resident service cannot grow per-request state forever."""
    imgs, cascades = corpus
    clk, svc = _fake_clock_service(imgs, cascades, shards=1,
                                   batch_size=8)
    assert svc.delivered.maxlen is not None
    for st in svc.stats.values():
        assert st.latencies.maxlen is not None


def test_factory_builds_both_modes(corpus):
    imgs, cascades = corpus
    svc = build_cascade_service(imgs, cascades, shards=2, batch_size=8)
    assert isinstance(svc, AsyncCascadeService)
    assert svc.repcache is not None
    sync = build_cascade_service(imgs, cascades, mode="sync",
                                 batch_size=8)
    assert isinstance(sync, CascadeService)
    with pytest.raises(ValueError):
        build_cascade_service(imgs, cascades, mode="threaded")
    # factory can share one repcache between scan engine and service
    cache = RepresentationCache()
    eng = build_scan_engine(imgs, repcache=cache)
    assert eng.repcache is cache
    svc2 = build_cascade_service(imgs, cascades, shards=1,
                                 repcache=cache)
    assert svc2.repcache is cache


# ==================================================== multidevice =========
@pytest.mark.multidevice
def test_shard_queues_spread_over_devices_dispatch_ahead(corpus):
    """With the conftest-forced 8 host devices, 8 shard queues sit on 8
    DISTINCT devices; a burst dispatches batches onto several devices
    before any delivery is forced (the dispatch-ahead window), and
    results stay exact."""
    imgs, cascades = corpus
    n = jax.device_count()
    svc = AsyncCascadeService(imgs, cascades, shards=n, batch_size=8,
                              max_wait_s=1e9)
    assert len(set(svc.devices)) == n
    # one full batch per shard, no poll in between: every dispatch parks
    # on its own device in flight
    rows_by_shard = {s: [] for s in range(n)}
    for row in range(len(imgs)):
        s = svc.shard_of(row)
        if len(rows_by_shard[s]) < 8:
            rows_by_shard[s].append(row)
    rid = 0
    reqs = []
    for s, rows in rows_by_shard.items():
        for row in rows:
            r = Request(rid, row)
            svc.submit("a", r)
            reqs.append((row, r))
            rid += 1
    assert len(svc._inflight) == n                # n batches in flight
    svc.drain()
    ref = _reference_labels(imgs, cascades,
                            [("a", row) for row, _ in reqs])
    assert all(r.result == ref[("a", row)] for row, r in reqs)


# ===================================== batcher keying regression ==========
def test_sync_service_keeps_concepts_separate_when_cascade_id_collides():
    """Two concepts whose cascades share a cascade id (the planner's
    grid coordinates repeat across concepts) must keep SEPARATE batch
    queues keyed (concept, cascade-id): each concept's requests run its
    own models and come back in its own arrival order."""
    hw = 8
    rep = Representation(hw, "gray")

    def runner(sign):
        def run(payloads):
            return [int(sign * float(np.asarray(p).mean()) > 0)
                    for p in payloads]
        return run

    shared_id = (0, 3, 1)                         # same grid coordinates
    service = CascadeService({"a": runner(+1), "b": runner(-1)},
                             batch_size=4, max_wait_s=1e9,
                             cascade_ids={"a": shared_id,
                                          "b": shared_id})
    assert set(service.batchers) == {("a", shared_id), ("b", shared_id)}
    assert set(service.concepts) == {"a", "b"}

    reqs = []
    for i in range(8):                            # interleaved a/b stream
        c = "a" if i % 2 == 0 else "b"
        r = Request(i, np.full((hw, hw, 1), 1.0))
        service.submit(c, r)
        reqs.append((c, r))
    service.drain()
    for c, r in reqs:                             # per-concept models ran
        assert int(r.result) == (1 if c == "a" else 0), (c, r.rid)
    stats = service.stats
    assert stats["a"].batches == 1 and stats["b"].batches == 1


def test_from_cascades_shares_runner_only_for_same_object(corpus):
    imgs, _ = corpus
    shared = _toy_cascade("x", 5)
    other = _toy_cascade("y", 6)
    other.cascade_id = shared.cascade_id          # id collision, new models
    svc = CascadeService.from_cascades(
        {"x": shared, "x2": shared, "y": other}, batch_size=4,
        max_wait_s=1e9)
    b = svc.batchers
    kx, kx2, ky = (("x", tuple(shared.cascade_id)),
                   ("x2", tuple(shared.cascade_id)),
                   ("y", tuple(other.cascade_id)))
    assert set(b) == {kx, kx2, ky}                # distinct queues
    assert b[kx].run_batch is b[kx2].run_batch    # shared compile
    assert b[kx].run_batch is not b[ky].run_batch  # different models
