"""Representation pyramid + streaming cascade-space evaluator (this PR's
two perf subsystems): progressive downsampling must be exactly the
from-base transform, the executor's rep-derivation must not change any
observable output, and the bounded-memory streaming evaluator must agree
with the dense evaluator (which itself is pinned to the naive per-image
walker in test_cascade.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import (KIND_SINGLE, cascade_time_naive,
                                evaluate_cascades,
                                evaluate_cascades_streaming,
                                simulate_cascade, spec_levels)
from repro.core.costs import CostProfile, rep_cost_s
from repro.core.executor import derivation_sources, run_cascade_batch
from repro.core.pareto import pareto_indices
from repro.core.thresholds import compute_thresholds_batch
from repro.core.transforms import (Representation, apply_transform,
                                   materialize_pyramid,
                                   materialize_representations,
                                   plan_pyramid, representation_space,
                                   resize_area)
from repro.kernels import ops


def _uint8_images(b, hw, seed=0):
    """Pixel values k/256: exactly-representable dyadics, so nested box
    filters are bit-exact (the real corpus regime — images come from
    uint8 sensors)."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, (b, hw, hw, 3))
                       .astype(np.float32) / 256.0)


# ---------------------------------------------------------------- pyramid --
def test_plan_pyramid_uses_nearest_source():
    steps = plan_pyramid([112, 56, 28], 224)
    assert [(s.resolution, s.source) for s in steps] == \
        [(112, 224), (56, 112), (28, 56)]
    # a hole in the ladder: 8 still nests under 32
    steps = plan_pyramid([32, 8], 64)
    assert [(s.resolution, s.source) for s in steps] == [(32, 64), (8, 32)]


def test_plan_pyramid_rejects_non_nesting():
    with pytest.raises(ValueError):
        plan_pyramid([120], 224)       # 224 % 120 != 0


def test_progressive_equals_from_base_exactly():
    img = _uint8_images(4, 32)
    pyr = materialize_pyramid(img, [16, 8, 4])
    for r in (16, 8, 4):
        direct = np.asarray(resize_area(img, r))
        assert (np.asarray(pyr[r]) == direct).all(), r


def test_progressive_close_on_arbitrary_floats():
    rng = np.random.default_rng(3)
    img = jnp.asarray(rng.random((2, 64, 64, 3), np.float32))
    pyr = materialize_pyramid(img, [32, 16, 8])
    for r in (32, 16, 8):
        np.testing.assert_allclose(np.asarray(pyr[r]),
                                   np.asarray(resize_area(img, r)),
                                   atol=1e-6)


def test_materialize_representations_matches_apply_transform():
    img = _uint8_images(3, 32, seed=1)
    reps = representation_space([8, 16, 32])
    cache = materialize_representations(img, reps)
    for rep in reps:
        expect = np.asarray(apply_transform(img, rep))
        assert (np.asarray(cache[rep]) == expect).all(), rep.name


def test_pyramid_kernel_matches_per_rep_reference():
    img = _uint8_images(3, 32, seed=2)
    specs = ((16, "rgb"), (16, "gray"), (8, "r"), (4, "gray"),
             (32, "rgb"))
    outs = ops.pyramid_transform_op(img, specs=specs)
    refs = ops.pyramid_transform_op(img, specs=specs, backend="ref")
    assert len(outs) == len(specs)
    for o, rf, (res, color) in zip(outs, refs, specs):
        assert o.shape == (3, res, res, 3 if color == "rgb" else 1)
        np.testing.assert_allclose(np.asarray(o), np.asarray(rf),
                                   atol=1e-5)


# ----------------------------------------------------- incremental pricing -
def test_incremental_transform_pricing():
    reps = [Representation(8, "gray"), Representation(16, "r"),
            Representation(32, "rgb")]
    prof = CostProfile.modeled({}, reps, base_hw=32)
    r8 = reps[0]
    from_base = rep_cost_s(prof, r8, "CAMERA", False)
    from_16 = rep_cost_s(prof, r8, "CAMERA", False, source_hw=16)
    assert from_16 < from_base            # smaller read
    # non-divisible / missing source falls back to from-base pricing
    assert rep_cost_s(prof, r8, "CAMERA", False, source_hw=12) == from_base
    assert rep_cost_s(prof, r8, "CAMERA", False, source_hw=None) == from_base
    # ONGOING loads pre-materialized reps; the source is irrelevant
    assert rep_cost_s(prof, r8, "ONGOING", False, source_hw=16) == \
        rep_cost_s(prof, r8, "ONGOING", False)
    # hand-built profile without bandwidth fields: no pyramid savings
    hand = CostProfile(infer_s={}, transform_s={r.name: 1e-3 for r in reps},
                       load_rep_s={r.name: 1e-4 for r in reps},
                       load_full_s=1e-2)
    assert rep_cost_s(hand, r8, "CAMERA", False, source_hw=16) == 1e-3


def _grid(seed, n_models=5, n_img=64, n_targets=2):
    rng = np.random.default_rng(seed)
    truth = rng.integers(0, 2, n_img)
    scores = np.clip(truth[None] * rng.uniform(0.3, 0.7, (n_models, 1))
                     + rng.normal(0.25, 0.2, (n_models, n_img)), 0, 1)
    p_low, p_high = compute_thresholds_batch(scores, truth,
                                             [0.9, 0.95][:n_targets])
    reps = [Representation(8 * (1 + i % 3), ["rgb", "gray", "r"][i % 3])
            for i in range(n_models)]
    reps[-1] = Representation(32, "rgb")
    infer = rng.uniform(1e-4, 5e-3, n_models)
    infer[-1] = 0.05
    profile = CostProfile.modeled({}, list(set(reps)), base_hw=32)
    return scores, truth, p_low, p_high, reps, infer, profile


def test_pyramid_pricing_shifts_frontier_down():
    """Incremental t_transform can only reduce expected cost, and strictly
    reduces it for some cascade whose later level nests under an earlier
    one (the paper-§VI frontier shift)."""
    scores, truth, p_low, p_high, reps, infer, profile = _grid(0)
    sp_pyr = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                               profile, "CAMERA", trusted=len(reps) - 1)
    sp_base = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                                profile, "CAMERA", trusted=len(reps) - 1,
                                pyramid=False)
    assert np.allclose(sp_pyr.acc, sp_base.acc)
    assert np.all(sp_pyr.time_s <= sp_base.time_s + 1e-15)
    assert np.any(sp_pyr.time_s < sp_base.time_s - 1e-12)


@pytest.mark.parametrize("scenario",
                         ["INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA"])
def test_pyramid_pricing_matches_naive_walker(scenario):
    scores, truth, p_low, p_high, reps, infer, profile = _grid(1)
    sp = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                           profile, scenario, trusted=len(reps) - 1)
    rng = np.random.default_rng(11)
    for i in rng.choice(len(sp), size=60, replace=False):
        levels = spec_levels(sp, int(i), p_low, p_high)
        acc, _ = simulate_cascade(levels, scores, truth)
        t = cascade_time_naive(levels, scores, reps, infer, profile,
                               scenario)
        assert sp.acc[i] == pytest.approx(acc, abs=1e-5)
        assert sp.time_s[i] == pytest.approx(t, rel=1e-5)


# ----------------------------------------------------------- executor ------
def test_derivation_sources_match_cost_model_policy():
    # ascending (cheap->expensive): each level from base or an earlier
    # nesting level; descending: each from the previous
    assert derivation_sources([8, 16, 32], 32) == [32, 32, 32]
    assert derivation_sources([32, 16, 8], 32) == [32, 32, 16]
    assert derivation_sources([16, 8, 8], 32) == [32, 16, 8]
    # the paper's 3-level shape: mid level derives from level 1, trusted
    # from base — exactly what _cost_matrices prices (56 -> 28 nests)
    assert derivation_sources([56, 28, 224], 224) == [224, 56, 224]


def _executor_setup(seed=4):
    rng = np.random.default_rng(seed)
    imgs = _uint8_images(48, 32, seed=seed)
    reps = [Representation(8, "gray"), Representation(16, "r"),
            Representation(32, "rgb")]
    ws = [jnp.asarray(rng.standard_normal((8 * 8, 1)).astype(np.float32))
          * 0.5,
          jnp.asarray(rng.standard_normal((16 * 16, 1)).astype(np.float32))
          * 0.5,
          jnp.asarray(rng.standard_normal((32 * 32 * 3, 1))
                      .astype(np.float32)) * 0.1]

    def mk(i):
        def f(x):
            return jnp.clip(x.reshape(x.shape[0], -1) @ ws[i], 0, 1)[:, 0]
        return f
    fns = [mk(0), mk(1), mk(2)]
    ths = [(0.3, 0.7), (0.35, 0.65), (None, None)]
    return imgs, reps, fns, ths


def test_executor_rep_derivation_identical_to_seed_path():
    """Pyramid derivation (gather small source rows, derive from the
    previous level's tensor) must reproduce the seed executor's labels
    and stats bit-for-bit."""
    imgs, reps, fns, ths = _executor_setup()
    legacy = [lambda x, r=r: apply_transform(x, r) for r in reps]
    l1, s1 = run_cascade_batch(imgs, fns, ths, legacy, capacities=[24, 12])
    l2, s2 = run_cascade_batch(imgs, fns, ths, reps, capacities=[24, 12])
    assert (np.asarray(l1) == np.asarray(l2)).all()
    assert int(s1["overflow"]) == int(s2["overflow"])
    assert (np.asarray(s1["levels_used"])
            == np.asarray(s2["levels_used"])).all()


def test_executor_rep_derivation_with_overflow():
    imgs, reps, fns, ths = _executor_setup(seed=5)
    legacy = [lambda x, r=r: apply_transform(x, r) for r in reps]
    l1, s1 = run_cascade_batch(imgs, fns, ths, legacy, capacities=[8, 8])
    l2, s2 = run_cascade_batch(imgs, fns, ths, reps, capacities=[8, 8])
    assert (np.asarray(l1) == np.asarray(l2)).all()
    assert int(s1["overflow"]) == int(s2["overflow"])


def test_executor_descending_then_trusted_shape():
    """The paper's 3-level shape (mid level nests under level 1, trusted
    at base res): derivation must still match the legacy path."""
    rng = np.random.default_rng(6)
    imgs = _uint8_images(32, 32, seed=6)
    reps = [Representation(16, "gray"), Representation(8, "gray"),
            Representation(32, "rgb")]
    ws = [jnp.asarray(rng.standard_normal((16 * 16, 1))
                      .astype(np.float32)) * 0.5,
          jnp.asarray(rng.standard_normal((8 * 8, 1))
                      .astype(np.float32)) * 0.5,
          jnp.asarray(rng.standard_normal((32 * 32 * 3, 1))
                      .astype(np.float32)) * 0.1]

    def mk(i):
        def f(x):
            return jnp.clip(x.reshape(x.shape[0], -1) @ ws[i], 0, 1)[:, 0]
        return f
    fns = [mk(0), mk(1), mk(2)]
    ths = [(0.3, 0.7), (0.35, 0.65), (None, None)]
    legacy = [lambda x, r=r: apply_transform(x, r) for r in reps]
    l1, s1 = run_cascade_batch(imgs, fns, ths, legacy, capacities=[16, 8])
    l2, s2 = run_cascade_batch(imgs, fns, ths, reps, capacities=[16, 8])
    assert (np.asarray(l1) == np.asarray(l2)).all()
    assert (np.asarray(s1["levels_used"])
            == np.asarray(s2["levels_used"])).all()


# ----------------------------------------------------- streaming evaluator -
@pytest.mark.parametrize("scenario",
                         ["INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA"])
def test_streaming_matches_dense(scenario):
    scores, truth, p_low, p_high, reps, infer, profile = _grid(2)
    sp = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                           profile, scenario, trusted=len(reps) - 1)
    st = evaluate_cascades_streaming(scores, truth, p_low, p_high, reps,
                                     infer, profile, scenario,
                                     trusted=len(reps) - 1, chunk=3)
    assert st.evaluated == len(sp)           # full space was scored
    lookup = {(int(k), int(a), int(b)): j for j, (k, a, b) in
              enumerate(zip(sp.kind, sp.i1, sp.i2))}
    for j in range(len(st)):
        di = lookup[(int(st.kind[j]), int(st.i1[j]), int(st.i2[j]))]
        assert st.acc[j] == pytest.approx(sp.acc[di], abs=1e-5)
        assert st.time_s[j] == pytest.approx(sp.time_s[di], rel=2e-5)
    # the streaming frontier IS the dense frontier (same cascades; dense
    # may list extra duplicates of equal (acc, time) points)
    fr = pareto_indices(sp.acc, sp.throughput)
    stream_ids = {(int(k), int(a), int(b)) for k, a, b in
                  zip(st.kind, st.i1, st.i2)}
    front_vals = {(int(sp.kind[i]), int(sp.i1[i]), int(sp.i2[i])):
                  (sp.acc[i], sp.time_s[i]) for i in fr}
    for ident, (acc_i, t_i) in front_vals.items():
        assert ident in stream_ids or any(
            abs(acc_i - st.acc[j]) < 1e-6
            and abs(t_i - st.time_s[j]) < 1e-6 * t_i
            for j in range(len(st))), ident


def test_streaming_chunk_size_invariant():
    scores, truth, p_low, p_high, reps, infer, profile = _grid(6)
    results = []
    for chunk in (1, 4, 64):
        st = evaluate_cascades_streaming(
            scores, truth, p_low, p_high, reps, infer, profile, "CAMERA",
            trusted=len(reps) - 1, chunk=chunk)
        results.append({(int(k), int(a), int(b)) for k, a, b in
                        zip(st.kind, st.i1, st.i2)})
    assert results[0] == results[1] == results[2]


def test_topk_prefilter_keeps_accuracy_ties():
    """Equal-accuracy candidates at the k-th boundary must be resolved by
    the faster-first tie-break, not dropped by the intra-block prefilter
    (accuracy is correct-count/n so exact ties are common)."""
    from repro.core.cascade import _StreamReducer
    red = _StreamReducer(keep="topk", top_k=2)
    red.push(np.array([0.5, 0.5, 0.5]), np.array([3.0, 2.0, 1.0]),
             KIND_SINGLE, np.arange(3), np.full(3, -1))
    sp = red.result(1, 0)
    np.testing.assert_allclose(sp.time_s, [1.0, 2.0])


def test_streaming_topk():
    scores, truth, p_low, p_high, reps, infer, profile = _grid(7)
    sp = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                           profile, "CAMERA", trusted=len(reps) - 1)
    k = 30
    st = evaluate_cascades_streaming(scores, truth, p_low, p_high, reps,
                                     infer, profile, "CAMERA",
                                     trusted=len(reps) - 1, chunk=5,
                                     keep="topk", top_k=k)
    assert len(st) == k
    assert np.all(np.diff(st.acc) <= 1e-12)  # sorted by accuracy desc
    # the true k-th best accuracy bounds everything kept
    kth = np.sort(sp.acc)[::-1][k - 1]
    assert st.acc.min() >= kth - 1e-6


def test_streaming_max_level_2_and_first_level_subset():
    scores, truth, p_low, p_high, reps, infer, profile = _grid(8)
    sub = [0, 2]
    sp = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                           profile, "ARCHIVE", trusted=len(reps) - 1,
                           max_level=2, first_level_models=sub)
    st = evaluate_cascades_streaming(scores, truth, p_low, p_high, reps,
                                     infer, profile, "ARCHIVE",
                                     trusted=len(reps) - 1, max_level=2,
                                     first_level_models=sub, chunk=2)
    assert st.evaluated == len(sp)
    fr = pareto_indices(sp.acc, sp.throughput)
    stream_ids = {(int(k), int(a), int(b)) for k, a, b in
                  zip(st.kind, st.i1, st.i2)}
    for i in fr:
        ident = (int(sp.kind[i]), int(sp.i1[i]), int(sp.i2[i]))
        assert ident in stream_ids or any(
            abs(sp.acc[i] - st.acc[j]) < 1e-6
            and abs(sp.time_s[i] - st.time_s[j]) < 1e-6 * sp.time_s[i]
            for j in range(len(st))), ident


def test_streaming_pallas_matmul_path():
    """Force the kernels/matmul.py route (interpret mode on CPU) on a tiny
    grid — the TPU code path must produce the same survivors."""
    scores, truth, p_low, p_high, reps, infer, profile = _grid(
        9, n_models=3, n_img=24, n_targets=1)
    st_jnp = evaluate_cascades_streaming(
        scores, truth, p_low, p_high, reps, infer, profile, "CAMERA",
        trusted=2, chunk=2, use_pallas_matmul=False)
    st_pl = evaluate_cascades_streaming(
        scores, truth, p_low, p_high, reps, infer, profile, "CAMERA",
        trusted=2, chunk=2, use_pallas_matmul=True)
    assert {(int(k), int(a), int(b)) for k, a, b in
            zip(st_jnp.kind, st_jnp.i1, st_jnp.i2)} == \
        {(int(k), int(a), int(b)) for k, a, b in
         zip(st_pl.kind, st_pl.i1, st_pl.i2)}
    np.testing.assert_allclose(st_jnp.acc, st_pl.acc, atol=1e-6)
    np.testing.assert_allclose(st_jnp.time_s, st_pl.time_s, rtol=1e-5)


def test_streaming_single_level_only():
    scores, truth, p_low, p_high, reps, infer, profile = _grid(10)
    st = evaluate_cascades_streaming(scores, truth, p_low, p_high, reps,
                                     infer, profile, "CAMERA",
                                     trusted=len(reps) - 1, max_level=1)
    assert st.evaluated == len(reps)
    assert np.all(st.kind == KIND_SINGLE)
