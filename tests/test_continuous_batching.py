"""Continuous batching: outputs must equal one-request-at-a-time greedy
decoding, slots refill immediately, occupancy stays high under load."""
import jax
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models.factory import build_model
from repro.serve.continuous_batching import ContinuousBatcher, GenRequest
from repro.serve.speculative import generate_greedy


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("deepseek-7b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_matches_sequential_greedy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 8, 6, 9, 7)]
    budgets = [4, 3, 5, 2, 4]
    refs = [generate_greedy(model, params, p, b)
            for p, b in zip(prompts, budgets)]

    eng = ContinuousBatcher(model, params, n_slots=2, capacity=24)
    reqs = [GenRequest(i, p, b)
            for i, (p, b) in enumerate(zip(prompts, budgets))]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_to_completion()

    assert stats.finished == len(reqs)
    for r, ref in zip(reqs, refs):
        np.testing.assert_array_equal(np.array(r.out), ref, err_msg=str(r.rid))


def test_slots_refill_and_occupancy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    reqs = [GenRequest(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                       3) for i in range(6)]
    eng = ContinuousBatcher(model, params, n_slots=2, capacity=16)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_to_completion()
    assert stats.finished == 6
    # 6 requests x 3 tokens on 2 slots -> ~9 fully-occupied steps
    assert stats.steps <= 12
    assert stats.mean_occupancy > 0.9
