import os
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# Force a multi-device host platform BEFORE the first jax import so the
# in-process suite (sharded scan engine, pmap lockstep) sees the same 8
# simulated devices CPU CI and real multi-chip hosts do. Must run at
# conftest import time: jax reads XLA_FLAGS once, at backend init. An
# operator-provided device count (or an already-imported jax) wins —
# devsim guards both, and imports nothing heavy.
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8)

_TESTS = str(Path(__file__).resolve().parent)
if _TESTS not in sys.path:
    sys.path.insert(0, _TESTS)

try:  # the real hypothesis always wins when installed
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    _hypothesis_shim.install()


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``multidevice`` tests when the flag didn't take (jax was
    already imported, or the operator forced a 1-device count) — the
    suite then still runs everything that is exact on one device."""
    multi = [it for it in items if "multidevice" in it.keywords]
    if not multi:
        return
    import jax
    if jax.device_count() > 1:
        return
    skip = pytest.mark.skip(reason="requires >1 JAX device "
                            f"(have {jax.device_count()})")
    for it in multi:
        it.add_marker(skip)


def run_subprocess_jax(code: str, devices: int = 8, timeout: int = 600):
    """Run a jax snippet in a fresh interpreter with N host devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stderr[-4000:]}"
    return r.stdout
