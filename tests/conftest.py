import os
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# Force a multi-device host platform BEFORE the first jax import so the
# in-process suite (sharded scan engine, pmap lockstep) sees the same 8
# simulated devices CPU CI and real multi-chip hosts do. Must run at
# conftest import time: jax reads XLA_FLAGS once, at backend init. An
# operator-provided device count (or an already-imported jax) wins —
# devsim guards both, and imports nothing heavy.
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8)

_TESTS = str(Path(__file__).resolve().parent)
if _TESTS not in sys.path:
    sys.path.insert(0, _TESTS)

try:  # the real hypothesis always wins when installed
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    _hypothesis_shim.install()


# ---------------------------------------------------------------------------
# Per-test wall-clock ceiling (pytest-timeout style, stdlib-only). The
# overload/fault suite's whole point is that nothing hangs — a regression
# there would otherwise wedge CI instead of failing it. Enabled by setting
# REPRO_TEST_TIMEOUT_S (the CI workflow exports it); a `timeout` marker
# overrides the budget per test. SIGALRM only exists on the main thread of
# Unix platforms, so the hook degrades to a no-op anywhere else — if the
# real pytest-timeout plugin is installed, it takes over and this stays out
# of the way.
_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", 0) or 0)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    import signal
    import threading
    budget = _TIMEOUT_S
    m = item.get_closest_marker("timeout")
    if m is not None and m.args:
        budget = float(m.args[0])
    use = (budget > 0 and hasattr(signal, "SIGALRM")
           and threading.current_thread() is threading.main_thread()
           and not item.config.pluginmanager.hasplugin("timeout"))
    if not use:
        yield
        return

    def _expire(signum, frame):
        pytest.fail(f"test exceeded the {budget:.0f}s per-test ceiling "
                    "(REPRO_TEST_TIMEOUT_S)", pytrace=False)

    prev = signal.signal(signal.SIGALRM, _expire)
    signal.setitimer(signal.ITIMER_REAL, budget)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, prev)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test wall-clock ceiling "
        "(active when REPRO_TEST_TIMEOUT_S is set)")


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``multidevice`` tests when the flag didn't take (jax was
    already imported, or the operator forced a 1-device count) — the
    suite then still runs everything that is exact on one device."""
    multi = [it for it in items if "multidevice" in it.keywords]
    if not multi:
        return
    import jax
    if jax.device_count() > 1:
        return
    skip = pytest.mark.skip(reason="requires >1 JAX device "
                            f"(have {jax.device_count()})")
    for it in multi:
        it.add_marker(skip)


def run_subprocess_jax(code: str, devices: int = 8, timeout: int = 600):
    """Run a jax snippet in a fresh interpreter with N host devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stderr[-4000:]}"
    return r.stdout
