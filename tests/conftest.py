import os
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

_TESTS = str(Path(__file__).resolve().parent)
if _TESTS not in sys.path:
    sys.path.insert(0, _TESTS)

try:  # the real hypothesis always wins when installed
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    _hypothesis_shim.install()

# NOTE: device count is intentionally NOT forced here — smoke tests run on
# the single real CPU device. Multi-device tests spawn subprocesses with
# their own XLA_FLAGS (see tests/_subproc.py).


def run_subprocess_jax(code: str, devices: int = 8, timeout: int = 600):
    """Run a jax snippet in a fresh interpreter with N host devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stderr[-4000:]}"
    return r.stdout
