"""Joint multi-predicate cascade selection + online re-ordering
(DESIGN.md §11): the §VI cost decomposition must be exact against the
evaluated space, the joint search must match a brute-force (set x order)
oracle on tiny spaces and never price worse than the independent plan
(hypothesis property), shared pyramid levels must be materialized ONCE
per chunk (invocation counting), and both the joint plan and mid-scan
re-ordering must leave query row sets bit-identical across the serial
engine, sharded engines at {1, 8} shards, the async service, and
naive per-predicate scans."""
import itertools
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cascade import (cascade_time_naive, evaluate_cascades,
                                spec_levels)
from repro.core.costs import (FULL_LOAD, CostProfile, DecomposedCost,
                              decompose_cascade_cost)
from repro.core.selector import (estimate_selectivity, pareto_set, select,
                                 select_candidates)
from repro.core.transforms import Representation
from repro.engine.planner import (OnlineReorderer, expected_scan_cost,
                                  joint_scan_cost, order_predicates,
                                  order_predicates_shared, plan_query,
                                  search_joint)
from repro.engine.scan import ScanEngine, naive_scan
from test_query_engine import _toy_cascade, _uint8_images


# --------------------------------------------------- synthetic fixtures ---
def _space_bank(seed, n_models=4, n_img=50, n_t=3):
    rng = np.random.default_rng(seed)
    reps = [Representation(8, "gray"), Representation(16, "gray"),
            Representation(16, "rgb"), Representation(32, "rgb")][:n_models]
    scores = rng.uniform(0, 1, (n_models, n_img))
    truth = rng.integers(0, 2, n_img).astype(bool)
    p_low = np.sort(rng.uniform(0, 0.5, (n_models, n_t)), axis=1)
    p_high = np.sort(rng.uniform(0.5, 1.0, (n_models, n_t)),
                     axis=1)[:, ::-1].copy()
    infer = rng.uniform(1e-5, 1e-3, n_models)
    profile = CostProfile.modeled(
        {f"m{i}": s for i, s in enumerate(infer)}, list(set(reps)),
        base_hw=32)
    return scores, truth, p_low, p_high, reps, infer, profile


def _rand_dec(rng, levels=(8, 16, 32)):
    """Random DecomposedCost over a random subset of pyramid levels."""
    picked = [r for r in levels if rng.random() < 0.7] or [levels[0]]
    return DecomposedCost(
        float(rng.uniform(1e-5, 1e-3)),
        {r: float(rng.uniform(1e-6, 5e-4)) for r in picked})


# ------------------------------------------------ decomposition exactness -
@pytest.mark.parametrize("scenario",
                         ["INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA"])
def test_decomposed_cost_exact_vs_space_and_naive_walk(scenario):
    scores, truth, p_low, p_high, reps, infer, profile = _space_bank(0)
    space = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                              profile, scenario, trusted=3)
    for i in range(len(space)):
        levels = spec_levels(space, i, p_low, p_high)
        dec = decompose_cascade_cost(levels, scores, reps, infer,
                                     profile, scenario)
        assert np.isclose(dec.total_s, space.time_s[i], rtol=1e-9), i
        assert np.isclose(
            dec.total_s,
            cascade_time_naive(levels, scores, reps, infer, profile,
                               scenario), rtol=1e-12), i
        # rep charges only on levels the cascade's reps actually touch
        touched = {reps[m].resolution for m, _, _ in levels}
        assert set(dec.rep_s) - {FULL_LOAD} <= touched
        if scenario == "ARCHIVE":
            assert FULL_LOAD in dec.rep_s      # raw load split out
        if scenario == "INFER_ONLY":
            assert dec.rep_total_s == 0.0


def test_marginal_never_exceeds_standalone():
    rng = np.random.default_rng(3)
    for _ in range(50):
        d = _rand_dec(rng)
        mat = {r for r in (8, 16, 32, FULL_LOAD) if rng.random() < 0.5}
        assert d.marginal_rep_s(mat) <= d.rep_total_s + 1e-18
        assert d.marginal_s(mat) <= d.total_s + 1e-18
        assert d.marginal_s(set()) == pytest.approx(d.total_s)
        assert d.marginal_rep_s(d.levels) == 0.0


# ----------------------------------------------------- joint cost model ---
def test_joint_cost_reduces_to_independent_when_disjoint():
    rng = np.random.default_rng(1)
    decs = [DecomposedCost(1e-4, {8: 2e-4}),
            DecomposedCost(3e-4, {16: 1e-4}),
            DecomposedCost(2e-4, {32: 4e-4})]
    for _ in range(10):
        sels = rng.uniform(0.05, 0.95, 3)
        order = list(rng.permutation(3))
        assert joint_scan_cost(decs, sels, order) == pytest.approx(
            expected_scan_cost([d.total_s for d in decs], sels, order),
            rel=1e-12)


def test_joint_cost_prices_shared_level_once():
    # both predicates touch level 16; the second must not pay it again
    decs = [DecomposedCost(1e-4, {16: 5e-4}),
            DecomposedCost(1e-4, {16: 5e-4})]
    sels = [0.5, 0.5]
    got = joint_scan_cost(decs, sels, [0, 1])
    want = (1e-4 + 5e-4) + 0.5 * 1e-4       # second pays inference only
    assert got == pytest.approx(want, rel=1e-12)
    assert got < expected_scan_cost([d.total_s for d in decs], sels)


def test_joint_cost_dense_reps_charges_levels_at_ingest():
    """Engine pricing: the scan materializes the union pyramid at chunk
    ingest for EVERY scanned row, so under dense_reps a first-touched
    level is charged at probability 1 even when only a late, unlikely
    predicate needs it — survival-weighting applies to inference only."""
    decs = [DecomposedCost(1e-4, {16: 2e-4}),
            DecomposedCost(3e-4, {32: 7e-4})]
    sels = [0.1, 0.5]
    got = joint_scan_cost(decs, sels, [0, 1], dense_reps=True)
    want = (1e-4 + 2e-4) + (0.1 * 3e-4 + 1.0 * 7e-4)
    assert got == pytest.approx(want, rel=1e-12)
    # the survival-weighted rule would undercharge level 32 by 0.9x
    assert got > joint_scan_cost(decs, sels, [0, 1])


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 4))
def test_joint_cost_never_exceeds_independent_same_set(seed, k):
    """For ANY fixed cascade set and order, shared pricing <= standalone
    pricing (marginal <= standalone per predicate)."""
    rng = np.random.default_rng(seed)
    decs = [_rand_dec(rng) for _ in range(k)]
    sels = rng.uniform(0.0, 1.0, k)
    order = list(rng.permutation(k))
    assert joint_scan_cost(decs, sels, order) <= expected_scan_cost(
        [d.total_s for d in decs], sels, order) + 1e-15


# ------------------------------------------------- ordering + search ------
def _oracle(pools, restrict_combo=None, dense_reps=False):
    """Brute force over every (candidate set x evaluation order)."""
    best = math.inf
    combos = ([restrict_combo] if restrict_combo is not None else
              itertools.product(*[range(len(p)) for p in pools]))
    for combo in combos:
        decs = [pools[i][j][0] for i, j in enumerate(combo)]
        sels = [pools[i][j][1] for i, j in enumerate(combo)]
        for order in itertools.permutations(range(len(pools))):
            best = min(best, joint_scan_cost(decs, sels, order,
                                             dense_reps=dense_reps))
    return best


def test_order_predicates_shared_matches_exhaustive():
    rng = np.random.default_rng(7)
    for _ in range(40):
        k = int(rng.integers(2, 5))
        decs = [_rand_dec(rng) for _ in range(k)]
        sels = rng.uniform(0.05, 0.95, k)
        got = joint_scan_cost(decs, sels,
                              order_predicates_shared(decs, sels))
        best = min(joint_scan_cost(decs, sels, o)
                   for o in itertools.permutations(range(k)))
        assert got == pytest.approx(best, rel=1e-12)


def test_search_joint_matches_brute_force_oracle():
    rng = np.random.default_rng(11)
    for trial in range(25):
        k = int(rng.integers(2, 4))
        pools = [[(_rand_dec(rng), float(rng.uniform(0.05, 0.95)))
                  for _ in range(int(rng.integers(1, 4)))]
                 for _ in range(k)]
        incumbent = tuple(int(rng.integers(0, len(p))) for p in pools)
        combo, order, cost = search_joint(pools, incumbent)
        assert cost == pytest.approx(_oracle(pools), rel=1e-12), trial
        # the returned (combo, order) really prices at the claimed cost
        decs = [pools[i][j][0] for i, j in enumerate(combo)]
        sels = [pools[i][j][1] for i, j in enumerate(combo)]
        assert joint_scan_cost(decs, sels, order) == pytest.approx(
            cost, rel=1e-12)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_search_joint_never_worse_than_independent(seed):
    """The never-worse guarantee: the search result never prices above
    the independent selection evaluated at ITS best order, nor above the
    classical standalone-cost plan."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 4))
    pools = [[(_rand_dec(rng), float(rng.uniform(0.05, 0.95)))
              for _ in range(int(rng.integers(1, 4)))] for _ in range(k)]
    # "independent" = cheapest standalone per pool (the select() rule
    # under a satisfied accuracy constraint)
    incumbent = tuple(min(range(len(p)), key=lambda j: p[j][0].total_s)
                      for p in pools)
    _, _, cost = search_joint(pools, incumbent)
    assert cost <= _oracle(pools, restrict_combo=incumbent) + 1e-15
    ind_decs = [pools[i][j][0] for i, j in enumerate(incumbent)]
    ind_sels = [pools[i][j][1] for i, j in enumerate(incumbent)]
    ind_order = order_predicates([d.total_s for d in ind_decs], ind_sels)
    assert cost <= expected_scan_cost([d.total_s for d in ind_decs],
                                      ind_sels, ind_order) + 1e-15


# ------------------------------------------------------ candidate pools ---
def test_select_candidates_contains_select_pick():
    scores, truth, p_low, p_high, reps, infer, profile = _space_bank(5)
    space = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                              profile, "CAMERA", trusted=3)
    floor = float(np.quantile(space.acc[pareto_set(space)], 0.4))
    pool = select_candidates(space, min_accuracy=floor)
    pick = select(space, min_accuracy=floor)
    assert pick.index in [s.index for s in pool]
    assert all(s.accuracy >= floor for s in pool)
    times = [space.time_s[s.index] for s in pool]
    assert times == sorted(times)                  # fastest-first
    with pytest.raises(ValueError):
        select_candidates(space, min_accuracy=1.1)


# --------------------------------------------- end-to-end trained system --
@pytest.fixture(scope="module")
def trained():
    from repro.configs.base import TahomaCNNConfig
    from repro.core.pipeline import initialize_system
    from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,
                                      make_multi_corpus, three_way_split)

    specs = DEFAULT_PREDICATES[:2]
    reps = [Representation(8, "gray"), Representation(16, "gray"),
            Representation(32, "rgb")]
    systems = {}
    for spec in specs:
        x, y = make_corpus(spec, 160, hw=32, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1),
            [TahomaCNNConfig(1, 8, 16)], reps, steps=30)
    qx, _ = make_multi_corpus(specs, 144, hw=32, seed=5,
                              positive_rate=0.4)
    metadata = {"cam": np.arange(len(qx)) % 2}
    return specs, systems, qx, metadata


def _plan_pair(trained, min_accuracy=0.6, costing="engine"):
    from repro.engine.planner import PredicateClause, QuerySpec

    specs, systems, qx, metadata = trained
    spec_q = QuerySpec(
        metadata_eq={"cam": 0},
        predicates=[PredicateClause(s.name, min_accuracy=min_accuracy)
                    for s in specs])
    ind = plan_query(systems, spec_q, scenario="CAMERA", metadata=metadata)
    joint = plan_query(systems, spec_q, scenario="CAMERA",
                       metadata=metadata, joint=True, costing=costing)
    return ind, joint


@pytest.mark.parametrize("costing", ["paper", "engine"])
def test_joint_plan_matches_oracle_and_never_worse(trained, costing):
    specs, systems, qx, metadata = trained
    dense = costing == "engine"
    ind, joint = _plan_pair(trained, costing=costing)
    assert joint.joint and not ind.joint
    assert joint.costing == costing
    assert all(p.decomposed is not None for p in joint.predicates)
    # never worse than the independent plan, in the same costing mode
    # (rep charges always at the lazy first-touch survival weight —
    # dense_reps=False — matching the engines' level_schedule; the
    # costing modes differ only in dense_levels)
    ind_as_joint = joint_scan_cost(
        [systems[p.cascade.concept].decomposed_cost(
            systems[p.cascade.concept].cascade_space("CAMERA"),
            p.selection.index, "CAMERA", dense_levels=dense)
         for p in ind.predicates],
        [p.cascade.selectivity for p in ind.predicates],
        dense_reps=False)
    assert joint.estimated_cost_per_row() <= ind_as_joint + 1e-15
    # brute-force oracle over (pool product x order) on the real spaces
    pools = []
    for s in specs:
        system = systems[s.name]
        space = system.cascade_space("CAMERA")
        pools.append([
            (system.decomposed_cost(space, c.index, "CAMERA",
                                    dense_levels=dense),
             estimate_selectivity(space, c.index, system.eval_scores,
                                  system.p_low, system.p_high))
            for c in select_candidates(space, min_accuracy=0.6)])
    assert joint.estimated_cost_per_row() == pytest.approx(
        _oracle(pools, dense_reps=False), rel=1e-9)
    # savings baseline is priced in the same mode: never negative
    assert joint.unshared_cost_per_row() >= \
        joint.estimated_cost_per_row() - 1e-15


def test_dense_levels_costing_sums_all_levels():
    """Engine costing charges EVERY level at reach 1 (the scan paths run
    full-width levels), so dense infer == the plain sum of the levels'
    infer costs, and dense >= paper reach-weighted pricing."""
    scores, truth, p_low, p_high, reps, infer, profile = _space_bank(9)
    space = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                              profile, "CAMERA", trusted=3)
    for i in range(0, len(space), 7):
        levels = spec_levels(space, i, p_low, p_high)
        dense = decompose_cascade_cost(levels, scores, reps, infer,
                                       profile, "CAMERA",
                                       dense_levels=True)
        paper = decompose_cascade_cost(levels, scores, reps, infer,
                                       profile, "CAMERA")
        assert dense.infer_s == pytest.approx(
            sum(infer[m] for m, _, _ in levels), rel=1e-12)
        assert dense.total_s >= paper.total_s - 1e-18
        # paper mode stops charging once no eval image reaches a level;
        # dense mode charges every level the scan would execute
        assert set(paper.rep_s) <= set(dense.rep_s)
        touched = {reps[m].resolution for m, _, _ in levels}
        assert set(dense.rep_s) == touched


def test_joint_explain_prints_savings(trained):
    _, joint = _plan_pair(trained)
    txt = joint.explain(n_rows=144)
    assert "[joint, engine costing]" in txt
    assert "shared-representation savings" in txt
    assert "levels={" in txt and "shared={" in txt
    assert "materialized once per chunk" in txt
    assert "PHYSICAL PLAN" in txt          # old fields intact
    assert "cost/row" in txt and "sel=" in txt
    # level_set is the union of the cascades' resolutions
    want = {r.resolution for c in joint.cascades for r in c.reps}
    assert set(joint.level_set) == want


def test_explain_renders_estimated_vs_actual_levels(trained):
    """DESIGN.md §13: explain(base_hw=, actual=) renders the lazy level
    schedule and per-level estimated-vs-actual materialization counts,
    and the engine-costing contract holds — the measured level_rows
    equal materialization_schedule's first-touch prediction exactly on
    a cold scan."""
    specs, systems, qx, metadata = trained
    _, joint = _plan_pair(trained)
    base_hw = qx.shape[1]
    eng = ScanEngine(qx, metadata, chunk=32)
    res = eng.execute(joint.cascades, joint.metadata_eq)
    txt = joint.explain(n_rows=len(qx), base_hw=base_hw,
                        actual=res.stats)
    assert "lazy level schedule" in txt
    assert "level rows:" in txt and "actual" in txt
    sched = joint.materialization_schedule(base_hw)
    assert set(sched) == set(joint.level_set) - {base_hw}
    for r, s in sched.items():
        want = (res.stats.rows_scanned if s == 0
                else res.stats.stages[s].rows_evaluated)
        assert res.stats.level_rows.get(r, 0) == want
    # the prior estimate exists for every scheduled level
    est = joint.expected_level_rows(res.stats.rows_scanned, base_hw)
    assert set(est) == set(sched)
    # without actual= the schedule/estimate lines still render
    assert "lazy level schedule" in joint.explain(n_rows=len(qx),
                                                  base_hw=base_hw)


def test_joint_plan_rows_identical_across_engines(trained):
    """Acceptance differential: the joint plan's row set is identical
    across ScanEngine, naive per-predicate scans, and the ordering
    choice (joint order vs classical rank order)."""
    specs, systems, qx, metadata = trained
    ind, joint = _plan_pair(trained)
    eng = ScanEngine(qx, metadata, chunk=32)
    res = eng.execute(joint.cascades, joint.metadata_eq)
    ref = naive_scan(qx, joint.cascades, metadata, joint.metadata_eq,
                     chunk=32)
    assert np.array_equal(res.indices, ref)
    # ordering invariance: same cascade set, any order -> same rows
    eng2 = ScanEngine(qx, metadata, chunk=32)
    res2 = eng2.execute(joint.cascades[::-1], joint.metadata_eq)
    assert np.array_equal(res2.indices, res.indices)
    # engine materializes exactly the joint level set (+ base)
    assert set(res.stats.pyramid_levels) == \
        set(joint.level_set) | {qx.shape[1]}
    # when both planners select the same cascade set, rows coincide
    if [c.key for c in ind.cascades] == [c.key for c in joint.cascades]:
        eng3 = ScanEngine(qx, metadata, chunk=32)
        assert np.array_equal(
            eng3.execute(ind.cascades, ind.metadata_eq).indices,
            res.indices)


def test_joint_plan_index_aware_costing(trained):
    """Candidate-index-aware joint planning (DESIGN.md §14.5): ingest
    the query corpus with the joint plan's cascades, re-plan with the
    index attached — every chosen pool entry must be priced against the
    rows the index leaves for it (decomposed cost scaled by its own
    eval_frac, level set untouched so marginal sharing still composes),
    and exact-mode execution stays bit-identical to a cold naive scan
    of the same plan."""
    from repro.engine.ingest import IngestPipeline, indexed_execute
    from repro.engine.planner import PredicateClause, QuerySpec

    specs, systems, qx, metadata = trained
    _, joint = _plan_pair(trained)
    pipe = IngestPipeline(joint.cascades, len(qx), chunk=48, skip=False)
    pipe.run(qx)
    idx = pipe.index
    # stage-0 both-threshold exits decided rows during ingest
    assert any(idx.planning_stats(c.key, 0.5)[0] < 1.0
               for c in joint.cascades)
    spec_q = QuerySpec(
        metadata_eq={"cam": 0},
        predicates=[PredicateClause(s.name, min_accuracy=0.6)
                    for s in specs])
    joint_idx = plan_query(systems, spec_q, scenario="CAMERA",
                           metadata=metadata, joint=True, index=idx)
    assert joint_idx.joint and joint_idx.index is idx
    scaled = 0
    for p in joint_idx.predicates:
        ef, _ = idx.planning_stats(p.cascade.key, 0.5, prefilter=True)
        system = systems[p.cascade.concept]
        raw = system.decomposed_cost(system.cascade_space("CAMERA"),
                                     p.selection.index, "CAMERA",
                                     dense_levels=True)
        assert p.decomposed.infer_s == pytest.approx(raw.infer_s * ef)
        assert p.decomposed.levels == raw.levels
        scaled += ef < 1.0
    assert scaled                  # the index actually discounted a pick
    eng = ScanEngine(qx, metadata, chunk=48)
    res = indexed_execute(eng, joint_idx)
    ref = naive_scan(qx, joint_idx.cascades, metadata,
                     joint_idx.metadata_eq, chunk=48)
    assert np.array_equal(res.indices, ref)


@pytest.mark.multidevice
@pytest.mark.parametrize("shards", [1, 8])
def test_joint_plan_rows_identical_sharded(trained, shards):
    from repro.engine.sharded import ShardedScanEngine

    specs, systems, qx, metadata = trained
    _, joint = _plan_pair(trained)
    ref = ScanEngine(qx, metadata, chunk=32).execute(
        joint.cascades, joint.metadata_eq)
    eng = ShardedScanEngine(qx, metadata, shards=shards, chunk=32)
    res = eng.execute(joint.cascades, joint.metadata_eq)
    assert np.array_equal(res.indices, ref.indices)
    for sh in res.stats.shards:
        if sh.rows_scanned:
            assert set(sh.pyramid_levels) == \
                set(joint.level_set) | {qx.shape[1]}


def test_joint_plan_labels_identical_async_service(trained):
    """Acceptance differential: the async service answers the joint
    plan's cascades bit-identically to the scan engine, and its
    repcache keys line up with the scan's published pyramid levels."""
    from repro.serve import RepresentationCache, Request
    from repro.serve.service import AsyncCascadeService

    specs, systems, qx, metadata = trained
    _, joint = _plan_pair(trained)
    cascades = {c.concept: c for c in joint.cascades}
    repcache = RepresentationCache()
    eng = ScanEngine(qx, metadata, chunk=32, repcache=repcache)
    res = eng.execute(joint.cascades, joint.metadata_eq)

    svc = AsyncCascadeService(qx, cascades, shards=2, batch_size=16,
                              max_wait_s=1e-4, repcache=repcache)
    want = {}
    for c in joint.cascades:
        col = np.zeros(len(qx), np.int8)
        chunk_eng = ScanEngine(qx, metadata, chunk=32)
        ids = chunk_eng.execute([c]).indices
        col[ids] = 1
        want[c.concept] = col
    reqs = []
    for i, row in enumerate(range(0, len(qx), 3)):
        for c in joint.cascades:
            r = Request((i, c.concept), row)
            svc.submit(c.concept, r)
            reqs.append((c.concept, row, r))
        svc.poll()
    svc.drain()
    for concept, row, r in reqs:
        assert int(r.result) == int(want[concept][row]), (concept, row)
    # the scan published the joint level set's non-base levels; the
    # service's batch assembly reads the same (row, resolution) keys
    assert repcache.hits > 0


# ------------------------------------------- materialize-once regression --
@pytest.mark.parametrize("lazy", [False, True])
def test_shared_levels_materialized_once_per_chunk(trained, monkeypatch,
                                                   lazy):
    """Invocation-counting: per chunk there is exactly ONE pyramid
    materialization and it covers exactly the ingest schedule —
    predicates never re-materialize shared levels. Eager: the whole
    union level set at ingest (the pre-lazy behavior). Lazy: only the
    FIRST cascade's levels; later-stage-only levels are first-touch
    derived inside the flush (resize_area), never through a second
    materialize_pyramid call."""
    import repro.engine.scan as scan_mod
    from repro.engine.scan import level_schedule

    specs, systems, qx, metadata = trained
    _, joint = _plan_pair(trained)
    calls = []
    real = scan_mod.materialize_pyramid

    def counting(img, resolutions):
        calls.append(tuple(resolutions))
        return real(img, resolutions)

    monkeypatch.setattr(scan_mod, "materialize_pyramid", counting)
    eng = ScanEngine(qx, metadata, chunk=32, jit=False, lazy=lazy)
    res = eng.execute(joint.cascades, joint.metadata_eq)
    n_meta = int((metadata["cam"] == 0).sum())
    want_chunks = math.ceil(n_meta / 32)
    assert res.stats.chunks == want_chunks
    assert len(calls) == want_chunks               # ONE per chunk
    ingest_set, _, _ = level_schedule(joint.cascades, qx.shape[1], lazy)
    assert all(set(c) == set(ingest_set) for c in calls)
    if not lazy:    # eager ingest covers the whole non-base union
        assert set(ingest_set) == set(joint.level_set) - {qx.shape[1]}
    # the static union is reported either way
    assert set(res.stats.pyramid_levels) == \
        set(joint.level_set) | {qx.shape[1]}


# ------------------------------------------------- online re-ordering -----
def _drifted_cascades():
    """Toy cascades whose planner estimates are deliberately wrong: the
    plan order (a, b) is optimal under the ESTIMATES but pessimal under
    the labels actually observed, so a low-threshold monitor must flip
    the order mid-scan. Under the corrected (first-position-exposure)
    estimator only the predicate at stage 0 ever observes its marginal,
    so the flip must come from a's OBSERVED selectivity (~0.5 on these
    corpora) overtaking b's ESTIMATE: a is estimated near-perfectly
    selective (rank ~cost), b moderately (rank cost/0.7) — once a's
    true ~0.5 is adopted its rank (cost/0.5) exceeds b's and b goes
    first."""
    a = _toy_cascade("a", 1)
    b = _toy_cascade("b", 2, [(0.25, 0.75), (0.3, 0.7), (None, None)])
    a.cost_s, a.selectivity = 1.0e-3, 0.05     # est: filters everything
    b.cost_s, b.selectivity = 1.0e-3, 0.30     # est: filters moderately
    return [a, b]


def test_online_reorder_bit_identical_and_triggers():
    imgs = _uint8_images(210, 32, seed=4)
    metadata = {"cam": np.arange(len(imgs)) % 2}
    cascades = _drifted_cascades()
    base = ScanEngine(imgs, metadata, chunk=32).execute(
        cascades, {"cam": 0})
    mon = OnlineReorderer(cascades, drift_threshold=0.05, min_rows=16)
    eng = ScanEngine(imgs, metadata, chunk=32)
    res = eng.execute(cascades, {"cam": 0}, monitor=mon)
    # exactness first: re-ordering must never change the row set
    assert np.array_equal(res.indices, base.indices)
    ref = naive_scan(imgs, cascades, metadata, {"cam": 0}, chunk=32)
    assert np.array_equal(res.indices, ref)
    # the drift actually fired (estimates were constructed wrong)
    assert res.stats.reorders >= 1
    assert mon.reorders == res.stats.reorders
    # stats stay per-concept coherent after the permutation
    assert {s.concept for s in res.stats.stages} == {"a", "b"}
    n_meta = int((metadata["cam"] == 0).sum())
    assert res.stats.stages[0].rows_in <= n_meta  # plausible routing
    # and the store ends consistent: a re-run returns the same rows,
    # reusing the virtual columns (the columns are PARTIAL by design —
    # rows the flipped order eliminated at stage b never got stage-a
    # labels, so a handful of fresh evaluations is expected)
    again = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(again.indices, res.indices)
    assert again.stats.rows_evaluated < res.stats.rows_evaluated
    assert sum(s.rows_cached for s in again.stats.stages) > 0


def test_online_reorder_noop_without_drift():
    imgs = _uint8_images(120, 32, seed=6)
    cascades = _drifted_cascades()
    mon = OnlineReorderer(cascades, drift_threshold=1.1, min_rows=8)
    eng = ScanEngine(imgs, chunk=32)
    res = eng.execute(cascades, monitor=mon)
    assert res.stats.reorders == 0 and mon.reorders == 0


def test_online_reorderer_unit():
    cascades = _drifted_cascades()
    mon = OnlineReorderer(cascades, drift_threshold=0.1, min_rows=4)
    key_a, key_b = cascades[0].key, cascades[1].key
    assert mon.propose(cascades) is None           # nothing observed
    # first-position (marginal) exposure — the only kind that refines
    mon.observe(key_a, np.ones(8), marginal=True)  # a survives everything
    mon.observe(key_b, np.zeros(8), marginal=True)  # b kills everything
    perm = mon.propose(cascades)
    assert perm == [1, 0]                          # b now goes first
    # estimates adopted: the same drift does not re-fire
    assert mon.propose(cascades) is None
    assert mon.reorders == 1
