"""Streaming ingest-time indexing (engine/ingest.py, DESIGN.md §14) and
the bugfix sweep it rides on: the skip detector must alias exactly the
held scene repeats, exact-mode indexed queries must stay bit-identical
to cold ScanEngine / naive_scan across shard counts and detector
settings (the differential oracle), ingest-decided rows must answer at
query time with ZERO model invocations (engine stats + service
store_hits), persistence round-trips (VirtualColumnStore,
RepresentationCache, CandidateIndex) must be bit-identical and refuse a
different corpus, and the OnlineReorderer's conditional-vs-marginal
selectivity bias must be provably FIXED (the legacy estimator flips an
ordering the corrected one gets right)."""
import numpy as np
import pytest

from repro.core.pipeline import build_ingest_pipeline
from repro.data.synthetic import DEFAULT_PREDICATES, make_camera_stream
from repro.engine.ingest import (CandidateIndex, IngestPipeline,
                                 frame_signature, indexed_execute)
from repro.engine.planner import (OnlineReorderer, PhysicalPlan,
                                  PlannedPredicate, expected_scan_cost)
from repro.engine.scan import ScanEngine, VirtualColumnStore, naive_scan
from repro.engine.sharded import ShardedScanEngine
from repro.serve.repcache import RepresentationCache, corpus_token
from test_query_engine import _toy_cascade, _uint8_images

SPECS = DEFAULT_PREDICATES[:3]


@pytest.fixture(scope="module")
def stream():
    """Small camera stream + toy cascades + a built index (module-scoped:
    the ingest pass and the cascades' jit cache are shared)."""
    frames, labels, scene = make_camera_stream(SPECS, 240, hw=32, seed=0)
    cascades = [_toy_cascade(c, s) for c, s in
                [("a", 1), ("b", 2), ("c", 3)]]
    pipe = IngestPipeline(cascades, len(frames), chunk=64, skip=True)
    pipe.run(frames)
    return frames, labels, scene, cascades, pipe


# ---------------------------------------------------------- skip detect ---
def test_skip_detector_aliases_exactly_the_scene_repeats(stream):
    frames, _, scene, _, pipe = stream
    idx = pipe.index
    self_ref = idx.alias == np.arange(len(frames))
    # one reference per scene, every held repeat aliased to it
    assert int(self_ref.sum()) == scene.max() + 1
    assert pipe.stats.skipped == len(frames) - (scene.max() + 1)
    # an alias NEVER crosses a scene boundary (the jitter-vs-scene-change
    # separation margin the corpus is constructed with)
    assert np.array_equal(scene[idx.alias], scene)
    # only references were scored
    assert pipe.stats.refs == int(self_ref.sum())
    assert pipe.stats.stage0_scores == pipe.stats.refs * 3


def test_detector_margin_separates_jitter_from_scene_changes(stream):
    frames, _, scene, _, pipe = stream
    sigs = frame_signature(frames, pipe.skip_res)
    diffs = np.abs(sigs[1:] - sigs[:-1]).mean(axis=(1, 2))
    same = scene[1:] == scene[:-1]
    assert diffs[same].max() < pipe.skip_threshold          # jitter below
    assert diffs[~same].min() > 2 * pipe.skip_threshold     # changes above


def test_autocalibrated_threshold_lands_in_the_margin(stream):
    """skip_threshold=None LEARNS the per-camera threshold from the
    warmup window: the learned value must land strictly between the
    jitter and scene-change diff clusters (the same margin the pinned
    default is tested for above), no frame may be skipped before
    calibration completes, and the alias invariants survive."""
    frames, _, scene, cascades, _ = stream
    auto = IngestPipeline(cascades, len(frames), chunk=64, skip=True,
                          skip_threshold=None)
    assert auto.skip_threshold is None            # nothing learned yet
    auto.run(frames)
    thr = auto.skip_threshold
    assert thr is not None
    sigs = frame_signature(frames, auto.skip_res)
    diffs = np.abs(sigs[1:] - sigs[:-1]).mean(axis=(1, 2))
    same = scene[1:] == scene[:-1]
    assert diffs[same].max() < thr < diffs[~same].min()
    # calibration holds skipping off: every warmup frame is a reference
    calib = auto.calib_frames
    assert np.array_equal(auto.index.alias[:calib], np.arange(calib))
    # skipping resumed afterwards, and aliases never cross a scene
    assert auto.stats.skipped > 0
    assert np.array_equal(scene[auto.index.alias], scene)


def test_calibrate_threshold_unit():
    lo = 1e-3 * np.linspace(0.5, 1.5, 20)         # jitter cluster
    hi = 0.2 * np.linspace(0.8, 1.2, 6)           # scene changes
    thr = IngestPipeline.calibrate_threshold(np.concatenate([hi, lo]))
    assert lo.max() < thr < hi.min()
    # the threshold is the geometric mean of the largest-gap endpoints
    assert thr == pytest.approx(np.sqrt(lo.max() * hi.min()))
    # non-positive diffs (chain starts) are ignored
    assert IngestPipeline.calibrate_threshold(
        np.concatenate([[0.0, 0.0], hi, lo])) == pytest.approx(thr)
    # too few samples, or no clear multiplicative gap: pinned fallback
    assert IngestPipeline.calibrate_threshold([1e-3] * 5) == 0.008
    assert IngestPipeline.calibrate_threshold(
        np.linspace(0.01, 0.02, 30)) == 0.008


def test_ingest_factory_passes_calibration_knobs(stream):
    frames, _, _, cascades, _ = stream
    pipe = build_ingest_pipeline(cascades, len(frames), chunk=32,
                                 skip_threshold=None, calib_frames=24)
    assert pipe.skip_threshold is None
    assert pipe.calib_frames == 24


def test_streaming_granularity_invariant(stream):
    """Feeding the stream in ragged batches (the detector chains across
    ingest() calls) builds the identical index to one full run()."""
    frames, _, _, cascades, pipe = stream
    ragged = IngestPipeline(cascades, len(frames), chunk=64, skip=True)
    ids = np.arange(len(frames))
    for lo, hi in [(0, 7), (7, 64), (64, 65), (65, 200), (200, len(frames))]:
        ragged.ingest(frames[lo:hi], ids[lo:hi])
    assert np.array_equal(ragged.index.alias, pipe.index.alias)
    for c in ragged.index.concepts:
        assert np.array_equal(ragged.index.candidates[c],
                              pipe.index.candidates[c])
    for k in pipe.index.decided.keys():
        assert np.array_equal(ragged.index.decided.column(k),
                              pipe.index.decided.column(k))


# --------------------------------------------------- differential oracle --
def _cold_rows(frames, cascades):
    return ScanEngine(frames, chunk=32).execute(cascades).indices


@pytest.mark.parametrize("shards", [0, 8])
def test_exact_mode_bit_identical_oracle(stream, shards):
    """THE exactness gate: exact-mode indexed row sets == cold ScanEngine
    == naive_scan, serial and sharded."""
    frames, _, _, cascades, pipe = stream
    cold = _cold_rows(frames, cascades)
    assert np.array_equal(cold, naive_scan(frames, cascades, chunk=32))
    if shards:
        eng = ShardedScanEngine(frames, shards=shards, chunk=32)
    else:
        eng = ScanEngine(frames, chunk=32)
    pipe.index.seed_store(eng.store, exact=True)
    surv = pipe.index.survivors(np.arange(len(frames)), cascades,
                                exact=True)
    res = eng.execute(cascades, survivors=surv)
    assert np.array_equal(res.indices, cold)
    # and the index genuinely removed work: pruned rows plus seeded
    # stage-0 labels both cut evaluated rows vs the cold scan
    cold_res = ScanEngine(frames, chunk=32).execute(cascades)
    assert res.stats.rows_evaluated < cold_res.stats.rows_evaluated


@pytest.mark.slow
@pytest.mark.parametrize("shards", [1, 8])
@pytest.mark.parametrize("skip", [True, False])
def test_exact_mode_oracle_full_grid(shards, skip):
    """Full {shards} x {skip-detector} differential grid (slow marker:
    each cell re-ingests and re-compiles)."""
    frames, _, _ = make_camera_stream(SPECS, 150, hw=32, seed=3)
    cascades = [_toy_cascade(c, s) for c, s in [("a", 11), ("b", 12)]]
    pipe = IngestPipeline(cascades, len(frames), chunk=64, skip=skip)
    pipe.run(frames)
    cold = _cold_rows(frames, cascades)
    assert np.array_equal(cold, naive_scan(frames, cascades, chunk=32))
    eng = ShardedScanEngine(frames, shards=shards, chunk=32)
    pipe.index.seed_store(eng.store, exact=True)
    surv = pipe.index.survivors(np.arange(len(frames)), cascades,
                                exact=True)
    assert np.array_equal(eng.execute(cascades, survivors=surv).indices,
                          cold)


def test_approx_mode_prunes_at_measured_recall(stream):
    frames, labels, _, cascades, pipe = stream
    idx = pipe.index
    ids = np.arange(len(frames))
    exact_surv = idx.survivors(ids, cascades, exact=True)
    approx_surv = idx.survivors(ids, cascades, exact=False)
    assert len(approx_surv) < len(exact_surv)   # aliases + candidates prune
    eng = ScanEngine(frames, chunk=32)
    idx.seed_store(eng.store, exact=False)
    res = eng.execute(cascades, survivors=approx_surv)
    cold = _cold_rows(frames, cascades)
    hit = len(np.intersect1d(res.indices, cold))
    # the recall knob's cost is measured, not assumed: per-concept
    # measured_recall is honest about the synthetic truth...
    for k, c in enumerate(idx.concepts):
        r = idx.measured_recall(c, labels[:, k])
        assert 0.0 <= r <= 1.0
    # ...and the end-to-end conjunction keeps most of the cold rows at a
    # fraction of the work (loose floor: the toy heads are weak learners)
    assert hit / max(len(cold), 1) > 0.6
    assert res.stats.rows_evaluated < 0.5 * ScanEngine(
        frames, chunk=32).execute(cascades).stats.rows_evaluated


# ----------------------------------------------------- zero invocations ---
def test_indexed_decided_rows_invoke_zero_models(stream):
    """Rows fully decided at ingest scan with ZERO model invocations:
    no evaluated rows, no flushes, no ingest chunks."""
    frames, _, _, cascades, pipe = stream
    idx = pipe.index
    decided_all = np.ones(len(frames), bool)
    for c in cascades:
        decided_all &= idx.decided.column(c.key) >= 0
    rows = np.where(decided_all)[0]
    assert len(rows) > 4                        # scenario is non-trivial
    eng = ScanEngine(frames, chunk=32)
    idx.seed_store(eng.store, exact=True)
    res = eng.scan_rows(cascades, rows)
    assert res.stats.rows_evaluated == 0
    assert res.stats.chunks == 0
    assert all(s.batches == 0 for s in res.stats.stages)
    assert sum(s.rows_cached for s in res.stats.stages) >= len(rows)


def test_service_answers_ingest_indexed_rows_with_store_hits(stream):
    from repro.serve.batcher import Request
    from repro.serve.service import AsyncCascadeService

    frames, _, _, cascades, pipe = stream
    casc = cascades[0]
    col = pipe.index.decided.column(casc.key)
    rows = np.where(col >= 0)[0][:16]
    svc = AsyncCascadeService(frames, {"a": casc}, shards=2,
                              ingest_index=pipe.index, ingest_exact=True)
    reqs = [Request(rid=i, payload=int(r)) for i, r in enumerate(rows)]
    for r in reqs:
        svc.submit("a", r)
    # answered AT SUBMIT: store hits, no batches, labels match the index
    assert svc.stats["a"].store_hits == len(rows)
    assert svc.stats["a"].batches == 0
    assert svc.stats["a"].rows_evaluated == 0
    assert [r.result for r in reqs] == [int(v) for v in col[rows]]


# -------------------------------------------------------- planner seams ---
def test_plan_carries_index_and_explains_it(stream):
    from repro.core.selector import Selection

    frames, _, _, cascades, pipe = stream
    plan = PhysicalPlan("CAMERA", {}, [
        PlannedPredicate(c, Selection(0, 0.9, 100.0), "toy", 0.1)
        for c in cascades], index=pipe.index, index_mode="approx")
    txt = plan.explain(n_rows=len(frames))
    assert "ingest index:" in txt and "skip-aliased" in txt
    ids = np.arange(len(frames))
    assert np.array_equal(
        plan.index_prefilter(ids),
        pipe.index.survivors(ids, cascades, exact=False))
    # exact-fallback mode via indexed_execute: bit-identical to cold
    plan_exact = PhysicalPlan("CAMERA", {}, plan.predicates,
                              index=pipe.index, index_mode="exact")
    eng = ScanEngine(frames, chunk=32)
    res = indexed_execute(eng, plan_exact)
    assert np.array_equal(res.indices, _cold_rows(frames, cascades))


def test_plan_query_rejects_unknown_index_mode():
    from repro.engine.planner import QuerySpec, plan_query

    with pytest.raises(ValueError, match="index mode"):
        plan_query({}, QuerySpec(metadata_eq={}, predicates=[]),
                   index_mode="fuzzy")


def test_ingest_factory_builds_pipeline(stream):
    frames, _, _, cascades, _ = stream
    pipe = build_ingest_pipeline({c.concept: c for c in cascades},
                                 len(frames), chunk=32, skip=False)
    assert isinstance(pipe, IngestPipeline)
    assert [c.concept for c in pipe.cascades] == ["a", "b", "c"]


# ----------------------------------------------------------- persistence --
def test_virtual_column_store_roundtrip(tmp_path, stream):
    frames, _, _, cascades, pipe = stream
    token = corpus_token(frames)
    store = VirtualColumnStore(len(frames))
    pipe.index.seed_store(store, exact=True)
    p = tmp_path / "store.npz"
    store.save(p, token)
    back = VirtualColumnStore.load(p, token)
    assert back.n_rows == store.n_rows
    assert set(back.keys()) == set(store.keys())
    for k in store.keys():
        assert np.array_equal(back.column(k), store.column(k))  # bit-exact
    with pytest.raises(ValueError, match="different corpus"):
        VirtualColumnStore.load(p, corpus_token(frames[:-1]))


def test_repcache_roundtrip(tmp_path):
    imgs = _uint8_images(12, 32, seed=9)
    cache = RepresentationCache(1 << 20)
    cache.bind_corpus(corpus_token(imgs))
    rng = np.random.default_rng(0)
    for row in range(12):
        cache.put(row, 8, rng.random((8, 8, 3)).astype(np.float32))
    p = tmp_path / "repcache.npz"
    cache.save(p)
    back = RepresentationCache.load(p, corpus_token(imgs))
    assert len(back) == len(cache) and back.nbytes == cache.nbytes
    for row in range(12):
        assert np.array_equal(back.get(row, 8), cache.get(row, 8))
    with pytest.raises(ValueError, match="different corpus"):
        RepresentationCache.load(p, corpus_token(imgs[:-1]))
    # LRU order survives: the oldest entry is evicted first either way
    cache.put(99, 8, np.zeros((8, 8, 3), np.float32))
    back.put(99, 8, np.zeros((8, 8, 3), np.float32))
    assert list(cache._od) == list(back._od)


def test_candidate_index_roundtrip(tmp_path, stream):
    frames, _, _, cascades, pipe = stream
    token = corpus_token(frames)
    p = tmp_path / "index.npz"
    pipe.index.save(p, token)
    back = CandidateIndex.load(p, token)
    ids = np.arange(len(frames))
    for exact in (True, False):
        assert np.array_equal(back.survivors(ids, cascades, exact=exact),
                              pipe.index.survivors(ids, cascades,
                                                   exact=exact))
    for k in pipe.index.decided.keys():
        assert np.array_equal(back.decided.column(k),
                              pipe.index.decided.column(k))
    with pytest.raises(ValueError, match="different corpus"):
        CandidateIndex.load(p, corpus_token(frames[:-1]))


# ------------------------------------- selectivity-feedback bias (FIXED) --
def test_conditional_bias_provably_flips_ordering_legacy_vs_fixed():
    """THE regression the estimator fix is for (DESIGN.md §11.3):

    two correlated predicates, planned order [b, a]; costs equal; true
    marginals sel(b)=0.4, sel(a)=0.5, but P(a | b passes)=0.1. Stage-1
    flushes observe the CONDITIONAL 0.1. The legacy estimator adopted it
    as if marginal -> rank(a)=1/(1-0.1) beats rank(b)=1/(1-0.4) -> it
    flips to [a, b], whose true cost 1 + 0.5 = 1.5 is WORSE than the
    planned 1 + 0.4 = 1.4. The corrected estimator keeps conditional
    exposure out of refinement, so the planned (optimal) order stands."""
    b = _toy_cascade("b", 21)
    a = _toy_cascade("a", 22)
    b.cost_s, b.selectivity = 1.0, 0.4
    a.cost_s, a.selectivity = 1.0, 0.5
    true_marg = {b.key: 0.4, a.key: 0.5}
    cond_a = np.zeros(100, np.int64)
    cond_a[:10] = 1                       # P(a | b) = 0.1, n >= min_rows
    marg_b = np.zeros(100, np.int64)
    marg_b[:40] = 1                       # b's stage-0 marginal: no drift

    def run(legacy: bool):
        mon = OnlineReorderer([b, a], drift_threshold=0.1, min_rows=32)
        mon.observe(b.key, marg_b, marginal=True)
        # stage-1 flush of `a` sees only b-survivors; the legacy
        # estimator treated this as marginal exposure
        mon.observe(a.key, cond_a, marginal=legacy)
        return mon.propose([b, a])

    flipped = run(legacy=True)
    assert flipped == [1, 0]              # legacy: bias flips to [a, b]
    cost = [b.cost_s, a.cost_s]
    sels = [true_marg[b.key], true_marg[a.key]]
    assert expected_scan_cost(cost, sels, flipped) > \
        expected_scan_cost(cost, sels)    # ...which is provably worse
    assert run(legacy=False) is None      # fixed: planned order stands
    # the conditional exposure is still visible for introspection
    mon = OnlineReorderer([b, a], min_rows=32)
    mon.observe(a.key, cond_a, marginal=False)
    assert mon.conditional(a.key) == pytest.approx(0.1)
    assert mon.observed(a.key) is None


@pytest.mark.parametrize("shards", [0, 2])
def test_engines_flag_only_stage0_flushes_as_marginal(shards):
    """The engines' side of the contract: every observe() for the
    first-position cascade is marginal, every later-stage observe is
    conditional — serial and sharded (incl. the fused ingest path)."""
    class Recorder(OnlineReorderer):
        def __init__(self, cascades):
            super().__init__(cascades, drift_threshold=10.0)  # never fire
            self.seen = []

        def observe(self, key, labels, *, marginal=False):
            self.seen.append((key, marginal))
            super().observe(key, labels, marginal=marginal)

    imgs = _uint8_images(150, 32, seed=5)
    cascades = [_toy_cascade("a", 31), _toy_cascade("b", 32)]
    mon = Recorder(cascades)
    if shards:
        eng = ShardedScanEngine(imgs, shards=shards, chunk=32)
    else:
        eng = ScanEngine(imgs, chunk=32)
    eng.execute(cascades, monitor=mon)
    by_key = {c.key: {m for k, m in mon.seen if k == c.key}
              for c in cascades}
    assert by_key[cascades[0].key] == {True}
    assert by_key[cascades[1].key] == {False}
    # refinement uses only the marginal stream
    assert mon.observed(cascades[0].key) is not None
    assert mon.observed(cascades[1].key) is None
    assert mon.conditional(cascades[1].key) is not None
