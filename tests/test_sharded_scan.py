"""Sharded scan engine (DESIGN.md §9): the differential oracle — sharded
lockstep ≡ sharded serial ≡ single-shard ScanEngine ≡ naive_scan, for
every shard count / partitioning strategy / backend — plus ShardPlan
partition properties, store-merge semantics, and the invocation-counting
regression proving the sharded path never evaluates a row twice and the
merged store serves re-planned queries from cache."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pipeline import build_scan_engine
from repro.engine import ScanEngine, ShardedScanEngine, naive_scan
from repro.engine.scan import VirtualColumnStore
from repro.sharding.policy import ShardPlan, plan_shards
from test_query_engine import _toy_cascade, _uint8_images


@pytest.fixture(scope="module")
def setup():
    imgs = _uint8_images(210, 32, seed=4)
    cascades = [
        _toy_cascade("a", 1),
        _toy_cascade("b", 2, [(0.25, 0.75), (0.3, 0.7), (None, None)]),
        _toy_cascade("c", 3, [(0.2, 0.8), (0.35, 0.65), (None, None)]),
    ]
    metadata = {"cam": np.arange(len(imgs)) % 2,
                "rare": (np.arange(len(imgs)) < 5).astype(np.int64)}
    ref = naive_scan(imgs, cascades, metadata, {"cam": 0}, chunk=64)
    single = ScanEngine(imgs, metadata, chunk=64)
    sres = single.execute(cascades, {"cam": 0})
    assert np.array_equal(sres.indices, ref) and len(ref) > 0
    return imgs, cascades, metadata, ref, sres


# ----------------------------------------------- differential oracle ------
@pytest.mark.parametrize("shards", [1, 2, 3, 8])
@pytest.mark.parametrize("strategy", ["range", "hash"])
def test_sharded_differential_oracle(setup, shards, strategy):
    """Bit-identical row sets vs the naive per-predicate full scans and
    the single-shard engine, on both execution backends."""
    imgs, cascades, metadata, ref, _ = setup
    eng = ShardedScanEngine(imgs, metadata, shards=shards, chunk=64,
                            strategy=strategy)
    lock = eng.execute(cascades, {"cam": 0}, parallel=True)
    assert np.array_equal(lock.indices, ref), (shards, strategy)
    serial = ShardedScanEngine(imgs, metadata, shards=shards, chunk=64,
                               strategy=strategy).execute(
        cascades, {"cam": 0}, parallel=False)
    assert np.array_equal(serial.indices, ref), (shards, strategy)
    # the plan partitioned exactly the metadata survivors
    lock.stats.plan.validate(np.where(metadata["cam"] == 0)[0])


def test_shards_exceed_devices_and_uneven_partition(setup):
    """16 shards > 8 forced devices: the lockstep runs shard groups at
    device width; 210/16 is uneven; rows sets stay exact."""
    imgs, cascades, metadata, ref, _ = setup
    eng = ShardedScanEngine(imgs, metadata, shards=16, chunk=64)
    res = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(res.indices, ref)
    assert len(set(res.stats.plan.sizes)) > 1       # uneven by necessity


def test_empty_shards_and_shards_exceeding_survivors(setup):
    """5 surviving rows across 8 shards: some shards are empty, results
    exact, empty shards do zero work."""
    imgs, cascades, metadata, _, _ = setup
    ref = naive_scan(imgs, cascades, metadata, {"rare": 1}, chunk=64)
    eng = ShardedScanEngine(imgs, metadata, shards=8, chunk=64)
    res = eng.execute(cascades, {"rare": 1})
    assert np.array_equal(res.indices, ref)
    assert 0 in res.stats.plan.sizes
    for st, part in zip(res.stats.shards, res.stats.plan.shards):
        if not len(part):
            assert st.rows_evaluated == 0 and st.chunks == 0
    # no survivors at all
    none = eng.execute(cascades, {"cam": 99})
    assert len(none.indices) == 0 and none.stats.rows_evaluated == 0


def test_eager_backend_differential(setup):
    imgs, cascades, metadata, ref, _ = setup
    eng = ShardedScanEngine(imgs, metadata, shards=3, chunk=64, jit=False)
    assert np.array_equal(eng.execute(cascades, {"cam": 0}).indices, ref)


# ------------------------------------------------- ShardPlan properties ---
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 300), st.integers(1, 16),
       st.sampled_from(["range", "hash"]), st.booleans(),
       st.integers(0, 2 ** 31 - 1))
def test_shard_plan_is_exact_partition(n_rows, n_shards, strategy,
                                       weighted, seed):
    """Every row assigned exactly once; shards cover the survivor set."""
    rng = np.random.default_rng(seed)
    ids = np.sort(rng.choice(1000, size=n_rows, replace=False))
    weights = rng.uniform(0.0, 5.0, n_rows) if weighted else None
    plan = plan_shards(ids, n_shards, strategy=strategy, weights=weights)
    assert plan.n_shards == n_shards and len(plan.shards) == n_shards
    cat = np.concatenate([s for s in plan.shards]) if n_shards else ids
    assert len(cat) == n_rows                       # exactly once
    assert np.array_equal(np.sort(cat), ids)        # full cover
    for part in plan.shards:                        # sorted within shard
        assert np.array_equal(part, np.sort(part))
    plan.validate(ids)


def test_shard_plan_skew_aware_rebalancing():
    """Range partitioning splits on cumulative weight: a run of expensive
    rows lands in a smaller shard, balancing estimated cost not counts."""
    ids = np.arange(100)
    weights = np.where(ids < 10, 100.0, 1.0)
    plan = plan_shards(ids, 2, strategy="range", weights=weights)
    assert len(plan.shards[0]) < len(plan.shards[1])
    assert plan.balance < 1.2
    uniform = plan_shards(ids, 2, strategy="range")
    assert [len(s) for s in uniform.shards] == [50, 50]
    # weights stay paired with their rows when ids arrive unsorted
    perm = np.random.default_rng(0).permutation(100)
    shuffled = plan_shards(ids[perm], 2, strategy="range",
                           weights=weights[perm])
    for a, b in zip(shuffled.shards, plan.shards):
        assert np.array_equal(a, b)
    assert shuffled.weights == pytest.approx(plan.weights)


def test_shard_plan_hash_is_stable_and_rejects_bad_input():
    ids = np.arange(64)
    a = plan_shards(ids, 4, strategy="hash")
    b = plan_shards(ids, 4, strategy="hash")
    for x, y in zip(a.shards, b.shards):
        assert np.array_equal(x, y)                 # stationary across calls
    with pytest.raises(ValueError):
        plan_shards(ids, 0)
    with pytest.raises(ValueError):
        plan_shards(ids, 2, strategy="modulo")


# ------------------------------------------------ store merge semantics ---
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
def test_store_merge_union_never_overwrites(n_rows, seed):
    """Merged store == union of shard stores; a computed entry is never
    overwritten by -1 or by the source."""
    rng = np.random.default_rng(seed)
    dst = VirtualColumnStore(n_rows)
    src = VirtualColumnStore(n_rows)
    key = ("concept", (0, 1, 2))
    dst.column(key)[:] = rng.integers(-1, 2, n_rows)
    src.column(key)[:] = rng.integers(-1, 2, n_rows)
    src.column(("only-src", (9,)))[:] = rng.integers(-1, 2, n_rows)
    before = dst.column(key).copy()
    src_before = {k: src.column(k).copy() for k in src.keys()}
    dst.merge_from(src)
    computed = before >= 0
    assert np.array_equal(dst.column(key)[computed], before[computed])
    unknown = ~computed
    assert np.array_equal(dst.column(key)[unknown],
                          src.column(key)[unknown])
    only = dst.column(("only-src", (9,)))
    assert np.array_equal(only, src_before[("only-src", (9,))])
    for k in src.keys():                            # source untouched
        assert np.array_equal(src.column(k), src_before[k])


def test_merged_store_equals_union_of_shard_work(setup):
    """After a fresh sharded scan, the corpus-wide store holds exactly
    one computed label per (cascade, evaluated row): known rows per
    column == rows evaluated at that stage across shards (no duplicates,
    nothing lost in the merge)."""
    imgs, cascades, metadata, _, _ = setup
    eng = ShardedScanEngine(imgs, metadata, shards=3, chunk=64)
    res = eng.execute(cascades, {"cam": 0})
    for casc, agg in zip(cascades, res.stats.stages):
        assert eng.store.known_rows(casc.key) == agg.rows_evaluated
        assert agg.rows_evaluated == agg.rows_in - agg.rows_cached


# ------------------------------- invocation counting / cache regression ---
def _counting_cascade(concept, seed, counters, thresholds=None):
    casc = _toy_cascade(concept, seed, thresholds)
    wrapped = []
    for li, fn in enumerate(casc.model_fns):
        def make(li, fn):
            def f(x):
                counters[concept][li] += 1
                return fn(x)
            return f
        wrapped.append(make(li, fn))
    casc.model_fns = wrapped
    return casc


def test_sharded_no_duplicate_evaluations_and_cache_hits(setup):
    """The PR-2 executor-invocation-counting pattern, sharded: per-stage
    evaluated rows match the single-shard engine exactly (each surviving
    row evaluated once, on one shard), a same-order re-run invokes the
    models ZERO times, and a re-planned (reversed) query is served
    partially from the merged store."""
    imgs, _, metadata, ref, sres = setup
    counters = {c: [0, 0, 0] for c in "abc"}
    cascades = [
        _counting_cascade("a", 1, counters),
        _counting_cascade("b", 2, counters,
                          [(0.25, 0.75), (0.3, 0.7), (None, None)]),
        _counting_cascade("c", 3, counters,
                          [(0.2, 0.8), (0.35, 0.65), (None, None)]),
    ]
    eng = ShardedScanEngine(imgs, metadata, shards=3, chunk=64, jit=False)
    res = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(res.indices, ref)
    # per-stage totals identical to the single-shard engine: a row is
    # evaluated exactly once, on exactly one shard
    for agg, st_single in zip(res.stats.stages, sres.stats.stages):
        assert agg.rows_evaluated == st_single.rows_evaluated
        assert agg.rows_in == st_single.rows_in
    calls_after_first = {c: list(v) for c, v in counters.items()}
    assert all(v[0] > 0 for v in calls_after_first.values())

    # identical re-run: answered entirely by the merged store — the
    # models are never invoked
    again = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(again.indices, ref)
    assert again.stats.rows_evaluated == 0
    assert counters == calls_after_first
    assert all(st.rows_cached == st.rows_in for st in again.stats.stages)

    # re-planned (reversed) query on a DIFFERENT shard count: merged
    # store serves every previously-decided row; only the complement of
    # rows that earlier predicates had eliminated is evaluated
    eng2 = ShardedScanEngine(imgs, metadata, shards=8, chunk=64,
                             jit=False)
    eng2.store.merge_from(eng.store)
    rres = eng2.execute(cascades[::-1], {"cam": 0})
    assert np.array_equal(rres.indices, ref)
    assert sum(st.rows_cached for st in rres.stats.stages) > 0
    assert rres.stats.rows_evaluated < res.stats.rows_evaluated


# --------------------------------------------------- planner + factory ----
def test_explain_reports_shard_layout(setup):
    from repro.engine.planner import PhysicalPlan, PlannedPredicate
    from repro.core.selector import Selection

    imgs, cascades, metadata, _, _ = setup
    eng = ShardedScanEngine(imgs, metadata, shards=4, chunk=64)
    shard_plan = eng.plan_for(cascades, {"cam": 0})
    plan = PhysicalPlan("CAMERA", {"cam": 0}, [
        PlannedPredicate(c, Selection(0, 0.9, 100.0), "toy", 0.1)
        for c in cascades])
    txt = plan.explain(n_rows=len(imgs), shard_plan=shard_plan)
    assert "sharding: 4 shards (range)" in txt
    for i in range(4):
        assert f"shard {i}:" in txt
    assert "balance=" in txt


def test_build_scan_engine_factory(setup):
    imgs, cascades, metadata, ref, _ = setup
    assert isinstance(build_scan_engine(imgs, metadata), ScanEngine)
    sharded = build_scan_engine(imgs, metadata, shards=2, chunk=64)
    assert isinstance(sharded, ShardedScanEngine)
    assert np.array_equal(sharded.execute(cascades, {"cam": 0}).indices,
                          ref)
    one = build_scan_engine(imgs, metadata, shards=1, chunk=64)
    assert isinstance(one, ShardedScanEngine)       # scaling-curve baseline


# ---------------------------------------------------------- multidevice ---
@pytest.mark.multidevice
def test_lockstep_spreads_over_distinct_devices(setup):
    """With the conftest-forced 8 host devices, the lockstep runs one
    shard per device (distinct devices, width > 1) and stays exact."""
    import jax

    from repro.launch.mesh import host_device_count, shard_devices

    assert host_device_count() == jax.device_count() > 1
    n = host_device_count()
    devs = shard_devices(n)
    assert len(set(devs)) == n
    imgs, cascades, metadata, ref, _ = setup
    eng = ShardedScanEngine(imgs, metadata, shards=n, chunk=64)
    res = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(res.indices, ref)
    assert res.stats.n_devices == n
    assert res.stats.backend == "lockstep" and res.stats.supersteps > 0


@pytest.mark.multidevice
def test_round_robin_when_shards_exceed_devices():
    from repro.launch.mesh import host_device_count, shard_devices
    n = host_device_count()
    devs = shard_devices(n + 3)
    assert len(devs) == n + 3
    assert devs[n] == devs[0] and devs[n + 1] == devs[1]
