import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.pareto import dominates, is_frontier, pareto_indices


def brute_force(acc, thr):
    pts = list(zip(acc, thr))
    out = []
    for i, p in enumerate(pts):
        if not any(dominates(q, p) for j, q in enumerate(pts) if j != i):
            out.append(i)
    return out


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0.01, 100)),
                min_size=1, max_size=60))
def test_frontier_nondominated(points):
    acc = np.array([p[0] for p in points])
    thr = np.array([p[1] for p in points])
    idx = pareto_indices(acc, thr)
    assert len(idx) >= 1
    for i in idx:
        assert is_frontier(acc, thr, int(i))
    # every excluded point is dominated or a duplicate of a frontier point
    fr = {(acc[i], thr[i]) for i in idx}
    for j in range(len(points)):
        if j not in set(idx.tolist()):
            p = (acc[j], thr[j])
            assert p in fr or any(
                dominates((acc[i], thr[i]), p) for i in idx)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0.01, 100)),
                min_size=2, max_size=40))
def test_adding_dominated_point_keeps_frontier(points):
    acc = np.array([p[0] for p in points])
    thr = np.array([p[1] for p in points])
    idx = pareto_indices(acc, thr)
    # add a clearly dominated point
    k = int(idx[0])
    acc2 = np.append(acc, acc[k] * 0.5)
    thr2 = np.append(thr, thr[k] * 0.5)
    idx2 = pareto_indices(acc2, thr2)
    assert {(acc[i], thr[i]) for i in idx} == \
        {(acc2[i], thr2[i]) for i in idx2}


def test_matches_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(20):
        acc = rng.random(30)
        thr = rng.random(30) * 10
        fast = {(acc[i], thr[i]) for i in pareto_indices(acc, thr)}
        slow = {(acc[i], thr[i]) for i in brute_force(acc, thr)}
        assert fast == slow
