"""End-to-end behaviour tests for the paper's system: initialize TAHOMA on
a synthetic predicate, verify the paper's qualitative claims at mini scale,
and run a content-based query through a selected cascade."""
import numpy as np
import pytest

from repro.configs.base import TahomaCNNConfig
from repro.core.pipeline import initialize_system
from repro.core.query import BinaryPredicate, Corpus, run_query
from repro.core.selector import pareto_set, select
from repro.core.transforms import representation_space
from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,
                                  three_way_split)


@pytest.fixture(scope="module")
def system():
    spec = DEFAULT_PREDICATES[1]  # ferret: needs resolution, gray-friendly
    x, y = make_corpus(spec, 420, hw=32, seed=0)
    splits = three_way_split(x, y, seed=1)
    archs = [TahomaCNNConfig(1, 8, 16), TahomaCNNConfig(2, 16, 16)]
    reps = representation_space([8, 16, 32], ("rgb", "g", "gray"))
    sys_ = initialize_system(*splits, archs, reps, steps=150)
    return sys_, splits, spec


def test_models_learn(system):
    sys_, splits, spec = system
    accs = ((sys_.eval_scores >= 0.5) == sys_.eval_truth[None]).mean(1)
    assert accs.max() > 0.85, accs.max()
    # trusted model is competitive
    assert accs[sys_.bank.trusted_index] > 0.8


def test_pareto_and_selection(system):
    sys_, _, _ = system
    space = sys_.cascade_space("CAMERA")
    par = pareto_set(space)
    assert 1 <= len(par) <= 200
    sel = select(space, min_accuracy=0.8)
    assert sel.accuracy >= 0.8
    # fastest-qualifying semantics: no Pareto point with acc>=0.8 is faster
    for i in par:
        if space.acc[i] >= 0.8:
            assert space.throughput[i] <= sel.throughput + 1e-9


def test_cascades_beat_trusted_model(system):
    """Paper Fig. 6: at the trusted model's accuracy, an optimal cascade is
    faster than the trusted model alone (INFER_ONLY)."""
    sys_, _, _ = system
    space = sys_.cascade_space("INFER_ONLY")
    ti = sys_.bank.trusted_index
    t_acc = space.acc[ti]
    t_thr = space.throughput[ti]
    from repro.core.alc import best_matching
    j = best_matching(space.acc, space.throughput, t_acc)
    assert j is not None
    assert space.throughput[j] > t_thr  # strictly faster at >= accuracy


def test_scenario_awareness_never_hurts(system):
    """Table III's property: cascades chosen with scenario-aware costs give
    >= throughput than cascades chosen obliviously then deployed in the
    scenario."""
    sys_, _, _ = system
    oblivious = sys_.cascade_space("INFER_ONLY")
    for scen in ("CAMERA", "ARCHIVE", "ONGOING"):
        aware = sys_.cascade_space(scen)
        for floor in (0.75, 0.85):
            if aware.acc.max() < floor:
                continue
            aw = select(aware, min_accuracy=floor)
            ob = select(oblivious, min_accuracy=floor)
            # deploy the obliviously-chosen cascade under the true scenario
            ob_true_thr = aware.throughput[ob.index]
            assert aw.throughput >= ob_true_thr - 1e-9


def test_end_to_end_query(system):
    sys_, splits, spec = system
    (_, _), (_, _), (ev_x, ev_y) = splits
    space = sys_.cascade_space("CAMERA")
    sel = select(space, min_accuracy=0.85) if space.acc.max() >= 0.85 \
        else select(space)
    from repro.core.cascade import spec_levels
    levels = spec_levels(space, sel.index, sys_.p_low, sys_.p_high)

    def executor(imgs):
        import jax.numpy as jnp
        from repro.core.transforms import apply_transform
        from repro.models.cnn import cnn_predict_proba
        out = np.full(len(imgs), -1, np.int32)
        active = np.ones(len(imgs), bool)
        for m, lo, hi in levels:
            e = sys_.bank.entries[m]
            scores = np.asarray(cnn_predict_proba(
                e.params, apply_transform(jnp.asarray(imgs), e.rep)))
            if lo is None:
                out[active] = (scores >= 0.5)[active]
                active[:] = False
            else:
                dec = active & ((scores <= lo) | (scores >= hi))
                out[dec] = (scores >= hi)[dec]
                active &= ~dec
        return out

    corpus = Corpus(images=ev_x,
                    metadata={"cam": np.arange(len(ev_x)) % 3})
    ids = run_query(corpus, metadata_eq={"cam": 0},
                    binary_preds=[BinaryPredicate(spec.name, executor)])
    # query respects metadata filter
    assert all(i % 3 == 0 for i in ids)
    # and the returned set is mostly true positives
    if len(ids):
        assert ev_y[ids].mean() > 0.7
