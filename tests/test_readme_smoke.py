"""README quickstart must keep working VERBATIM: the commands are parsed
out of README.md's Quickstart section and executed exactly as written,
so editing the README without updating the examples (or vice versa)
fails CI instead of rotting silently. The headline-results table is
held to the same standard: every quoted figure is parsed out of its
row and checked against the committed ``BENCH_*.json`` artifact within
a pinned tolerance, so the README can't drift from the measurements it
cites.

The tier-1 verify command in the README is asserted to match
ROADMAP.md's canonical line rather than executed — running the full
suite from inside the suite would recurse."""
import json
import os
import re
import subprocess
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
README = ROOT / "README.md"


def _quickstart_commands() -> list[str]:
    """Command lines of the FIRST fenced ```bash block after the
    '## Quickstart' heading."""
    text = README.read_text()
    m = re.search(r"^## Quickstart\n(.*?)(?=^## )", text,
                  re.DOTALL | re.MULTILINE)
    assert m, "README.md lost its '## Quickstart' section"
    block = re.search(r"```bash\n(.*?)```", m.group(1), re.DOTALL)
    assert block, "README Quickstart lost its ```bash command block"
    cmds = [ln.strip() for ln in block.group(1).splitlines()
            if ln.strip() and not ln.strip().startswith("#")]
    assert cmds, "README Quickstart bash block is empty"
    return cmds


def test_readme_exists_with_required_sections():
    text = README.read_text()
    for heading in ("## Architecture map", "## Quickstart",
                    "## Headline results"):
        assert heading in text, heading
    # every BENCH artifact the results table cites must exist
    for name in re.findall(r"`(BENCH_\w+\.json)`", text):
        assert (ROOT / name).exists(), name


def test_readme_tier1_command_matches_roadmap():
    """The README's verify command is ROADMAP.md's canonical tier-1 line
    (checked verbatim; executing it here would recurse the suite)."""
    roadmap = (ROOT / "ROADMAP.md").read_text()
    m = re.search(r"\*\*Tier-1 verify:\*\* `([^`]+)`", roadmap)
    assert m, "ROADMAP.md lost its tier-1 verify line"
    assert m.group(1) in README.read_text()


# ------------------------------------------------- headline figures ------
def _bench(name: str) -> dict:
    return json.loads((ROOT / name).read_text())


def _row(label: str) -> str:
    """The headline-results table row containing ``label``."""
    for ln in README.read_text().splitlines():
        if ln.startswith("|") and label in ln:
            return ln
    raise AssertionError(f"README results table lost its {label!r} row")


def _fig(row: str, pattern: str) -> float:
    """First capture group of ``pattern`` in the row, as float."""
    m = re.search(pattern, row)
    assert m, f"figure /{pattern}/ not found in row: {row}"
    return float(m.group(1))


def test_readme_figures_query_engine():
    qe = _bench("BENCH_query_engine.json")
    row = _row("Multi-predicate query engine")
    assert qe["speedup_min_x"] >= _fig(row, r">(\d+(?:\.\d+)?)x end")
    assert qe["all_identical"]
    row = _row("Joint vs independent")
    m = re.search(r"(\d+\.\d+)–(\d+\.\d+)x end-to-end", row)
    assert m, row
    lo, hi = float(m.group(1)), float(m.group(2))
    assert lo - 0.05 <= qe["joint_speedup_min_x"] <= hi + 0.05
    assert qe["joint_all_identical_vs_own_naive"]


def test_readme_figures_sharded_and_serving():
    sh = _bench("BENCH_sharded_scan.json")
    row = _row("Sharded scan")
    assert sh["throughput_scaling_x"] == pytest.approx(
        _fig(row, r"~(\d+(?:\.\d+)?)x row-throughput"), rel=0.15)
    assert sh["all_identical"]
    sv = _bench("BENCH_serve.json")
    row = _row("Async serving")
    assert sv["speedup_8dev_x"] == pytest.approx(
        _fig(row, r"(\d+\.\d+)x request throughput"), rel=0.01)
    assert sv["all_identical"]


def test_readme_figures_cascade_eval_and_fused():
    ce = _bench("BENCH_cascade_eval.json")
    row = _row("Cascade-space evaluation")
    assert ce["eval"]["grid_large"]["n_cascades"] == pytest.approx(
        _fig(row, r"(\d+)M cascades") * 1e6, rel=0.05)
    assert ce["eval"]["end_to_end_speedup_x"] == pytest.approx(
        _fig(row, r"(\d+\.\d+)x end-to-end"), rel=0.05)
    assert ce["eval"]["streaming_large_grid"]["total_s"] == pytest.approx(
        _fig(row, r"~(\d+)s streaming"), rel=0.15)
    assert ce["transform"]["speedup"] == pytest.approx(
        _fig(row, r"(\d+\.\d+)x transform"), rel=0.02)
    fu = _bench("BENCH_fused_scan.json")
    row = _row("Fused + lazy hot path")
    assert fu["hotpath_speedup_x"] == pytest.approx(
        _fig(row, r"(\d+\.\d+)x per-chunk"), rel=0.01)
    assert fu["hotpath_stress"]["lazy_level_rows_saved_x"] == \
        pytest.approx(_fig(row, r"(\d+\.\d+)x fewer level-rows"), rel=0.01)
    assert fu["all_identical"]


def test_readme_figures_overload():
    ov = _bench("BENCH_overload.json")
    row = _row("Overload hardening")
    deg = next(p for p in ov["curves"]["degrade"] if p["load_x"] == 4.0)
    shed = next(p for p in ov["curves"]["shed"] if p["load_x"] == 4.0)
    assert 100 * deg["goodput_rps"] / deg["offered_rps"] == \
        pytest.approx(_fig(row, r"~(\d+)% of offered load"), abs=2.0)
    assert deg["p99_ms"] == pytest.approx(
        _fig(row, r"p99 ~(\d+)ms"), rel=0.05)
    assert 100 * shed["shed_rate"] == pytest.approx(
        _fig(row, r"sheds (\d+)%"), abs=2.0)
    assert shed["p99_ms"] == pytest.approx(
        _fig(row, r"p99 bounded ~(\d+)ms"), rel=0.10)
    assert ov["subsat_identical"]


def test_readme_figures_algebra():
    al = _bench("BENCH_algebra.json")
    row = _row("Query algebra")
    assert al["tree"]["speedup_vs_unoptimized_x"] == pytest.approx(
        _fig(row, r"(\d+\.\d+)x vs the same tree unoptimized"), rel=0.01)
    assert al["tree"]["speedup_vs_naive_x"] == pytest.approx(
        _fig(row, r"(\d+\.\d+)x vs naive"), rel=0.01)
    assert al["join"]["speedup_pushdown_x"] == pytest.approx(
        _fig(row, r"pushdown (\d+\.\d+)x"), rel=0.01)
    assert al["tree"]["rows_identical"] and al["join"]["pairs_identical"]
    # the acceptance floor the PR ships under: the rewrites must WIN
    assert al["tree"]["speedup_vs_unoptimized_x"] > 1.0
    assert al["join"]["speedup_pushdown_x"] > 1.0


def test_readme_figures_ingest():
    ig = _bench("BENCH_ingest.json")
    row = _row("Ingest-time indexing")
    assert ig["invocations_eliminated_approx_pct"] == pytest.approx(
        _fig(row, r"(\d+)% of query-time model invocations"), abs=2.0)
    assert ig["approx_recall_vs_cold"] == pytest.approx(
        _fig(row, r"recall (\d+\.\d+)"), abs=0.02)
    assert ig["invocations_eliminated_exact_pct"] == pytest.approx(
        _fig(row, r"exact mode still removes (\d+)%"), abs=2.0)
    assert ig["exact_identical"]
    # the acceptance floor the PR ships under
    assert ig["invocations_eliminated_approx_pct"] >= 50.0


@pytest.mark.parametrize("cmd", _quickstart_commands(),
                         ids=lambda c: c.split("examples/")[-1].split()[0])
def test_readme_quickstart_commands_run_verbatim(cmd):
    env = dict(os.environ)
    # the README says 'PYTHONPATH=src python ...'; run it through a
    # shell from the repo root, exactly as a new user would
    r = subprocess.run(cmd, shell=True, cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=540,
                       executable="/bin/bash")
    assert r.returncode == 0, \
        f"README quickstart command failed: {cmd}\n" \
        f"{r.stdout[-2000:]}\n{r.stderr[-3000:]}"
