"""README quickstart must keep working VERBATIM: the commands are parsed
out of README.md's Quickstart section and executed exactly as written,
so editing the README without updating the examples (or vice versa)
fails CI instead of rotting silently.

The tier-1 verify command in the README is asserted to match
ROADMAP.md's canonical line rather than executed — running the full
suite from inside the suite would recurse."""
import os
import re
import subprocess
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
README = ROOT / "README.md"


def _quickstart_commands() -> list[str]:
    """Command lines of the FIRST fenced ```bash block after the
    '## Quickstart' heading."""
    text = README.read_text()
    m = re.search(r"^## Quickstart\n(.*?)(?=^## )", text,
                  re.DOTALL | re.MULTILINE)
    assert m, "README.md lost its '## Quickstart' section"
    block = re.search(r"```bash\n(.*?)```", m.group(1), re.DOTALL)
    assert block, "README Quickstart lost its ```bash command block"
    cmds = [ln.strip() for ln in block.group(1).splitlines()
            if ln.strip() and not ln.strip().startswith("#")]
    assert cmds, "README Quickstart bash block is empty"
    return cmds


def test_readme_exists_with_required_sections():
    text = README.read_text()
    for heading in ("## Architecture map", "## Quickstart",
                    "## Headline results"):
        assert heading in text, heading
    # every BENCH artifact the results table cites must exist
    for name in re.findall(r"`(BENCH_\w+\.json)`", text):
        assert (ROOT / name).exists(), name


def test_readme_tier1_command_matches_roadmap():
    """The README's verify command is ROADMAP.md's canonical tier-1 line
    (checked verbatim; executing it here would recurse the suite)."""
    roadmap = (ROOT / "ROADMAP.md").read_text()
    m = re.search(r"\*\*Tier-1 verify:\*\* `([^`]+)`", roadmap)
    assert m, "ROADMAP.md lost its tier-1 verify line"
    assert m.group(1) in README.read_text()


@pytest.mark.parametrize("cmd", _quickstart_commands(),
                         ids=lambda c: c.split("examples/")[-1].split()[0])
def test_readme_quickstart_commands_run_verbatim(cmd):
    env = dict(os.environ)
    # the README says 'PYTHONPATH=src python ...'; run it through a
    # shell from the repo root, exactly as a new user would
    r = subprocess.run(cmd, shell=True, cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=540,
                       executable="/bin/bash")
    assert r.returncode == 0, \
        f"README quickstart command failed: {cmd}\n" \
        f"{r.stdout[-2000:]}\n{r.stderr[-3000:]}"
