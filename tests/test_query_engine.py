"""Query planner + unified scan engine (DESIGN.md §4): predicate
ordering must match the brute-force-optimal ordering, the engine's row
set must be bit-identical to naive per-predicate full scans, partial
virtual columns must eliminate re-evaluation, and run_query must never
evaluate a binary predicate on rows already eliminated."""
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeSpace, KIND_SINGLE
from repro.core.query import BinaryPredicate, Corpus, run_query
from repro.core.selector import cascade_eval_labels, estimate_selectivity
from repro.core.transforms import Representation
from repro.engine.planner import (PhysicalPlan, PredicateClause, QuerySpec,
                                  expected_scan_cost, order_predicates,
                                  plan_query)
from repro.engine.scan import (CompiledCascade, ScanEngine, naive_scan)


def _uint8_images(n, hw, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 256, (n, hw, hw, 3))
            .astype(np.float32) / 256.0)


def _toy_cascade(concept, seed, thresholds=None, hw=32):
    """3-level linear toy cascade with spread (sigmoid) scores so all
    levels see traffic and selectivity is non-trivial."""
    r = np.random.default_rng(seed)
    reps = [Representation(hw // 4, "gray"), Representation(hw // 2, "r"),
            Representation(hw, "rgb")]
    dims = [(hw // 4) ** 2, (hw // 2) ** 2, hw * hw * 3]
    ws = [jnp.asarray(r.standard_normal((d, 1)).astype(np.float32))
          for d in dims]

    def mk(i):
        def f(x):
            z = (x.reshape(x.shape[0], -1) - 0.5) @ ws[i]
            return jax.nn.sigmoid(z[:, 0] * 60.0 / math.sqrt(dims[i]))
        return f
    ths = thresholds or [(0.2, 0.8), (0.3, 0.7), (None, None)]
    return CompiledCascade(concept, ("toy", seed), reps,
                           [mk(0), mk(1), mk(2)], list(ths))


# ----------------------------------------------------------- ordering -----
def test_order_predicates_matches_brute_force():
    rng = np.random.default_rng(0)
    for trial in range(30):
        k = int(rng.integers(2, 5))
        costs = rng.uniform(0.1, 10.0, k)
        sels = rng.uniform(0.05, 0.95, k)
        best = min(itertools.permutations(range(k)),
                   key=lambda p: expected_scan_cost(costs, sels, p))
        got = order_predicates(costs, sels)
        assert math.isclose(expected_scan_cost(costs, sels, got),
                            expected_scan_cost(costs, sels, best),
                            rel_tol=1e-12), (trial, got, best)


def test_order_predicates_edge_cases():
    # selectivity 1.0 (filters nothing) goes last regardless of cost
    order = order_predicates([0.001, 5.0], [1.0, 0.5])
    assert order == [1, 0]
    # equal ranks tie-break by cost
    order = order_predicates([2.0, 1.0], [0.5, 0.5])
    assert order == [1, 0]


def test_expected_scan_cost_masks_later_predicates():
    # second predicate only pays on the first one's survivors
    assert expected_scan_cost([1.0, 1.0], [0.25, 0.5]) == 1.25


# ------------------------------------------------- selectivity estimate ---
def _single_space(n_models, times):
    return CascadeSpace(
        acc=np.linspace(0.5, 0.9, n_models),
        time_s=np.asarray(times, np.float64),
        kind=np.full(n_models, KIND_SINGLE, np.int8),
        i1=np.arange(n_models, dtype=np.int32),
        i2=np.full(n_models, -1, np.int32),
        n_targets=1, trusted=n_models - 1, evaluated=n_models)


def test_estimate_selectivity_single_model():
    scores = np.array([[0.9, 0.1, 0.8, 0.2, 0.6]])
    space = _single_space(1, [1.0])
    p_low = np.zeros((1, 1))
    p_high = np.ones((1, 1))
    labels = cascade_eval_labels(space, 0, scores, p_low, p_high)
    assert (labels == (scores[0] >= 0.5)).all()
    assert estimate_selectivity(space, 0, scores, p_low, p_high) == 0.6


# ------------------------------------------------------- scan engine ------
@pytest.fixture(scope="module")
def toy_setup():
    imgs = _uint8_images(210, 32, seed=4)
    cascades = [
        _toy_cascade("a", 1),
        _toy_cascade("b", 2, [(0.25, 0.75), (0.3, 0.7), (None, None)]),
        _toy_cascade("c", 3, [(0.2, 0.8), (0.35, 0.65), (None, None)]),
    ]
    metadata = {"cam": np.arange(len(imgs)) % 2}
    return imgs, cascades, metadata


def test_engine_bit_identical_to_naive_full_scan(toy_setup):
    imgs, cascades, metadata = toy_setup
    for k in (2, 3):
        eng = ScanEngine(imgs, metadata, chunk=64)
        res = eng.execute(cascades[:k], {"cam": 0})
        ref = naive_scan(imgs, cascades[:k], metadata, {"cam": 0},
                         chunk=64)
        assert np.array_equal(res.indices, ref), k
        assert len(ref) > 0          # non-degenerate query


def test_engine_chunk_size_does_not_change_rows(toy_setup):
    imgs, cascades, metadata = toy_setup
    outs = []
    for chunk in (32, 64, 128):
        eng = ScanEngine(imgs, metadata, chunk=chunk)
        outs.append(eng.execute(cascades, {"cam": 0}).indices)
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


def test_engine_masking_skips_eliminated_rows(toy_setup):
    """The core regression: predicate k+1 must evaluate ONLY predicate
    k's survivors (plus nothing when metadata kills a row)."""
    imgs, cascades, metadata = toy_setup
    eng = ScanEngine(imgs, metadata, chunk=64)
    res = eng.execute(cascades, {"cam": 0})
    st = res.stats.stages
    n_meta = int((metadata["cam"] == 0).sum())
    assert res.stats.rows_scanned == n_meta
    assert st[0].rows_evaluated == n_meta
    # survivors shrink monotonically and stage k+1 never sees more rows
    # than stage k passed
    col0 = eng.store.column(cascades[0].key)
    assert st[1].rows_in == int((col0[metadata["cam"] == 0] == 1).sum())
    assert st[1].rows_evaluated == st[1].rows_in
    assert st[2].rows_in < st[1].rows_in < st[0].rows_in


def test_engine_virtual_column_cache(toy_setup):
    imgs, cascades, metadata = toy_setup
    eng = ScanEngine(imgs, metadata, chunk=64)
    first = eng.execute(cascades, {"cam": 0})
    # identical re-run: zero evaluation, pure cache hits
    again = eng.execute(cascades, {"cam": 0})
    assert np.array_equal(again.indices, first.indices)
    assert again.stats.rows_evaluated == 0
    assert all(s.rows_cached == s.rows_in for s in again.stats.stages)
    # re-planned (reversed) order: only the complement is evaluated
    rev = eng.execute(cascades[::-1], {"cam": 0})
    assert np.array_equal(rev.indices, first.indices)
    assert rev.stats.rows_evaluated < first.stats.rows_evaluated
    assert sum(s.rows_cached for s in rev.stats.stages) > 0
    # widened query (drop the metadata filter): prior rows reused
    wide = eng.execute(cascades)
    ref = naive_scan(imgs, cascades, metadata, None, chunk=64)
    assert np.array_equal(wide.indices, ref)
    assert wide.stats.stages[0].rows_cached == first.stats.rows_scanned


def test_engine_no_binary_predicates(toy_setup):
    imgs, _, metadata = toy_setup
    eng = ScanEngine(imgs, metadata, chunk=64)
    res = eng.execute([], {"cam": 1})
    assert np.array_equal(res.indices, np.where(metadata["cam"] == 1)[0])


def test_engine_ignores_serving_capacities(toy_setup):
    """Capacity-capped levels force overflow rows to batch-packing-
    dependent labels — a serving-only tradeoff. Scan paths must ignore
    casc.capacities (full-width levels) so row sets stay exact and
    virtual columns cacheable."""
    import dataclasses

    imgs, cascades, metadata = toy_setup
    capped = [dataclasses.replace(c, capacities=[4, 2]) for c in cascades]
    eng = ScanEngine(imgs, metadata, chunk=64)
    want = ScanEngine(imgs, metadata, chunk=64).execute(
        cascades, {"cam": 0}).indices
    res = eng.execute(capped, {"cam": 0})
    ref = naive_scan(imgs, capped, metadata, {"cam": 0}, chunk=64)
    assert np.array_equal(res.indices, want)
    assert np.array_equal(res.indices, ref)


def test_engine_empty_metadata_survivors(toy_setup):
    imgs, cascades, metadata = toy_setup
    eng = ScanEngine(imgs, metadata, chunk=64)
    res = eng.execute(cascades, {"cam": 99})
    assert len(res.indices) == 0
    assert res.stats.rows_evaluated == 0


def test_executor_caller_provided_pyramid_bit_identical(toy_setup):
    """run_cascade_batch with a pre-materialized pyramid_cache (the
    engine's shared-pyramid path) must reproduce the self-derived path
    bit-for-bit."""
    from repro.core.executor import run_cascade_batch
    from repro.core.transforms import materialize_pyramid

    imgs, cascades, _ = toy_setup
    casc = cascades[0]
    batch = jnp.asarray(imgs[:64])
    caps = [64, 64]
    l1, s1 = run_cascade_batch(batch, casc.model_fns, casc.thresholds,
                               casc.reps, caps)
    pyr = materialize_pyramid(batch, casc.resolutions)
    l2, s2 = run_cascade_batch(batch, casc.model_fns, casc.thresholds,
                               casc.reps, caps, pyramid_cache=pyr)
    assert (np.asarray(l1) == np.asarray(l2)).all()
    assert (np.asarray(s1["levels_used"])
            == np.asarray(s2["levels_used"])).all()


# ------------------------------------------------------ run_query fix -----
class _CountingExecutor:
    def __init__(self, fn):
        self.fn = fn
        self.calls = 0
        self.rows = 0

    def __call__(self, imgs):
        self.calls += 1
        self.rows += len(imgs)
        return self.fn(imgs)


def test_run_query_skips_eliminated_rows():
    """Regression (pre-refactor bug): binary predicates ran a FULL corpus
    scan regardless of the metadata filter and earlier predicates."""
    n, batch = 96, 16
    imgs = _uint8_images(n, 16, seed=1)
    meta = {"cam": np.arange(n) % 4}           # filter keeps n/4 rows
    ex1 = _CountingExecutor(
        lambda im: (im.mean(axis=(1, 2, 3)) > 0.5).astype(np.int32))
    ex2 = _CountingExecutor(
        lambda im: (im[:, 0, 0, 0] > 0.5).astype(np.int32))
    corpus = Corpus(images=imgs, metadata=meta)
    ids = run_query(corpus, metadata_eq={"cam": 0},
                    binary_preds=[BinaryPredicate("p1", ex1),
                                  BinaryPredicate("p2", ex2)],
                    batch_size=batch)
    n_meta = n // 4
    assert ex1.calls == math.ceil(n_meta / batch)
    assert ex1.rows == ex1.calls * batch       # padded batches only
    # second predicate saw only the first predicate's survivors
    col1 = corpus.virtual_columns["p1"]
    n_surv = int((col1[meta["cam"] == 0] == 1).sum())
    assert ex2.calls == math.ceil(n_surv / batch)
    # results match the brute-force reference
    brute = np.where((meta["cam"] == 0)
                     & (imgs.mean(axis=(1, 2, 3)) > 0.5)
                     & (imgs[:, 0, 0, 0] > 0.5))[0]
    assert np.array_equal(ids, brute)
    # repeated query: fully answered from the partial virtual columns
    ids2 = run_query(corpus, metadata_eq={"cam": 0},
                     binary_preds=[BinaryPredicate("p1", ex1),
                                   BinaryPredicate("p2", ex2)],
                     batch_size=batch)
    assert np.array_equal(ids2, ids)
    assert ex1.calls == math.ceil(n_meta / batch)   # unchanged


def test_run_query_partial_columns_extend():
    """A wider follow-up query evaluates only the not-yet-known rows."""
    n, batch = 64, 16
    imgs = _uint8_images(n, 16, seed=2)
    meta = {"cam": np.arange(n) % 2}
    ex = _CountingExecutor(
        lambda im: (im.mean(axis=(1, 2, 3)) > 0.5).astype(np.int32))
    corpus = Corpus(images=imgs, metadata=meta)
    run_query(corpus, metadata_eq={"cam": 0},
              binary_preds=[BinaryPredicate("p", ex)], batch_size=batch)
    rows_first = ex.rows
    run_query(corpus, binary_preds=[BinaryPredicate("p", ex)],
              batch_size=batch)
    # second (unfiltered) query only evaluated the cam=1 half
    assert ex.rows - rows_first <= math.ceil((n // 2) / batch) * batch
    assert (corpus.virtual_columns["p"] != -1).all()


# ----------------------------------------------------- planner + plan -----
def test_plan_query_end_to_end_with_trained_system():
    """Tiny trained system -> plan -> engine == naive, and the EXPLAIN
    output names every predicate with cost/selectivity estimates."""
    from repro.configs.base import TahomaCNNConfig
    from repro.core.pipeline import initialize_system
    from repro.data.synthetic import (DEFAULT_PREDICATES, make_corpus,
                                      make_multi_corpus, three_way_split)

    specs = DEFAULT_PREDICATES[:2]
    reps = [Representation(8, "gray"), Representation(16, "gray"),
            Representation(32, "rgb")]
    systems = {}
    for spec in specs:
        x, y = make_corpus(spec, 160, hw=32, seed=0)
        systems[spec.name] = initialize_system(
            *three_way_split(x, y, seed=1),
            [TahomaCNNConfig(1, 8, 16)], reps, steps=30)
    # space memoization: planning twice reuses the evaluated space
    s0 = systems[specs[0].name].cascade_space("CAMERA")
    assert systems[specs[0].name].cascade_space("CAMERA") is s0

    qx, _ = make_multi_corpus(specs, 128, hw=32, seed=5,
                              positive_rate=0.4)
    metadata = {"cam": np.arange(len(qx)) % 2}
    spec_q = QuerySpec(metadata_eq={"cam": 0},
                       predicates=[PredicateClause(s.name) for s in specs])
    plan = plan_query(systems, spec_q, scenario="CAMERA",
                      metadata=metadata)
    assert isinstance(plan, PhysicalPlan)
    assert len(plan.predicates) == 2
    # ordering respects the rank rule
    ranks = [p.rank for p in plan.predicates]
    assert ranks == sorted(ranks)
    txt = plan.explain(n_rows=len(qx))
    for s in specs:
        assert f"contains({s.name})" in txt
    assert "cost/row" in txt and "sel=" in txt and "PHYSICAL PLAN" in txt
    assert plan.meta_selectivity == 0.5

    eng = ScanEngine(qx, metadata, chunk=32)
    res = eng.execute(plan.cascades, plan.metadata_eq)
    ref = naive_scan(qx, plan.cascades, metadata, plan.metadata_eq,
                     chunk=32)
    assert np.array_equal(res.indices, ref)


# --------------------------------------------------------- service --------
def test_cascade_service_routes_and_batches(toy_setup):
    from repro.engine.scan import make_batch_runner
    from repro.serve.batcher import CascadeService, Request

    imgs, cascades, _ = toy_setup
    bs = 16
    service = CascadeService(
        {c.concept: make_batch_runner(c, bs) for c in cascades[:2]},
        batch_size=bs, max_wait_s=10.0)
    reqs = []
    for i in range(40):
        concept = cascades[i % 2].concept
        r = Request(i, jnp.asarray(imgs[i]))
        service.submit(concept, r)
        reqs.append((concept, i, r))
    service.drain()
    assert all(r.result in (0, 1) for _, _, r in reqs)
    # routing: each concept's batcher saw exactly its own requests
    st = service.stats
    assert st["a"].batches == 2 and st["b"].batches == 2
    # results agree with the unbatched cascade run
    eng = ScanEngine(imgs[:40], chunk=bs)
    eng_res = eng.execute([cascades[0]])
    want = set(eng_res.indices[eng_res.indices % 2 == 0])
    got = {i for c, i, r in reqs if c == "a" and r.result == 1 and
           i % 2 == 0}
    assert got == {i for i in want if i % 2 == 0}
