"""Per-arch REDUCED smoke tests (deliverable f): one forward + one train
step on CPU per assigned architecture; asserts shapes + finiteness.
The FULL configs are exercised only via the dry-run artifacts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_arch, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.factory import build_model
from repro.train.optimizer import adamw

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, train=True, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.asarray(rng.standard_normal(
            (b, cfg.encoder.n_frames, cfg.d_model)), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
        batch["vision_embeds"] = jnp.asarray(rng.standard_normal(
            (b, cfg.vision.n_patches, cfg.d_model)), jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits, aux, _ = model.forward(params, _batch(cfg, train=False),
                                   remat_policy="none")
    assert logits.shape == (2, 32, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    state = opt.init(params)
    shape = ShapeConfig(name="t", kind="train", seq_len=32, global_batch=2)
    step, _ = make_train_step(model, mesh, shape, opt)
    with mesh:
        p2, s2, metrics = jax.jit(step)(params, state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-130m",
                                  "zamba2-1.2b", "whisper-tiny",
                                  "deepseek-v2-236b"])
def test_full_config_abstract_init(arch):
    """Full (production) configs build abstract param trees with the
    published parameter counts (no allocation)."""
    from repro.launch.steps import abstract_params, count_params_from_shapes
    cfg = get_arch(arch).replace(head_pad_to=16)
    n = count_params_from_shapes(abstract_params(build_model(cfg)))
    expected = {"deepseek-7b": 7e9, "mamba2-130m": 1.3e8,
                "zamba2-1.2b": 1.2e9, "whisper-tiny": 3.7e7,
                "deepseek-v2-236b": 2.36e11}[arch]
    assert 0.5 * expected < n < 1.9 * expected, (arch, n)
