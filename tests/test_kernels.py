"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret mode on CPU — deliverable c)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("res", [8, 16, 32])
@pytest.mark.parametrize("color", ["rgb", "r", "g", "b", "gray"])
def test_image_transform(res, color):
    img = RNG.random((3, 32, 32, 3), np.float32)
    out = ops.transform_op(jnp.asarray(img), res=res, color=color)
    expect = ops.transform_op(jnp.asarray(img), res=res, color=color,
                              backend="ref")
    assert out.shape == (3, res, res, 1 if color != "rgb" else 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)


@pytest.mark.parametrize("shape", [(64, 96, 32), (128, 128, 128),
                                   (33, 17, 65), (256, 64, 130)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul(shape, dtype):
    m, k, n = shape
    a = RNG.standard_normal((m, k)).astype(dtype)
    b = RNG.standard_normal((k, n)).astype(dtype)
    out = ops.matmul_op(a, b)
    expect = ref.matmul_ref(a, b)
    tol = 1e-3 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bhsd", [(1, 2, 64, 32), (2, 3, 128, 64),
                                  (1, 1, 256, 16)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention(causal, bhsd, dtype):
    b, h, s, d = bhsd
    q = (RNG.standard_normal((b, h, s, d)) * 0.5).astype(dtype)
    k = (RNG.standard_normal((b, h, s, d)) * 0.5).astype(dtype)
    v = (RNG.standard_normal((b, h, s, d)) * 0.5).astype(dtype)
    out = ops.flash_attention_op(q, k, v, causal=causal)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-3 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("shp", [(1, 64, 2, 8, 16), (2, 128, 3, 16, 32)])
def test_ssd_scan(chunk, shp):
    b, s, h, p, n = shp
    x = (RNG.standard_normal((b, s, h, p)) * 0.5).astype(np.float32)
    dt = (RNG.random((b, s, h)) * 0.1).astype(np.float32)
    a = (-RNG.random(h) * 2).astype(np.float32)
    bm = (RNG.standard_normal((b, s, n)) * 0.3).astype(np.float32)
    cm = (RNG.standard_normal((b, s, n)) * 0.3).astype(np.float32)
    y = ops.ssd_scan_op(x, dt, a, bm, cm, chunk=chunk)
    yr = ref.ssd_scan_ref(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-3)


def test_ssd_chunk_invariance():
    """Chunk size is an implementation detail — results must not change."""
    b, s, h, p, n = 1, 128, 2, 8, 16
    x = (RNG.standard_normal((b, s, h, p)) * 0.5).astype(np.float32)
    dt = (RNG.random((b, s, h)) * 0.1).astype(np.float32)
    a = (-RNG.random(h)).astype(np.float32)
    bm = (RNG.standard_normal((b, s, n)) * 0.3).astype(np.float32)
    cm = (RNG.standard_normal((b, s, n)) * 0.3).astype(np.float32)
    y1 = ops.ssd_scan_op(x, dt, a, bm, cm, chunk=16)
    y2 = ops.ssd_scan_op(x, dt, a, bm, cm, chunk=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-3)
