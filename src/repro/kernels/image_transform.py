"""Fused physical-representation transform kernels (paper §V-B / §VI).

``fused_transform`` — one HBM->VMEM pass per image tile performs:
area-average resize (base_hw -> res), color projection (RGB keep / channel
select / grayscale — all expressed as a length-3 channel weight matrix),
and normalization. This is THE data-handling hot spot the paper's cost
model prices (t_transform); fusing the three stages removes two HBM
round-trips vs the naive resize->select->normalize chain.

``fused_pyramid_transform`` — the multi-output variant: ONE HBM read of
the base image emits every (resolution, color) representation a cascade
(or the whole A x F grid) needs. Resolutions are pooled progressively in
VMEM (each level from the nearest already-materialized level, mirroring
core/transforms.plan_pyramid), so HBM traffic is one base read plus the
(much smaller) representation writes — vs one full base read PER
representation on the naive path.

Grid: one program per batch element (images are small: 224*224*3 f32 =
602 KB — fits VMEM comfortably with the output tiles).

``interpret=None`` (default) resolves by backend: compiled Mosaic on TPU,
interpret mode elsewhere — callers no longer get silently-interpreted
kernels on TPU (the seed's interpret=True-by-default compile bug).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from repro.core.transforms import _GRAY, plan_pyramid
from repro.kernels import resolve_interpret


def _pool(img, res: int):
    """(H, W, 3) -> (res, res, 3) area average; factors guaranteed to nest
    by plan_pyramid."""
    h = img.shape[0]
    f = h // res
    return jnp.mean(img.reshape(res, f, res, f, 3), axis=(1, 3))


def _transform_kernel(img_ref, cw_ref, out_ref, *, factor: int,
                      res: int, out_ch: int, mean: float, inv_std: float):
    img = img_ref[0]                                   # (H, W, 3)
    h = img.reshape(res, factor, res, factor, 3)
    pooled = jnp.mean(h, axis=(1, 3))                  # (res, res, 3)
    cw = cw_ref[...]                                   # (3, out_ch)
    proj = jax.lax.dot_general(
        pooled.reshape(res * res, 3), cw,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(res, res, out_ch)
    out_ref[0] = (proj - mean) * inv_std


def fused_transform(images, channel_weights, res: int,
                    mean: float = 0.5, std: float = 0.25,
                    interpret: bool | None = None):
    """images (B, H, H, 3) float32; channel_weights (3, C') encodes the
    color representation (identity columns / unit column / gray weights).
    -> (B, res, res, C') normalized."""
    b, h, w, _ = images.shape
    assert h == w and h % res == 0, (h, w, res)
    factor = h // res
    out_ch = channel_weights.shape[1]
    kernel = functools.partial(
        _transform_kernel, factor=factor, res=res, out_ch=out_ch,
        mean=mean, inv_std=1.0 / std)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, out_ch), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, res, res, out_ch),
                               lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, res, res, out_ch), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(images.astype(jnp.float32), channel_weights.astype(jnp.float32))


def _pyramid_kernel(img_ref, *refs, base: int, plan, out_meta,
                    mean: float, inv_std: float):
    """refs = (cw_ref_0..cw_ref_{n-1}, out_ref_0..out_ref_{n-1}).
    plan: ((resolution, source), ...) progressive pooling steps.
    out_meta: ((res_i, out_ch_i), ...) per output."""
    n = len(out_meta)
    cw_refs, out_refs = refs[:n], refs[n:]
    img = img_ref[0]                                   # (H, H, 3)
    levels = {base: img}
    for res, src in plan:                              # unrolled at trace
        levels[res] = _pool(levels[src], res)
    for i, (res, out_ch) in enumerate(out_meta):
        pooled = levels[res]
        cw = cw_refs[i][...]                           # (3, out_ch)
        proj = jax.lax.dot_general(
            pooled.reshape(res * res, 3), cw,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(res, res, out_ch)
        out_refs[i][0] = (proj - mean) * inv_std


def fused_pyramid_transform(images, rep_specs,
                            mean: float = 0.5, std: float = 0.25,
                            interpret: bool | None = None):
    """Multi-output fused transform: images (B, H, H, 3) float32 ->
    tuple of (B, res_i, res_i, C'_i) normalized tensors, one per
    (res, channel_weights) pair in ``rep_specs``, all emitted from a
    single HBM read of the base image per batch element."""
    b, h, w, _ = images.shape
    assert h == w, (h, w)
    specs = [(int(res), jnp.asarray(cw, jnp.float32))
             for res, cw in rep_specs]
    plan = tuple((s.resolution, s.source)
                 for s in plan_pyramid([r for r, _ in specs], h))
    out_meta = tuple((res, int(cw.shape[1])) for res, cw in specs)
    kernel = functools.partial(
        _pyramid_kernel, base=h, plan=plan, out_meta=out_meta,
        mean=mean, inv_std=1.0 / std)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=(
            [pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0))]
            + [pl.BlockSpec((3, ch), lambda i: (0, 0))
               for _, ch in out_meta]),
        out_specs=[pl.BlockSpec((1, res, res, ch),
                                lambda i, _r=res, _c=ch: (i, 0, 0, 0))
                   for res, ch in out_meta],
        out_shape=[jax.ShapeDtypeStruct((b, res, res, ch), jnp.float32)
                   for res, ch in out_meta],
        interpret=resolve_interpret(interpret),
    )(images.astype(jnp.float32), *[cw for _, cw in specs])
    return tuple(out) if isinstance(out, (list, tuple)) else (out,)


# ------------------------------------------- fused pyramid + stage-0 pass --
# One HBM read of the base image emits (a) the raw pooled RGB pyramid
# levels the scan engine carries between cascade stages and (b) the
# stage-0 cascade model's sigmoid scores, with the small CNN folded into
# the kernel epilogue: conv3x3-SAME as im2col + one MXU dot per layer,
# maxpool2 as a reshape-max, dense + output head as two more dots.
# Weights ride in as kernel operands; the int8 path carries int8 weight
# tensors and dequantizes at use (per-tensor scale baked in as a trace
# constant — models/cnn.quantize_cnn).

def color_weight_matrix(color: str) -> np.ndarray:
    """(3, C') channel-projection matrix matching core.transforms.
    color_transform exactly (identity / unit column / gray weights)."""
    if color == "rgb":
        return np.eye(3, dtype=np.float32)
    if color == "gray":
        return _GRAY.reshape(3, 1).astype(np.float32)
    idx = {"r": 0, "g": 1, "b": 2}[color]
    w = np.zeros((3, 1), np.float32)
    w[idx, 0] = 1.0
    return w


def _conv3x3_relu_pool(x, w, b):
    """relu(conv3x3-SAME(x, w) + b) then maxpool2, in Mosaic-lowerable
    ops only: im2col (9 static shifted slices of the zero-padded input)
    + one dot_general, reshape-max for the pool.
    x (H, W, Cin) f32; w (3, 3, Cin, Cout) f32; b (Cout,)."""
    h, wd, cin = x.shape
    cout = w.shape[-1]
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    patches = jnp.concatenate(
        [xp[dy:dy + h, dx:dx + wd, :]
         for dy in range(3) for dx in range(3)], axis=-1)   # (H, W, 9*Cin)
    y = jax.lax.dot_general(
        patches.reshape(h * wd, 9 * cin), w.reshape(9 * cin, cout),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(h, wd, cout)
    y = jnp.maximum(y + b, 0.0)
    return y.reshape(h // 2, 2, wd // 2, 2, cout).max(axis=(1, 3))


def _pyramid_stage0_kernel(img_ref, cw_ref, *refs, base: int, plan,
                           out_res, s0_res: int, n_conv: int, scales):
    """refs = (w_0, b_0, ..., dense_w, dense_b, out_w, out_b,
               out_ref_0..out_ref_{n-1}, score_ref).
    scales: per-weight-tensor dequant scales (conv..., dense, out) for the
    int8 path, or None when weights arrive as f32."""
    n_w = 2 * n_conv + 4
    w_refs, out_refs = refs[:n_w], refs[n_w:]

    def weight(k, si):
        w = w_refs[k][...]
        if scales is not None:
            w = w.astype(jnp.float32) * scales[si]
        return w

    img = img_ref[0]                                   # (H, H, 3)
    levels = {base: img}
    for res, src in plan:                              # unrolled at trace
        levels[res] = _pool(levels[src], res)
    for i, res in enumerate(out_res):
        out_refs[i][0] = levels[res]

    # ---- stage-0 epilogue: color-project the level-0 input, run the CNN
    cw = cw_ref[...]                                   # (3, C)
    x = jax.lax.dot_general(
        levels[s0_res].reshape(s0_res * s0_res, 3), cw,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32
    ).reshape(s0_res, s0_res, cw.shape[1])
    k = 0
    for li in range(n_conv):
        x = _conv3x3_relu_pool(x, weight(k, li), w_refs[k + 1][...].reshape(-1))
        k += 2
    flat = x.reshape(1, -1)
    hdn = jnp.maximum(
        jax.lax.dot_general(flat, weight(k, n_conv),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + w_refs[k + 1][...].reshape(-1), 0.0)
    logit = (jax.lax.dot_general(hdn, weight(k + 2, n_conv + 1),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + w_refs[k + 3][...].reshape(-1))[0, 0]
    out_refs[-1][0, 0] = jax.nn.sigmoid(logit)


def _stage0_weight_operands(params, qparams):
    """Flatten stage-0 CNN weights into kernel operands. Returns
    (tensors, scales, n_conv); scales is None on the f32 path."""
    if qparams is not None:
        tensors, scales = [], []
        for l in qparams["conv"]:
            tensors += [l["w"]["q"], jnp.reshape(l["b"], (1, -1))]
            scales.append(float(l["w"]["scale"]))
        tensors += [qparams["dense_w"]["q"],
                    jnp.reshape(qparams["dense_b"], (1, -1))]
        scales.append(float(qparams["dense_w"]["scale"]))
        tensors += [qparams["out_w"]["q"],
                    jnp.reshape(qparams["out_b"], (1, -1))]
        scales.append(float(qparams["out_w"]["scale"]))
        return tensors, tuple(scales), len(qparams["conv"])
    tensors = []
    for l in params["conv"]:
        tensors += [jnp.asarray(l["w"], jnp.float32),
                    jnp.reshape(l["b"], (1, -1))]
    tensors += [jnp.asarray(params["dense_w"], jnp.float32),
                jnp.reshape(params["dense_b"], (1, -1)),
                jnp.asarray(params["out_w"], jnp.float32),
                jnp.reshape(params["out_b"], (1, -1))]
    return tensors, None, len(params["conv"])


def fused_pyramid_stage0(images, out_res, params, rep, *, qparams=None,
                         interpret: bool | None = None):
    """ONE Pallas pass per batch element: raw RGB (B, H, H, 3) float32 ->
    ({res: (B, res, res, 3) raw pooled RGB level for res in out_res},
     stage-0 sigmoid scores (B,)).

    Levels are the engine's carry currency — raw [0,1] pooled RGB, bit-
    identical to core.transforms.materialize_pyramid (NOT the normalized
    projected reps fused_pyramid_transform emits). ``rep`` names the
    stage-0 model's input representation; its resolution is materialized
    in-VMEM even when not in ``out_res``. ``qparams`` (models/cnn.
    quantize_cnn output) selects the int8 weight path."""
    b, h, w, _ = images.shape
    assert h == w, (h, w)
    out_res = [int(r) for r in out_res]
    s0_res = int(rep.resolution)
    need = set(out_res) | {s0_res}
    plan = tuple((s.resolution, s.source)
                 for s in plan_pyramid(need, h))
    tensors, scales, n_conv = _stage0_weight_operands(params, qparams)
    cw = jnp.asarray(color_weight_matrix(rep.color))
    kernel = functools.partial(
        _pyramid_stage0_kernel, base=h, plan=plan, out_res=tuple(out_res),
        s0_res=s0_res, n_conv=n_conv, scales=scales)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=(
            [pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
             pl.BlockSpec(cw.shape, lambda i: (0, 0))]
            + [pl.BlockSpec(t.shape, lambda i, _n=t.ndim: (0,) * _n)
               for t in tensors]),
        out_specs=(
            [pl.BlockSpec((1, res, res, 3),
                          lambda i, _r=res: (i, 0, 0, 0))
             for res in out_res]
            + [pl.BlockSpec((1, 1), lambda i: (i, 0))]),
        out_shape=(
            [jax.ShapeDtypeStruct((b, res, res, 3), jnp.float32)
             for res in out_res]
            + [jax.ShapeDtypeStruct((b, 1), jnp.float32)]),
        interpret=resolve_interpret(interpret),
    )(images.astype(jnp.float32), cw, *tensors)
    return ({res: out[i] for i, res in enumerate(out_res)},
            out[-1][:, 0])
