"""Fused physical-representation transform kernels (paper §V-B / §VI).

``fused_transform`` — one HBM->VMEM pass per image tile performs:
area-average resize (base_hw -> res), color projection (RGB keep / channel
select / grayscale — all expressed as a length-3 channel weight matrix),
and normalization. This is THE data-handling hot spot the paper's cost
model prices (t_transform); fusing the three stages removes two HBM
round-trips vs the naive resize->select->normalize chain.

``fused_pyramid_transform`` — the multi-output variant: ONE HBM read of
the base image emits every (resolution, color) representation a cascade
(or the whole A x F grid) needs. Resolutions are pooled progressively in
VMEM (each level from the nearest already-materialized level, mirroring
core/transforms.plan_pyramid), so HBM traffic is one base read plus the
(much smaller) representation writes — vs one full base read PER
representation on the naive path.

Grid: one program per batch element (images are small: 224*224*3 f32 =
602 KB — fits VMEM comfortably with the output tiles).

``interpret=None`` (default) resolves by backend: compiled Mosaic on TPU,
interpret mode elsewhere — callers no longer get silently-interpreted
kernels on TPU (the seed's interpret=True-by-default compile bug).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.transforms import plan_pyramid
from repro.kernels import resolve_interpret


def _pool(img, res: int):
    """(H, W, 3) -> (res, res, 3) area average; factors guaranteed to nest
    by plan_pyramid."""
    h = img.shape[0]
    f = h // res
    return jnp.mean(img.reshape(res, f, res, f, 3), axis=(1, 3))


def _transform_kernel(img_ref, cw_ref, out_ref, *, factor: int,
                      res: int, out_ch: int, mean: float, inv_std: float):
    img = img_ref[0]                                   # (H, W, 3)
    h = img.reshape(res, factor, res, factor, 3)
    pooled = jnp.mean(h, axis=(1, 3))                  # (res, res, 3)
    cw = cw_ref[...]                                   # (3, out_ch)
    proj = jax.lax.dot_general(
        pooled.reshape(res * res, 3), cw,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(res, res, out_ch)
    out_ref[0] = (proj - mean) * inv_std


def fused_transform(images, channel_weights, res: int,
                    mean: float = 0.5, std: float = 0.25,
                    interpret: bool | None = None):
    """images (B, H, H, 3) float32; channel_weights (3, C') encodes the
    color representation (identity columns / unit column / gray weights).
    -> (B, res, res, C') normalized."""
    b, h, w, _ = images.shape
    assert h == w and h % res == 0, (h, w, res)
    factor = h // res
    out_ch = channel_weights.shape[1]
    kernel = functools.partial(
        _transform_kernel, factor=factor, res=res, out_ch=out_ch,
        mean=mean, inv_std=1.0 / std)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, out_ch), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, res, res, out_ch),
                               lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, res, res, out_ch), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(images.astype(jnp.float32), channel_weights.astype(jnp.float32))


def _pyramid_kernel(img_ref, *refs, base: int, plan, out_meta,
                    mean: float, inv_std: float):
    """refs = (cw_ref_0..cw_ref_{n-1}, out_ref_0..out_ref_{n-1}).
    plan: ((resolution, source), ...) progressive pooling steps.
    out_meta: ((res_i, out_ch_i), ...) per output."""
    n = len(out_meta)
    cw_refs, out_refs = refs[:n], refs[n:]
    img = img_ref[0]                                   # (H, H, 3)
    levels = {base: img}
    for res, src in plan:                              # unrolled at trace
        levels[res] = _pool(levels[src], res)
    for i, (res, out_ch) in enumerate(out_meta):
        pooled = levels[res]
        cw = cw_refs[i][...]                           # (3, out_ch)
        proj = jax.lax.dot_general(
            pooled.reshape(res * res, 3), cw,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(res, res, out_ch)
        out_refs[i][0] = (proj - mean) * inv_std


def fused_pyramid_transform(images, rep_specs,
                            mean: float = 0.5, std: float = 0.25,
                            interpret: bool | None = None):
    """Multi-output fused transform: images (B, H, H, 3) float32 ->
    tuple of (B, res_i, res_i, C'_i) normalized tensors, one per
    (res, channel_weights) pair in ``rep_specs``, all emitted from a
    single HBM read of the base image per batch element."""
    b, h, w, _ = images.shape
    assert h == w, (h, w)
    specs = [(int(res), jnp.asarray(cw, jnp.float32))
             for res, cw in rep_specs]
    plan = tuple((s.resolution, s.source)
                 for s in plan_pyramid([r for r, _ in specs], h))
    out_meta = tuple((res, int(cw.shape[1])) for res, cw in specs)
    kernel = functools.partial(
        _pyramid_kernel, base=h, plan=plan, out_meta=out_meta,
        mean=mean, inv_std=1.0 / std)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=(
            [pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0))]
            + [pl.BlockSpec((3, ch), lambda i: (0, 0))
               for _, ch in out_meta]),
        out_specs=[pl.BlockSpec((1, res, res, ch),
                                lambda i, _r=res, _c=ch: (i, 0, 0, 0))
                   for res, ch in out_meta],
        out_shape=[jax.ShapeDtypeStruct((b, res, res, ch), jnp.float32)
                   for res, ch in out_meta],
        interpret=resolve_interpret(interpret),
    )(images.astype(jnp.float32), *[cw for _, cw in specs])
    return tuple(out) if isinstance(out, (list, tuple)) else (out,)
