"""Fused physical-representation transform kernel (paper §V-B / §VI).

One HBM->VMEM pass per image tile performs: area-average resize
(base_hw -> res), color projection (RGB keep / channel select / grayscale —
all expressed as a length-3 channel weight matrix), and normalization.
This is THE data-handling hot spot the paper's cost model prices
(t_transform); fusing the three stages removes two HBM round-trips vs the
naive resize->select->normalize chain.

Grid: one program per batch element (images are small: 224*224*3 f32 =
602 KB — fits VMEM comfortably with the output tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transform_kernel(img_ref, cw_ref, out_ref, *, factor: int,
                      res: int, out_ch: int, mean: float, inv_std: float):
    img = img_ref[0]                                   # (H, W, 3)
    h = img.reshape(res, factor, res, factor, 3)
    pooled = jnp.mean(h, axis=(1, 3))                  # (res, res, 3)
    cw = cw_ref[...]                                   # (3, out_ch)
    proj = jax.lax.dot_general(
        pooled.reshape(res * res, 3), cw,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(res, res, out_ch)
    out_ref[0] = (proj - mean) * inv_std


def fused_transform(images, channel_weights, res: int,
                    mean: float = 0.5, std: float = 0.25,
                    interpret: bool = True):
    """images (B, H, H, 3) float32; channel_weights (3, C') encodes the
    color representation (identity columns / unit column / gray weights).
    -> (B, res, res, C') normalized."""
    b, h, w, _ = images.shape
    assert h == w and h % res == 0, (h, w, res)
    factor = h // res
    out_ch = channel_weights.shape[1]
    kernel = functools.partial(
        _transform_kernel, factor=factor, res=res, out_ch=out_ch,
        mean=mean, inv_std=1.0 / std)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, out_ch), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, res, res, out_ch),
                               lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, res, res, out_ch), jnp.float32),
        interpret=interpret,
    )(images.astype(jnp.float32), channel_weights.astype(jnp.float32))
