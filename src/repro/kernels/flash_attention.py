"""Flash attention (online softmax) Pallas kernel — causal, GQA-ready.

Grid (batch*heads, n_q_blocks, n_kv_blocks) with the KV axis innermost
("arbitrary"); the running max / denominator / output accumulator live in
VMEM scratch and persist across KV steps. Causal skipping: KV blocks fully
above the diagonal contribute nothing and are masked per-element on the
diagonal block. Block shapes default to MXU-aligned (128).

Serving uses this for long prefill on real TPUs; the dry-run lowers the
pure-jnp path so roofline FLOP accounting stays visible (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _block():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        pl.when(qi * bq + bq > ki * bk)(_block)   # skip fully-masked blocks
    else:
        _block()

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q (B,H,S,D); k/v (B,H,T,D) (kv heads already repeated).
    Returns (B,H,S,D)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    scale = d ** -0.5
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    nk = t // bk
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=(b * h, s // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
