"""Blocked MXU matmul kernel: (M,K) @ (K,N) with explicit VMEM tiling.

Used by the serving path for tiny-CNN dense layers and im2col'd convs
(DESIGN.md §3). Tiles default to 128-aligned MXU shapes; the K dimension is
the innermost ("arbitrary") grid axis with a float32 VMEM accumulator that
persists across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           out_dtype=None, interpret: bool | None = None):
    """Pads to tile multiples, runs the blocked kernel, slices back.
    interpret=None: compiled on TPU, interpret mode elsewhere."""
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out_dtype = out_dtype or a.dtype
    bm, bn, bk = min(bm, _ceil(m)), min(bn, _ceil(n)), min(bk, _ceil(k))
    mp, np_, kp = _pad_to(m, bm), _pad_to(n, bn), _pad_to(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[_vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _ceil(x: int, base: int = 8) -> int:
    return max(base, 1 << (x - 1).bit_length()) if x else base


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
