"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.transforms import resize_area


def fused_transform_ref(images, channel_weights, res: int,
                        mean: float = 0.5, std: float = 0.25):
    x = resize_area(images.astype(jnp.float32), res)
    x = jnp.einsum("bhwc,cd->bhwd", x, channel_weights.astype(jnp.float32))
    return (x - mean) / std


def fused_pyramid_transform_ref(images, rep_specs,
                                mean: float = 0.5, std: float = 0.25):
    """Oracle for the multi-output pyramid kernel: each representation
    independently from the base image (the nesting property makes the
    progressive kernel agree with this)."""
    return tuple(fused_transform_ref(images, cw, int(res), mean, std)
                 for res, cw in rep_specs)


def fused_pyramid_stage0_ref(images, out_res, params, rep, qparams=None):
    """Oracle for the fused pyramid+stage-0 kernel: the unfused
    materialize_pyramid -> color_transform -> cnn_predict_proba chain.
    With ``qparams`` the weights are dequantized first (weight-only int8:
    the reference arithmetic stays f32, matching the kernel's
    dequantize-at-use)."""
    from repro.core.transforms import color_transform, materialize_pyramid
    from repro.models.cnn import cnn_predict_proba, dequantize_cnn
    p = dequantize_cnn(qparams) if qparams is not None else params
    out_res = [int(r) for r in out_res]
    levels = materialize_pyramid(images.astype(jnp.float32),
                                 set(out_res) | {int(rep.resolution)})
    scores = cnn_predict_proba(
        p, color_transform(levels[int(rep.resolution)], rep.color))
    return {r: levels[r] for r in out_res}, scores


def matmul_ref(a, b, out_dtype=None):
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    return out.astype(out_dtype or a.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q (B,H,S,D); k/v (B,H,T,D)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if causal:
        qn, kn = q.shape[2], k.shape[2]
        mask = jnp.arange(qn)[:, None] >= jnp.arange(kn)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)


def ssd_scan_ref(x, dt, a, bmat, cmat, *, chunk: int = 128):
    """Reference = the model-layer implementation (models/ssm.py)."""
    from repro.models.ssm import ssd_chunked
    y, _ = ssd_chunked(x, dt, a, bmat, cmat, chunk)
    return y
