"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends the kernels execute in interpret mode (the kernel body
runs in Python on CPU — correctness-exact, used by tests and this
container); on TPU they compile to Mosaic. ``backend='ref'`` forces the
pure-jnp oracle (the dry-run path, so XLA cost analysis sees the FLOPs —
DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import image_transform as _it
from repro.kernels import matmul as _mm
from repro.kernels import ref as _ref
from repro.kernels import ssd_scan as _ssd

COLOR_WEIGHTS = {
    "rgb": np.eye(3, dtype=np.float32),
    "r": np.array([[1.0], [0.0], [0.0]], np.float32),
    "g": np.array([[0.0], [1.0], [0.0]], np.float32),
    "b": np.array([[0.0], [0.0], [1.0]], np.float32),
    "gray": np.array([[0.299], [0.587], [0.114]], np.float32),
}


def _interpret() -> bool:
    from repro.kernels import resolve_interpret
    return resolve_interpret(None)


@functools.partial(jax.jit, static_argnames=("res", "color", "backend"))
def transform_op(images, *, res: int, color: str = "rgb",
                 backend: str = "pallas"):
    cw = jnp.asarray(COLOR_WEIGHTS[color])
    if backend == "ref":
        return _ref.fused_transform_ref(images, cw, res)
    return _it.fused_transform(images, cw, res, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("specs", "backend"))
def pyramid_transform_op(images, *, specs, backend: str = "pallas"):
    """Multi-output fused transform. specs: tuple of (res, color) pairs —
    one output tensor per pair, all from a single pass over the base
    image (kernels/image_transform.fused_pyramid_transform)."""
    rep_specs = [(res, jnp.asarray(COLOR_WEIGHTS[color]))
                 for res, color in specs]
    if backend == "ref":
        return _ref.fused_pyramid_transform_ref(images, rep_specs)
    return _it.fused_pyramid_transform(images, rep_specs,
                                       interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("backend",))
def matmul_op(a, b, *, backend: str = "pallas"):
    if backend == "ref":
        return _ref.matmul_ref(a, b)
    return _mm.matmul(a, b, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "backend"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       backend: str = "pallas"):
    if backend == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def ssd_scan_op(x, dt, a, bmat, cmat, *, chunk: int = 128,
                backend: str = "pallas"):
    if backend == "ref":
        return _ref.ssd_scan_ref(x, dt, a, bmat, cmat, chunk=chunk)
    return _ssd.ssd_scan(x, dt, a, bmat, cmat, chunk=chunk,
                         interpret=_interpret())
