# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def resolve_interpret(interpret) -> bool:
    """Shared interpret-mode resolver: ``None`` means "compile on TPU,
    interpret elsewhere" — so callers never silently run interpreted
    kernels on real hardware (nor try to Mosaic-compile on CPU)."""
    if interpret is None:
        import jax
        return jax.default_backend() != "tpu"
    return bool(interpret)
