"""Mamba-2 SSD chunk kernel: fused intra-chunk attention-like term +
inter-chunk state recurrence for ONE (batch, head) stream.

Grid (batch*heads, n_chunks) with the chunk axis innermost; the SSD state
(P x N) lives in VMEM scratch and carries across chunks — the recurrence
never round-trips HBM, which is the TPU-native restatement of Mamba-2's
"state stays in SRAM" GPU design (DESIGN.md §3).

Per chunk: y = (C B^T ⊙ decay) @ (x dt)  +  C @ state_in ⊙ decay_in;
           state = state * chunk_decay + (B ⊙ decay_to_end dt)^T x.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                l: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (l, p)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (l,)
    a = a_ref[0, 0]                            # scalar decay rate (<0)
    bmat = b_ref[0, 0].astype(jnp.float32)     # (l, n)
    cmat = c_ref[0, 0].astype(jnp.float32)     # (l, n)

    da = dt * a
    da_cum = jnp.cumsum(da)                    # (l,)
    seg = da_cum[:, None] - da_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    xdt = x * dt[:, None]
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(cb * decay, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state_in = state_ref[...]                  # (p, n)
    y_off = jax.lax.dot_general(cmat, state_in,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(da_cum)[:, None]
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_to_end = jnp.exp(da_cum[-1] - da_cum)
    upd = jax.lax.dot_general(xdt * decay_to_end[:, None], bmat,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_ref[...] = state_in * jnp.exp(da_cum[-1]) + upd


def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 128,
             interpret: bool = True):
    """x (B,S,H,P); dt (B,S,H) >=0; a (H,) <0; b/c (B,S,N) shared across
    heads (n_groups=1). Returns y (B,S,H,P) float32 (pre-gating)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, s)
    assert s % l == 0
    nc = s // l
    # (B*H, nc, l, ...) streams
    xs = x.transpose(0, 2, 1, 3).reshape(b * h, nc, l, p)
    dts = dt.transpose(0, 2, 1).reshape(b * h, nc, l)
    a_s = jnp.tile(a, b).reshape(b * h, 1)
    bs = jnp.broadcast_to(bmat[:, None], (b, h, s, n)).reshape(
        b * h, nc, l, n)
    cs = jnp.broadcast_to(cmat[:, None], (b, h, s, n)).reshape(
        b * h, nc, l, n)
    from jax.experimental.pallas import tpu as pltpu
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, l=l),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, l, p), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, l), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, 1), lambda g, c: (g, 0)),
            pl.BlockSpec((1, 1, l, n), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda g, c: (g, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, p), lambda g, c: (g, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nc, l, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xs, dts, a_s, bs, cs)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
