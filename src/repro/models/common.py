"""Shared model building blocks (no flax — pure functional pytrees).

Conventions
-----------
* ``init_*`` functions return nested dicts of jnp arrays (the params pytree).
* Every leaf's *name* (its last dict key) is drawn from a fixed vocabulary;
  sharding/policy.py maps leaf names -> logical axes -> mesh PartitionSpecs,
  so sharding stays out of model code entirely.
* Norms compute in float32 and cast back; params live in cfg.dtype.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def pdtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16, scale=1.0):
    fan_in = shape[in_axis]
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms ----
def init_norm(cfg, with_bias: bool | None = None):
    bias = cfg.norm == "layernorm" if with_bias is None else with_bias
    p = {"scale": jnp.ones((cfg.d_model,), pdtype(cfg))}
    if bias:
        p["bias"] = jnp.zeros((cfg.d_model,), pdtype(cfg))
    return p


def apply_norm(p, x, cfg, kind: str | None = None):
    kind = kind or cfg.norm
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True)
                                + cfg.norm_eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    out = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rmsnorm_vec(x, scale, eps=1e-5):
    """Norm over last axis for arbitrary-width vectors (MLA latents etc.)."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- RoPE ----
def rope_angles(positions, dim: int, theta: float):
    """positions (...,) int -> cos/sin of shape (..., dim//2), float32."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., dim); cos/sin broadcastable to (..., dim//2). Pairs are the
    llama 'rotate_half' convention (first/second half split)."""
    d = x.shape[-1] // 2
    x1, x2 = x[..., :d], x[..., d:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def rope_for_heads(positions, head_dim: int, theta: float):
    """positions (B, S) -> cos/sin (B, S, 1, head_dim//2) for (B,S,H,D) q/k."""
    cos, sin = rope_angles(positions, head_dim, theta)
    return cos[:, :, None, :], sin[:, :, None, :]


def mrope_for_heads(positions3, head_dim: int, theta: float,
                    sections: Sequence[int]):
    """Qwen2-VL M-RoPE: positions3 (3, B, S) carries (t, h, w) position
    streams; head_dim//2 frequency slots are split into ``sections`` and each
    section takes its angles from the corresponding stream."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    cos3, sin3 = rope_angles(positions3, head_dim, theta)  # (3,B,S,hd/2)
    parts_c, parts_s = [], []
    lo = 0
    for i, sec in enumerate(sections):
        parts_c.append(cos3[i, ..., lo:lo + sec])
        parts_s.append(sin3[i, ..., lo:lo + sec])
        lo += sec
    cos = jnp.concatenate(parts_c, -1)
    sin = jnp.concatenate(parts_s, -1)
    return cos[:, :, None, :], sin[:, :, None, :]


def sinusoidal_positions(n_pos: int, d_model: int):
    """Whisper-style sinusoidal embeddings (n_pos, d_model), float32."""
    half = d_model // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    t = np.arange(n_pos)[:, None] * freq[None, :]
    return jnp.asarray(np.concatenate([np.sin(t), np.cos(t)], axis=1),
                       jnp.float32)


# ----------------------------------------------------------- embeddings ----
def init_embedding(key, cfg):
    vp = cfg.padded_vocab()
    return {"embedding": embed_init(key, (vp, cfg.d_model), pdtype(cfg))}


def embed_tokens(p, tokens, cfg):
    return jnp.take(p["embedding"], tokens, axis=0)


def init_lm_head(key, cfg):
    if cfg.tie_embeddings:
        return {}
    vp = cfg.padded_vocab()
    return {"lm_head": dense_init(key, (cfg.d_model, vp), 0, pdtype(cfg))}


def lm_logits(head_p, embed_p, h, cfg):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, embed_p["embedding"])
    return jnp.einsum("...d,dv->...v", h, head_p["lm_head"])


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]
