"""Attention: GQA/MQA (optional QKV bias), MLA (DeepSeek-V2), M-RoPE,
cross-attention, chunked (jnp-flash) prefill, cache decode.

Tensor-parallel head padding
----------------------------
The production mesh has a 16-wide 'model' axis, but several assigned archs
have head counts not divisible by 16 (qwen2.5: 40, minitron: 24, whisper: 6).
We pad the *q-head* axis per KV group so (a) the padded head count shards,
(b) the original q->kv group mapping is preserved, and (c) numerics are
exactly preserved by zero-masking padded heads' outputs before w_o (so their
grads are exactly zero too). MHA (group size 1) pads q and kv together.
If no layout is found the layout degrades to no padding (replicated heads).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import (apply_rope, dense_init, pdtype, rmsnorm_vec)
from repro.sharding import policy as _policy


class HeadLayout(NamedTuple):
    n_q: int          # true q heads
    n_kv: int         # true kv heads
    hp: int           # padded q heads
    khp: int          # padded kv heads
    gp: int           # padded group size (hp // khp)

    @property
    def q_mask(self):
        """(hp,) 1.0 for real q heads."""
        if self.khp == self.n_kv:     # per-group padding
            g = self.n_q // self.n_kv
            return ((jnp.arange(self.hp) % self.gp) < g).astype(jnp.float32)
        return (jnp.arange(self.hp) < self.n_q).astype(jnp.float32)

    def q_head_is_real(self, i: int) -> bool:
        if self.khp == self.n_kv:
            g = self.n_q // self.n_kv
            return (i % self.gp) < g
        return i < self.n_q


def head_layout(n_q: int, n_kv: int, pad_to: int) -> HeadLayout:
    if pad_to <= 1 or n_q % pad_to == 0:
        return HeadLayout(n_q, n_kv, n_q, n_kv, n_q // max(n_kv, 1))
    g = n_q // n_kv
    if g == 1:  # MHA: pad q and kv in lockstep (mapping i -> i preserved)
        hp = ((n_q + pad_to - 1) // pad_to) * pad_to
        return HeadLayout(n_q, n_kv, hp, hp, 1)
    for gp in range(g, 64 * g):
        if (n_kv * gp) % pad_to == 0:
            return HeadLayout(n_q, n_kv, n_kv * gp, n_kv, gp)
    return HeadLayout(n_q, n_kv, n_q, n_kv, g)  # fallback: no padding


def layout_from_cfg(cfg) -> HeadLayout:
    return head_layout(cfg.n_heads, cfg.n_kv_heads, cfg.head_pad_to)


# ------------------------------------------------------------------ GQA ----
def init_gqa(key, cfg, cross: bool = False):
    lo = layout_from_cfg(cfg)
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    p = {
        "wq": dense_init(ks[0], (d, lo.hp * dh), 0, dt),
        "wk": dense_init(ks[1], (d, lo.khp * dh), 0, dt),
        "wv": dense_init(ks[2], (d, lo.khp * dh), 0, dt),
        "wo": dense_init(ks[3], (lo.hp * dh, d), 0, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((lo.hp * dh,), dt)
        p["bk"] = jnp.zeros((lo.khp * dh,), dt)
        p["bv"] = jnp.zeros((lo.khp * dh,), dt)
    return p


def gqa_qkv(p, x, cfg, rope=None, kv_x=None):
    """Project to q (B,S,hp,dh) and k,v (B,T,khp,dh); apply rope if given.
    kv_x: source for k/v (cross-attention uses encoder states)."""
    lo = layout_from_cfg(cfg)
    b, s, _ = x.shape
    src = x if kv_x is None else kv_x
    t = src.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, lo.hp, cfg.head_dim)
    k = k.reshape(b, t, lo.khp, cfg.head_dim)
    v = v.reshape(b, t, lo.khp, cfg.head_dim)
    # NOTE (EXPERIMENTS.md §Perf cell C, iter C3 — refuted): re-sharding
    # K/V to batch-only here to avoid sub-head partial-score reduces was
    # measured WORSE (+0.7s collective) than letting SPMD keep half-head
    # shards; the constraint was removed again.
    if rope is not None:
        cos_q, sin_q, cos_k, sin_k = rope
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_k, sin_k)
    return q, k, v


def repeat_kv(k, gp: int):
    """(B,T,khp,dh) -> (B,T,khp*gp,dh) by broadcast (no copy until use)."""
    if gp == 1:
        return k
    b, t, kh, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, kh, gp, dh)) \
              .reshape(b, t, kh * gp, dh)


def sdpa(q, k, v, *, causal: bool, q_positions=None, k_positions=None,
         k_valid=None, gp: int = 1):
    """GQA-grouped scaled-dot-product attention.
    q (B,S,H,dh); k/v (B,T,KH,dh) with H = KH*gp -> out (B,S,H,dh).

    KV heads are NEVER materialized repeated: q is regrouped to
    (B,S,KH,gp,dh) and contracted against k/v directly. Besides avoiding
    the gp x KV copy, this keeps SPMD sharding propagation intact when the
    cache is sequence-sharded (a broadcast+reshape here forced XLA into
    'involuntary full rematerialization' = a full cache all-gather per
    layer — EXPERIMENTS.md §Perf cell A, iteration A2)."""
    b, s, h, dh = q.shape
    kh = k.shape[2]
    assert h == kh * gp, (h, kh, gp)
    qg = q.reshape(b, s, kh, gp, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) \
        * scale
    neg = jnp.finfo(jnp.float32).min
    if causal:
        qp = q_positions if q_positions is not None else jnp.arange(s)
        kp = k_positions if k_positions is not None else jnp.arange(
            k.shape[1])
        if qp.ndim == 1:
            mask = qp[:, None] < kp[None, :]
            scores = jnp.where(mask[None, None, None], neg, scores)
        else:
            mask = qp[:, None, :, None] < kp[:, None, None, :]
            scores = jnp.where(mask[:, :, None], neg, scores)
    if k_valid is not None:  # (B,T) bool: cache entries that exist
        scores = jnp.where(k_valid[:, None, None, None, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs.astype(q.dtype), v)
    return ctx.reshape(b, s, h, v.shape[-1])  # dv != dh under MLA


def chunked_sdpa(q, k, v, *, causal: bool, chunk: int, gp: int = 1):
    """jnp-flash: scan over query chunks so the (S x T) score matrix is never
    materialized at once. Used for long prefill (DESIGN.md §3). Each chunk
    step is rematerialized under grad. GQA-grouped (see sdpa)."""
    b, s, h, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    kh = k.shape[2]
    assert h == kh * gp, (h, kh, gp)
    t = k.shape[1]
    scale = dh ** -0.5
    kpos = jnp.arange(t)

    def step(carry, qc_i):
        qc, i = qc_i                                 # (B,chunk,KH,gp,dh)
        scores = jnp.einsum("bskgd,btkd->bkgst", qc, k).astype(jnp.float32)
        scores = scores * scale
        if causal:
            qpos = i * chunk + jnp.arange(chunk)
            neg = jnp.finfo(jnp.float32).min
            scores = jnp.where(
                (qpos[:, None] < kpos[None, :])[None, None, None],
                neg, scores)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(q.dtype), v)
        return carry, out

    qs = q.reshape(b, s // chunk, chunk, kh, gp, dh).transpose(
        1, 0, 2, 3, 4, 5)
    _, outs = jax.lax.scan(jax.checkpoint(step), None,
                           (qs, jnp.arange(s // chunk)))
    dv = v.shape[-1]  # may differ from dh (MLA: qk=192, v=128)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dv)


def gqa_out(p, ctx, cfg):
    """Mask padded heads (exact-zero contribution + grads), then w_o."""
    lo = layout_from_cfg(cfg)
    b, s = ctx.shape[:2]
    if lo.hp != lo.n_q:
        ctx = ctx * lo.q_mask[None, None, :, None].astype(ctx.dtype)
    return jnp.einsum("bsh,hd->bsd", ctx.reshape(b, s, lo.hp * cfg.head_dim),
                      p["wo"])


# ------------------------------------------------------------------ MLA ----
def init_mla(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    dt = pdtype(cfg)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), 0, dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, h * qk), 0, dt),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            0, dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim),
                           0, dt),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), 0, dt),
        "wo": dense_init(ks[5], (h * m.v_head_dim, d), 0, dt),
    }


def mla_q(p, x, cfg, cos, sin):
    """-> q_nope (B,S,H,nope), q_rope (B,S,H,rope)."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    cq = rmsnorm_vec(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"],
                     cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], cos, sin)
    return q_nope, q_rope


def mla_latent_kv(p, x, cfg, cos, sin):
    """-> c_kv (B,S,r) normalized latent, k_rope (B,S,rope) (shared head,
    rope applied). This pair IS the KV cache (physical representation:
    r+rope floats per token instead of 2*H*head_dim)."""
    m = cfg.mla
    ckr = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rmsnorm_vec(ckr[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(ckr[:, :, None, m.kv_lora_rank:], cos, sin)[:, :, 0]
    return c_kv, k_rope


def mla_attention_full(p, x, cfg, cos, sin, *, causal=True, chunk=0):
    """Train/prefill path: reconstruct per-head K,V from the latent then run
    standard attention (flops-faithful to the naive formulation)."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope = mla_q(p, x, cfg, cos, sin)
    c_kv, k_rope = mla_latent_kv(p, x, cfg, cos, sin)
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, p["w_uk"]).reshape(
        b, s, h, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["w_uv"]).reshape(
        b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], -1)
    if chunk and s > chunk:
        ctx = chunked_sdpa(q, k, v, causal=causal, chunk=chunk)
    else:
        ctx = sdpa(q, k, v, causal=causal)
    out = jnp.einsum("bsh,hd->bsd", ctx.reshape(b, s, h * m.v_head_dim),
                     p["wo"])
    return out, (c_kv, k_rope)


def mla_attention_decode(p, x, cfg, cos, sin, c_kv_cache, k_rope_cache,
                         k_valid):
    """Absorbed decode: score and aggregate directly in latent space —
    O(S * (r + rope)) per head instead of reconstructing K/V.
    x (B,1,d); c_kv_cache (B,T,r) (current token already written)."""
    m, h = cfg.mla, cfg.n_heads
    b = x.shape[0]
    q_nope, q_rope = mla_q(p, x, cfg, cos, sin)          # (B,1,H,*)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)   # absorb W_UK
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv_cache)
              + jnp.einsum("bshn,btn->bhst", q_rope, k_rope_cache))
    scores = scores.astype(jnp.float32) * scale
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(k_valid[:, None, None, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv_cache)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    ctx = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_uv)    # absorb W_UV
    return jnp.einsum("bsh,hd->bsd", ctx.reshape(b, 1, h * m.v_head_dim),
                      p["wo"])
