"""Decoder-only model assembly for dense / moe / vlm / ssm / hybrid
families. Layer stacks run under ``jax.lax.scan`` over stacked params
(compile-time O(1) in depth); the hybrid (zamba2) family unrolls into
[6-SSM-layer scan -> shared-attention block] segments so the shared block's
KV cache is handled at the python level.

Three entry points, shared by training and serving:
  forward(params, batch)          -> (logits (B,S,Vp), aux)
  prefill(params, batch)          -> (last_logits (B,Vp), cache)
  decode_step(params, cache, tok) -> (logits (B,Vp), cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn
from repro.models import ssm as ssm_mod
from repro.models.common import (
    apply_norm, dense_init, embed_tokens, init_embedding, init_lm_head,
    init_norm, lm_logits, mrope_for_heads, pdtype, rope_for_heads)
from repro.serve import kvcache


# ------------------------------------------------------------------ init ---
def _init_dense_layer(key, cfg):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg), "ln2": init_norm(cfg)}
    p["attn"] = (attn.init_mla(ks[0], cfg) if cfg.mla is not None
                 else attn.init_gqa(ks[0], cfg))
    if cfg.moe is not None:
        p["moe"] = ffn.init_moe(ks[1], cfg)
    else:
        p["mlp"] = ffn.init_mlp(ks[1], cfg)
    return p


def _init_ssm_layer(key, cfg):
    return {"ln1": init_norm(cfg), "ssm": ssm_mod.init_ssm(key, cfg)}


def _stack(layers):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_decoder(key, cfg):
    ks = jax.random.split(key, cfg.n_layers + 4)
    p: dict[str, Any] = {"embed": init_embedding(ks[0], cfg),
                         "final_norm": init_norm(cfg)}
    p.update(init_lm_head(ks[1], cfg))
    if cfg.family in ("dense", "moe", "vlm"):
        p["layers"] = _stack([_init_dense_layer(ks[3 + i], cfg)
                              for i in range(cfg.n_layers)])
    elif cfg.family == "ssm":
        p["layers"] = _stack([_init_ssm_layer(ks[3 + i], cfg)
                              for i in range(cfg.n_layers)])
    elif cfg.family == "hybrid":
        p["layers"] = _stack([_init_ssm_layer(ks[3 + i], cfg)
                              for i in range(cfg.n_layers)])
        p["shared"] = _init_dense_layer(ks[2], cfg)  # ONE block, reused
    else:
        raise ValueError(cfg.family)
    return p


# ------------------------------------------------------------ rope setup ---
def _make_rope(cfg, positions, mrope_positions=None):
    """-> (cos, sin) shaped (B, S, 1, rot/2) or None (whisper-style)."""
    if not cfg.uses_attention:
        return None
    if cfg.rope_theta == 0.0:
        return None
    rot = (cfg.mla.qk_rope_head_dim if cfg.mla is not None else cfg.head_dim)
    if cfg.vision is not None and mrope_positions is not None:
        return mrope_for_heads(mrope_positions, rot, cfg.rope_theta,
                               cfg.vision.mrope_sections)
    return rope_for_heads(positions, rot, cfg.rope_theta)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ----------------------------------------------------- dense-family body ---
def _dense_block(lp, h, cfg, rope, *, chunk, moe_groups, cache_slice=None,
                 pos=None):
    """One transformer block. cache_slice given => decode (S==1)."""
    cos, sin = (rope if rope is not None else (None, None))
    ain = apply_norm(lp["ln1"], h, cfg)
    new_cache = None
    collected = None
    if cfg.mla is not None:
        if cache_slice is not None:
            c_kv_new, k_rope_new = attn.mla_latent_kv(lp["attn"], ain, cfg,
                                                      cos, sin)
            bidx = jnp.arange(h.shape[0])
            c_kv = cache_slice["c_kv"].at[bidx, pos].set(
                c_kv_new[:, 0].astype(cache_slice["c_kv"].dtype))
            k_rope = cache_slice["k_rope"].at[bidx, pos].set(
                k_rope_new[:, 0].astype(cache_slice["k_rope"].dtype))
            k_valid = jnp.arange(c_kv.shape[1])[None] <= pos[:, None]
            aout = attn.mla_attention_decode(
                lp["attn"], ain, cfg, cos, sin,
                c_kv.astype(h.dtype), k_rope.astype(h.dtype), k_valid)
            new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            aout, (c_kv, k_rope) = attn.mla_attention_full(
                lp["attn"], ain, cfg, cos, sin, chunk=chunk)
            collected = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        lo = attn.layout_from_cfg(cfg)
        rope4 = None if cos is None else (cos, sin, cos, sin)
        q, k, v = attn.gqa_qkv(lp["attn"], ain, cfg, rope=rope4)
        if cache_slice is not None:
            new_cache = kvcache.write_kv_layer(cache_slice, k, v, pos)
            kf, vf = kvcache.read_kv_layer(new_cache, h.dtype)
            k_valid = jnp.arange(kf.shape[1])[None] <= pos[:, None]
            ctx = attn.sdpa(q, kf, vf, causal=False, k_valid=k_valid,
                            gp=lo.gp)
        else:
            if chunk and h.shape[1] > chunk:
                ctx = attn.chunked_sdpa(q, k, v, causal=True, chunk=chunk,
                                        gp=lo.gp)
            else:
                ctx = attn.sdpa(q, k, v, causal=True, gp=lo.gp)
            collected = {"k": k, "v": v}
        aout = attn.gqa_out(lp["attn"], ctx, cfg)
    h = h + aout
    fin = apply_norm(lp["ln2"], h, cfg)
    if cfg.moe is not None:
        mout, aux = ffn.apply_moe(lp["moe"], fin, cfg, moe_groups)
    else:
        mout, aux = ffn.apply_mlp(lp["mlp"], fin, cfg), jnp.float32(0)
    return h + mout, aux, collected, new_cache


# ------------------------------------------------------------- forward -----
def _embed_input(params, batch, cfg):
    h = embed_tokens(params["embed"], batch["tokens"], cfg).astype(pdtype(cfg))
    ve = batch.get("vision_embeds")
    if ve is not None:  # VLM stub: patch embeddings replace the prefix
        h = jnp.concatenate([ve.astype(h.dtype), h[:, ve.shape[1]:]], axis=1)
    return h


def forward(params, batch, cfg, *, remat_policy="full", attn_chunk=0,
            moe_groups=1, collect_cache=False, logits_last_only=False):
    """Full-sequence pass. Returns (logits, aux, cache_pieces|None).
    logits_last_only: compute the LM head on the final position only
    (prefill optimization — decode needs just one next-token
    distribution; saves T x V logit compute/memory/collectives)."""
    h = _embed_input(params, batch, cfg)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    rope = _make_rope(cfg, positions, batch.get("mrope_positions"))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            out, aux, coll, _ = _dense_block(
                lp, carry, cfg, rope, chunk=attn_chunk,
                moe_groups=moe_groups)
            ys = {"aux": aux}
            if collect_cache:
                ys["cache"] = coll
            return out, ys
        h, ys = jax.lax.scan(_remat(body, remat_policy), h, params["layers"])
        aux = jnp.sum(ys["aux"])
        cache_pieces = ys.get("cache")
    elif cfg.family == "ssm":
        def body(carry, lp):
            out, st = ssm_mod.apply_ssm(
                lp["ssm"], apply_norm(lp["ln1"], carry, cfg), cfg,
                collect_state=collect_cache)
            ys = {"st": st} if collect_cache else {}
            return carry + out, ys
        h, ys = jax.lax.scan(_remat(body, remat_policy), h, params["layers"])
        aux = jnp.float32(0)
        cache_pieces = ys.get("st")
    elif cfg.family == "hybrid":
        h, aux, cache_pieces = _hybrid_forward(
            params, h, cfg, rope, remat_policy=remat_policy,
            attn_chunk=attn_chunk, collect_cache=collect_cache)
    else:
        raise ValueError(cfg.family)

    if logits_last_only:
        h = h[:, -1:]
    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params, params["embed"], h, cfg)
    return logits, aux, cache_pieces


def hybrid_segments(cfg):
    """[(n_ssm_layers, has_shared_attn_after), ...]."""
    every = cfg.hybrid_attn_every
    segs = []
    done = 0
    while done < cfg.n_layers:
        n = min(every, cfg.n_layers - done)
        done += n
        segs.append((n, n == every))
    return segs


def _tree_slice(tree, lo, hi):
    return jax.tree.map(lambda x: x[lo:hi], tree)


def _hybrid_forward(params, h, cfg, rope, *, remat_policy, attn_chunk,
                    collect_cache):
    def ssm_body(carry, lp):
        out, st = ssm_mod.apply_ssm(
            lp["ssm"], apply_norm(lp["ln1"], carry, cfg), cfg,
            collect_state=collect_cache)
        return carry + out, ({"st": st} if collect_cache else {})

    ssm_states, shared_kv = [], []
    lo_i = 0
    for n, has_attn in hybrid_segments(cfg):
        seg = _tree_slice(params["layers"], lo_i, lo_i + n)
        lo_i += n
        h, ys = jax.lax.scan(_remat(ssm_body, remat_policy), h, seg)
        if collect_cache:
            ssm_states.append(ys["st"])
        if has_attn:
            h, _, coll, _ = _dense_block(params["shared"], h, cfg, rope,
                                         chunk=attn_chunk, moe_groups=1)
            if collect_cache:
                shared_kv.append(coll)
    cache_pieces = None
    if collect_cache:
        ssm_all = jax.tree.map(lambda *xs: jnp.concatenate(xs), *ssm_states)
        kv_all = (jax.tree.map(lambda *xs: jnp.stack(xs), *shared_kv)
                  if shared_kv else None)
        cache_pieces = {"ssm": ssm_all, "shared": kv_all}
    return h, jnp.float32(0), cache_pieces


# -------------------------------------------------------------- prefill ----
def prefill(params, batch, cfg, *, attn_chunk=0, kv_dtype="bfloat16",
            moe_groups=1, last_only=False):
    """Returns (last-token logits (B,Vp), decode-ready cache)."""
    logits, _, pieces = forward(params, batch, cfg, remat_policy="none",
                                attn_chunk=attn_chunk, moe_groups=moe_groups,
                                collect_cache=True,
                                logits_last_only=last_only)
    b, s = batch["tokens"].shape
    cache: dict = {"pos": jnp.full((b,), s, jnp.int32)}
    cache_dt = jnp.bfloat16 if kv_dtype == "int8" else jnp.dtype(kv_dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.mla is not None:
            cache["mla"] = {
                "c_kv": pieces["c_kv"].astype(cache_dt),
                "k_rope": pieces["k_rope"].astype(cache_dt)}
        else:
            if kv_dtype == "int8":
                kq, ks_ = kvcache._q8(pieces["k"])
                vq, vs_ = kvcache._q8(pieces["v"])
                cache["kv"] = {"k": kq, "v": vq, "k_scale": ks_,
                               "v_scale": vs_}
            else:
                cache["kv"] = {
                    "k": pieces["k"].astype(jnp.dtype(kv_dtype)),
                    "v": pieces["v"].astype(jnp.dtype(kv_dtype))}
    elif cfg.family == "ssm":
        cache["ssm"] = pieces
    elif cfg.family == "hybrid":
        cache["ssm"] = pieces["ssm"]
        if pieces["shared"] is not None:
            cache["shared_attn"] = {
                "k": pieces["shared"]["k"].astype(cache_dt),
                "v": pieces["shared"]["v"].astype(cache_dt)}
    return logits[:, -1], cache


# ---------------------------------------------------------------- decode ---
def decode_step(params, cache, batch, cfg, *, moe_groups=1):
    """One token: batch["tokens"] (B,1). Returns (logits (B,Vp), cache)."""
    h = _embed_input(params, batch, cfg)
    pos = cache["pos"]                                  # (B,) write index
    mp = batch.get("mrope_positions")
    rope = _make_rope(cfg, pos[:, None], mp)

    new_cache = dict(cache)
    if cfg.family in ("dense", "moe", "vlm"):
        layer_cache = cache["mla"] if cfg.mla is not None else cache["kv"]

        def body(carry, xs):
            lp, lc = xs
            out, _, _, nc = _dense_block(lp, carry, cfg, rope,
                                         chunk=0, moe_groups=moe_groups,
                                         cache_slice=lc, pos=pos)
            return out, nc
        h, upd = jax.lax.scan(body, h, (params["layers"], layer_cache))
        new_cache["mla" if cfg.mla is not None else "kv"] = upd
    elif cfg.family == "ssm":
        def body(carry, xs):
            lp, lc = xs
            out, nc = ssm_mod.apply_ssm(
                lp["ssm"], apply_norm(lp["ln1"], carry, cfg), cfg, cache=lc)
            return carry + out, nc
        h, upd = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = upd
    elif cfg.family == "hybrid":
        h, new_cache = _hybrid_decode(params, h, cache, cfg, rope, pos,
                                      new_cache)
    else:
        raise ValueError(cfg.family)

    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params, params["embed"], h, cfg)
    new_cache["pos"] = pos + 1
    return logits[:, -1], new_cache


def _hybrid_decode(params, h, cache, cfg, rope, pos, new_cache):
    def ssm_body(carry, xs):
        lp, lc = xs
        out, nc = ssm_mod.apply_ssm(
            lp["ssm"], apply_norm(lp["ln1"], carry, cfg), cfg, cache=lc)
        return carry + out, nc

    ssm_upds, kv_upds = [], []
    lo_i = inv = 0
    for n, has_attn in hybrid_segments(cfg):
        seg = _tree_slice(params["layers"], lo_i, lo_i + n)
        seg_cache = _tree_slice(cache["ssm"], lo_i, lo_i + n)
        lo_i += n
        h, upd = jax.lax.scan(ssm_body, h, (seg, seg_cache))
        ssm_upds.append(upd)
        if has_attn:
            lc = jax.tree.map(lambda x: x[inv], cache["shared_attn"])
            inv += 1
            h, _, _, nc = _dense_block(params["shared"], h, cfg, rope,
                                       chunk=0, moe_groups=1,
                                       cache_slice=lc, pos=pos)
            kv_upds.append(nc)
    new_cache["ssm"] = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                    *ssm_upds)
    if kv_upds:
        new_cache["shared_attn"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                                *kv_upds)
    return h, new_cache
