"""TAHOMA's specialized classifier family (paper Fig. 3):
[conv(3x3) -> ReLU -> maxpool(2x2)] x L -> dense ReLU -> sigmoid output.

The architecture space A varies (n_conv_layers, conv_nodes, dense_nodes);
the input representation space F (resolution x color) is applied by
core/transforms.py BEFORE the model sees the image — jointly they form the
paper's model design space A x F (§IV Def. 5/6).

CNNs run in float32 (they are trained on CPU in this container; on TPU the
convs lower to im2col + the MXU matmul kernel — kernels/matmul.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TahomaCNNConfig


def init_cnn(key, cfg: TahomaCNNConfig):
    ks = jax.random.split(key, cfg.n_conv_layers + 2)
    params = {"conv": []}
    c_in = cfg.input_channels
    hw = cfg.input_hw
    for i in range(cfg.n_conv_layers):
        w = jax.random.normal(ks[i], (cfg.kernel_size, cfg.kernel_size,
                                      c_in, cfg.conv_nodes)) * (
            2.0 / (cfg.kernel_size ** 2 * c_in)) ** 0.5
        params["conv"].append({"w": w.astype(jnp.float32),
                               "b": jnp.zeros((cfg.conv_nodes,))})
        c_in = cfg.conv_nodes
        hw = hw // 2
    flat = hw * hw * c_in
    params["dense_w"] = (jax.random.normal(ks[-2], (flat, cfg.dense_nodes))
                         * (2.0 / flat) ** 0.5).astype(jnp.float32)
    params["dense_b"] = jnp.zeros((cfg.dense_nodes,))
    params["out_w"] = (jax.random.normal(ks[-1], (cfg.dense_nodes, 1))
                       * (1.0 / cfg.dense_nodes) ** 0.5).astype(jnp.float32)
    params["out_b"] = jnp.zeros((1,))
    return params


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params, images):
    """images (B, H, W, C) float32 in [0,1] -> pre-sigmoid logits (B,)."""
    h = images
    for layer in params["conv"]:
        h = jax.lax.conv_general_dilated(
            h, layer["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + layer["b"])
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense_w"] + params["dense_b"])
    return (h @ params["out_w"] + params["out_b"])[:, 0]


def cnn_predict_proba(params, images):
    return jax.nn.sigmoid(cnn_forward(params, images))


def cnn_flops(cfg: TahomaCNNConfig) -> float:
    """Forward FLOPs per image (the cost profiler's analytic input)."""
    total = 0.0
    hw, c_in = cfg.input_hw, cfg.input_channels
    for _ in range(cfg.n_conv_layers):
        total += 2.0 * hw * hw * cfg.kernel_size ** 2 * c_in \
            * cfg.conv_nodes
        c_in = cfg.conv_nodes
        hw //= 2
    flat = hw * hw * c_in
    total += 2.0 * flat * cfg.dense_nodes + 2.0 * cfg.dense_nodes
    return total


def quantize_cnn(params):
    """Weight-only int8 quantization (per-tensor symmetric, scale =
    absmax/127). Biases stay float32 — they are tiny and additive.

    Returns a pytree mirroring ``params`` where every weight tensor is
    replaced by ``{"q": int8, "scale": f32 scalar}``. Dequantize-at-use
    (``dequantize_cnn``) keeps the arithmetic in f32, so the deviation
    from the f32 model is bounded by the weight rounding alone — the
    calibrated tolerance pinned in benchmarks/calibrated_int8_stage0.json.
    """
    def q(w):
        w = jnp.asarray(w, jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / 127.0
        return {"q": jnp.clip(jnp.round(w / scale), -127, 127
                              ).astype(jnp.int8),
                "scale": scale.astype(jnp.float32)}

    return {
        "conv": [{"w": q(l["w"]), "b": jnp.asarray(l["b"], jnp.float32)}
                 for l in params["conv"]],
        "dense_w": q(params["dense_w"]),
        "dense_b": jnp.asarray(params["dense_b"], jnp.float32),
        "out_w": q(params["out_w"]),
        "out_b": jnp.asarray(params["out_b"], jnp.float32),
    }


def dequantize_cnn(qparams):
    """Inverse of ``quantize_cnn`` up to rounding: int8 weights back to
    f32 (``q * scale``), shaped exactly like ``init_cnn`` output so the
    result feeds ``cnn_forward`` unchanged."""
    def dq(t):
        return t["q"].astype(jnp.float32) * t["scale"]

    return {
        "conv": [{"w": dq(l["w"]), "b": l["b"]} for l in qparams["conv"]],
        "dense_w": dq(qparams["dense_w"]),
        "dense_b": qparams["dense_b"],
        "out_w": dq(qparams["out_w"]),
        "out_b": qparams["out_b"],
    }


def bce_loss(params, images, labels):
    """Numerically-stable binary cross-entropy (labels in {0,1})."""
    logits = cnn_forward(params, images)
    z = jnp.maximum(logits, 0.0)
    loss = z - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return jnp.mean(loss)
