"""TAHOMA's specialized classifier family (paper Fig. 3):
[conv(3x3) -> ReLU -> maxpool(2x2)] x L -> dense ReLU -> sigmoid output.

The architecture space A varies (n_conv_layers, conv_nodes, dense_nodes);
the input representation space F (resolution x color) is applied by
core/transforms.py BEFORE the model sees the image — jointly they form the
paper's model design space A x F (§IV Def. 5/6).

CNNs run in float32 (they are trained on CPU in this container; on TPU the
convs lower to im2col + the MXU matmul kernel — kernels/matmul.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TahomaCNNConfig


def init_cnn(key, cfg: TahomaCNNConfig):
    ks = jax.random.split(key, cfg.n_conv_layers + 2)
    params = {"conv": []}
    c_in = cfg.input_channels
    hw = cfg.input_hw
    for i in range(cfg.n_conv_layers):
        w = jax.random.normal(ks[i], (cfg.kernel_size, cfg.kernel_size,
                                      c_in, cfg.conv_nodes)) * (
            2.0 / (cfg.kernel_size ** 2 * c_in)) ** 0.5
        params["conv"].append({"w": w.astype(jnp.float32),
                               "b": jnp.zeros((cfg.conv_nodes,))})
        c_in = cfg.conv_nodes
        hw = hw // 2
    flat = hw * hw * c_in
    params["dense_w"] = (jax.random.normal(ks[-2], (flat, cfg.dense_nodes))
                         * (2.0 / flat) ** 0.5).astype(jnp.float32)
    params["dense_b"] = jnp.zeros((cfg.dense_nodes,))
    params["out_w"] = (jax.random.normal(ks[-1], (cfg.dense_nodes, 1))
                       * (1.0 / cfg.dense_nodes) ** 0.5).astype(jnp.float32)
    params["out_b"] = jnp.zeros((1,))
    return params


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params, images):
    """images (B, H, W, C) float32 in [0,1] -> pre-sigmoid logits (B,)."""
    h = images
    for layer in params["conv"]:
        h = jax.lax.conv_general_dilated(
            h, layer["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + layer["b"])
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense_w"] + params["dense_b"])
    return (h @ params["out_w"] + params["out_b"])[:, 0]


def cnn_predict_proba(params, images):
    return jax.nn.sigmoid(cnn_forward(params, images))


def cnn_flops(cfg: TahomaCNNConfig) -> float:
    """Forward FLOPs per image (the cost profiler's analytic input)."""
    total = 0.0
    hw, c_in = cfg.input_hw, cfg.input_channels
    for _ in range(cfg.n_conv_layers):
        total += 2.0 * hw * hw * cfg.kernel_size ** 2 * c_in \
            * cfg.conv_nodes
        c_in = cfg.conv_nodes
        hw //= 2
    flat = hw * hw * c_in
    total += 2.0 * flat * cfg.dense_nodes + 2.0 * cfg.dense_nodes
    return total


def bce_loss(params, images, labels):
    """Numerically-stable binary cross-entropy (labels in {0,1})."""
    logits = cnn_forward(params, images)
    z = jnp.maximum(logits, 0.0)
    loss = z - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return jnp.mean(loss)
