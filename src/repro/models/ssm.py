"""Mamba-2 (SSD — state-space duality) block, chunked, TPU-friendly.

Layout follows the Mamba-2 reference: in_proj -> [z | x | B | C | dt],
depthwise causal conv over (x,B,C), SiLU, chunked SSD recurrence, gated
RMSNorm, out_proj. The projections are *split into separate weights* (w_z,
w_x, w_b, w_c, w_dt and conv_x/conv_b/conv_c) — algebraically identical to
the fused layouts (depthwise conv has no cross-channel mixing) but each
piece then carries its own clean PartitionSpec (DESIGN.md §6).

TP head padding: SSM heads are padded like attention heads; padded-head
outputs are zero-masked before the gated norm and the norm denominator uses
the TRUE channel count, so numerics match the unpadded model exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, pdtype


def init_ssm(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    din = cfg.d_inner_padded
    hp = cfg.ssm_heads_padded
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 11)
    dt = pdtype(cfg)
    kconv = s.d_conv

    def conv_w(k, ch):
        return (jax.random.uniform(k, (ch, kconv), jnp.float32,
                                   -1.0, 1.0) / kconv).astype(dt)

    a = jax.random.uniform(ks[7], (hp,), jnp.float32,
                           cfg.ssm.a_init_range[0], cfg.ssm.a_init_range[1])
    dt0 = jnp.exp(jax.random.uniform(ks[8], (hp,), jnp.float32)
                  * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
                  + jnp.log(s.dt_min))
    dt0 = jnp.clip(dt0, 1e-4, None)
    return {
        "w_z": dense_init(ks[0], (d, din), 0, dt),
        "w_x": dense_init(ks[1], (d, din), 0, dt),
        "w_b": dense_init(ks[2], (d, gn), 0, dt),
        "w_c": dense_init(ks[3], (d, gn), 0, dt),
        "w_dt": dense_init(ks[4], (d, hp), 0, dt),
        "conv_x": conv_w(ks[5], din), "conv_x_b": jnp.zeros((din,), dt),
        "conv_b": conv_w(ks[6], gn), "conv_b_b": jnp.zeros((gn,), dt),
        "conv_c": conv_w(ks[9], gn), "conv_c_b": jnp.zeros((gn,), dt),
        "a_log": jnp.log(a),                       # A = -exp(a_log)
        "dt_bias": jnp.log(jnp.expm1(dt0)),        # softplus inverse
        "d_skip": jnp.ones((hp,), jnp.float32),
        "norm_scale": jnp.ones((din,), dt),
        "w_out": dense_init(ks[10], (din, d), 0, dt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x (B,S,ch), w (ch,K). If ``state`` (B,ch,K-1)
    is given (decode), x is (B,1,ch) and the updated state is returned."""
    k = w.shape[1]
    if state is None:
        pads = [jnp.pad(x, ((0, 0), (k - 1 - i, 0), (0, 0)))[:, :x.shape[1]]
                for i in range(k)]
        out = sum(p * w[None, None, :, i] for i, p in enumerate(pads))
        return out + b, None
    window = jnp.concatenate([state, x.transpose(0, 2, 1)], axis=2)  # (B,ch,K)
    out = jnp.sum(window * w[None], axis=2)[:, None, :] + b
    return out, window[:, :, 1:]


def _segsum_decay(da_cum):
    """da_cum (..., L) -> lower-triangular exp(da_cum[i]-da_cum[j]) i>=j.
    Mask BEFORE exp: the upper triangle has positive exponents that
    overflow to inf and poison the where-gradient (0 * inf = NaN)."""
    li = da_cum[..., :, None] - da_cum[..., None, :]
    mask = jnp.tril(jnp.ones(li.shape[-2:], bool))
    return jnp.exp(jnp.where(mask, li, -jnp.inf))


def ssd_chunked(x, dtv, a, bmat, cmat, chunk, initial_state=None):
    """SSD over a full sequence, chunked.
    x (B,S,H,P) head inputs; dtv (B,S,H) positive step sizes; a (H,)
    negative decay; bmat/cmat (B,S,N) (n_groups==1, shared across heads).
    Returns y (B,S,H,P) float32 and final state (B,H,P,N) float32."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    xf = x.astype(jnp.float32).reshape(b, nc, l, h, p)
    dtf = dtv.astype(jnp.float32).reshape(b, nc, l, h)
    bf = bmat.astype(jnp.float32).reshape(b, nc, l, n)
    cf = cmat.astype(jnp.float32).reshape(b, nc, l, n)

    da = dtf * a[None, None, None, :]                      # (b,nc,l,h) <= 0
    da_cum = jnp.cumsum(da, axis=2)
    xdt = xf * dtf[..., None]

    # intra-chunk (the "attention-like" quadratic-in-l term)
    cb = jnp.einsum("bcln,bcsn->bcls", cf, bf)             # shared over h
    decay = _segsum_decay(da_cum.transpose(0, 1, 3, 2))    # (b,nc,h,l,l)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        cb, decay, xdt)

    # chunk -> state contributions
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bf, decay_to_end, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])             # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                  # emit ENTERING state

    final, states_in = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)         # (b,nc,h,p,n)

    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       cf, states_in, jnp.exp(da_cum))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssd_decode(x, dtv, a, bmat, cmat, state):
    """Single-token SSD update. x (B,1,H,P); state (B,H,P,N) float32."""
    xf = x.astype(jnp.float32)[:, 0]                       # (B,H,P)
    dtf = dtv.astype(jnp.float32)[:, 0]                    # (B,H)
    bf = bmat.astype(jnp.float32)[:, 0]                    # (B,N)
    cf = cmat.astype(jnp.float32)[:, 0]
    da = jnp.exp(dtf * a[None, :])                         # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dtf, bf, xf)
    new_state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, cf)
    return y[:, None], new_state                           # (B,1,H,P)


def _gated_norm(y, z, scale, true_dim: int, eps: float):
    """RMSNorm(y * silu(z)) with the denominator using the TRUE channel
    count so zero-padded channels do not perturb real outputs."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    ms = jnp.sum(g * g, axis=-1, keepdims=True) / true_dim
    return (g * jax.lax.rsqrt(ms + eps)) * scale.astype(jnp.float32)


def apply_ssm(p, x, cfg, cache=None, collect_state: bool = False):
    """Full-sequence when cache is None; single-token decode otherwise.
    cache = {"conv_x","conv_b","conv_c","state"}. Returns (out, new_cache).
    collect_state=True (prefill): new_cache carries the decode-ready state
    (conv windows over the last K-1 raw projected inputs + final SSD state).
    """
    s = cfg.ssm
    b, seqlen, _ = x.shape
    hp, hd = cfg.ssm_heads_padded, s.head_dim
    h_true = cfg.ssm_heads

    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    xi = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    bi = jnp.einsum("bsd,dn->bsn", x, p["w_b"])
    ci = jnp.einsum("bsd,dn->bsn", x, p["w_c"])
    dtv = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    dtv = jax.nn.softplus(dtv.astype(jnp.float32)
                          + p["dt_bias"][None, None].astype(jnp.float32))

    decode = cache is not None
    k1 = s.d_conv - 1
    raw_windows = None
    if collect_state:
        raw_windows = (xi[:, -k1:].transpose(0, 2, 1),
                       bi[:, -k1:].transpose(0, 2, 1),
                       ci[:, -k1:].transpose(0, 2, 1))
    xi, conv_x = _causal_conv(xi, p["conv_x"], p["conv_x_b"],
                              cache["conv_x"] if decode else None)
    bi, conv_b = _causal_conv(bi, p["conv_b"], p["conv_b_b"],
                              cache["conv_b"] if decode else None)
    ci, conv_c = _causal_conv(ci, p["conv_c"], p["conv_c_b"],
                              cache["conv_c"] if decode else None)
    xi, bi, ci = jax.nn.silu(xi), jax.nn.silu(bi), jax.nn.silu(ci)

    xh = xi.reshape(b, seqlen, hp, hd)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    if decode:
        y, state = ssd_decode(xh, dtv, a, bi, ci, cache["state"])
    else:
        y, state = ssd_chunked(xh, dtv, a, bi, ci, s.chunk_size)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]

    if hp != h_true:  # zero padded heads before the coupling norm
        mask = (jnp.arange(hp) < h_true).astype(jnp.float32)
        y = y * mask[None, None, :, None]
    y = y.reshape(b, seqlen, hp * hd)
    y = _gated_norm(y, z, p["norm_scale"], true_dim=h_true * hd,
                    eps=cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    if decode:
        new_cache = dict(conv_x=conv_x, conv_b=conv_b, conv_c=conv_c,
                         state=state)
    elif collect_state:
        new_cache = dict(conv_x=raw_windows[0], conv_b=raw_windows[1],
                         conv_c=raw_windows[2], state=state)
    else:
        new_cache = None
    return out, new_cache


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    k = s.d_conv - 1
    gn = s.n_groups * s.d_state
    return dict(
        conv_x=jnp.zeros((batch, cfg.d_inner_padded, k), dtype),
        conv_b=jnp.zeros((batch, gn, k), dtype),
        conv_c=jnp.zeros((batch, gn, k), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads_padded, s.head_dim, s.d_state),
                        jnp.float32),
    )
