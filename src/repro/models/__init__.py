from repro.models.factory import Model, build_model, count_params  # noqa: F401
