"""config -> Model: uniform init/forward/prefill/decode across families."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer
from repro.serve import kvcache


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]      # (params, batch, **opt) -> (logits, aux, cache|None)
    prefill: Callable[..., Any]      # (params, batch, **opt) -> (logits, cache)
    decode: Callable[..., Any]       # (params, cache, batch, **opt) -> (logits, cache)
    init_cache: Callable[..., Any]   # (batch, seq, kv_dtype) -> cache


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            forward=lambda p, b, **kw: encdec.forward(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: encdec.prefill(p, b, cfg, **kw),
            decode=lambda p, c, b, **kw: encdec.decode_step(p, c, b, cfg,
                                                            **kw),
            init_cache=lambda batch, seq, kv_dtype="bfloat16":
                kvcache.init_cache(cfg, batch, seq, kv_dtype),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_decoder(key, cfg),
        forward=lambda p, b, **kw: transformer.forward(p, b, cfg, **kw),
        prefill=lambda p, b, **kw: transformer.prefill(p, b, cfg, **kw),
        decode=lambda p, c, b, **kw: transformer.decode_step(p, c, b, cfg,
                                                             **kw),
        init_cache=lambda batch, seq, kv_dtype="bfloat16":
            kvcache.init_cache(cfg, batch, seq, kv_dtype),
    )


def count_params(params) -> int:
    import jax
    return sum(x.size for x in jax.tree.leaves(params))
