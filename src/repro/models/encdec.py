"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d_model). The encoder
is bidirectional; the decoder is causal with per-layer cross-attention whose
K/V are computed once from encoder output and cached for decode.
Positions: sinusoidal (encoder), learned (decoder); no RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn
from repro.models.common import (
    apply_norm, embed_init, embed_tokens, init_embedding, init_norm,
    lm_logits, pdtype, sinusoidal_positions)
from repro.serve import kvcache


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg), "attn": attn.init_gqa(ks[0], cfg),
            "ln2": init_norm(cfg), "mlp": ffn.init_mlp(ks[1], cfg)}


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg), "self_attn": attn.init_gqa(ks[0], cfg),
            "ln_x": init_norm(cfg), "cross_attn": attn.init_gqa(ks[1], cfg),
            "ln2": init_norm(cfg), "mlp": ffn.init_mlp(ks[2], cfg)}


def init_encdec(key, cfg):
    enc_l = cfg.encoder.n_layers
    ks = jax.random.split(key, enc_l + cfg.n_layers + 3)
    stack = lambda xs: jax.tree.map(lambda *y: jnp.stack(y), *xs)
    return {
        "embed": init_embedding(ks[0], cfg),
        "dec_pos": embed_init(ks[1], (cfg.max_seq_len, cfg.d_model),
                              pdtype(cfg)),
        "enc_layers": stack([_init_enc_layer(ks[2 + i], cfg)
                             for i in range(enc_l)]),
        "enc_norm": init_norm(cfg),
        "dec_layers": stack([_init_dec_layer(ks[2 + enc_l + i], cfg)
                             for i in range(cfg.n_layers)]),
        "final_norm": init_norm(cfg),
    }


def encode(params, enc_frames, cfg, remat_policy="full"):
    h = enc_frames.astype(pdtype(cfg))
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    lo = attn.layout_from_cfg(cfg)

    def body(carry, lp):
        ain = apply_norm(lp["ln1"], carry, cfg)
        q, k, v = attn.gqa_qkv(lp["attn"], ain, cfg)
        ctx = attn.sdpa(q, k, v, causal=False, gp=lo.gp)
        h2 = carry + attn.gqa_out(lp["attn"], ctx, cfg)
        h2 = h2 + ffn.apply_mlp(lp["mlp"], apply_norm(lp["ln2"], h2, cfg),
                                cfg)
        return h2, None

    fn = jax.checkpoint(body) if remat_policy != "none" else body
    h, _ = jax.lax.scan(fn, h, params["enc_layers"])
    return apply_norm(params["enc_norm"], h, cfg)


def _dec_block(lp, h, enc_out, cfg, *, self_cache=None, cross_kv=None,
               pos=None, collect=False):
    lo = attn.layout_from_cfg(cfg)
    ain = apply_norm(lp["ln1"], h, cfg)
    q, k, v = attn.gqa_qkv(lp["self_attn"], ain, cfg)
    new_self = collected = None
    if self_cache is not None:
        new_self = kvcache.write_kv_layer(self_cache, k, v, pos)
        kf, vf = kvcache.read_kv_layer(new_self, h.dtype)
        k_valid = jnp.arange(kf.shape[1])[None] <= pos[:, None]
        ctx = attn.sdpa(q, kf, vf, causal=False, k_valid=k_valid, gp=lo.gp)
    else:
        ctx = attn.sdpa(q, k, v, causal=True, gp=lo.gp)
        if collect:
            collected = {"k": k, "v": v}
    h = h + attn.gqa_out(lp["self_attn"], ctx, cfg)

    xin = apply_norm(lp["ln_x"], h, cfg)
    if cross_kv is not None:
        kx, vx = cross_kv
        qx = jnp.einsum("bsd,dh->bsh", xin, lp["cross_attn"]["wq"])
        if "bq" in lp["cross_attn"]:
            qx = qx + lp["cross_attn"]["bq"]
        qx = qx.reshape(*xin.shape[:2], lo.hp, cfg.head_dim)
    else:
        qx, kx, vx = attn.gqa_qkv(lp["cross_attn"], xin, cfg, kv_x=enc_out)
    ctx_x = attn.sdpa(qx, kx, vx, causal=False, gp=lo.gp)
    h = h + attn.gqa_out(lp["cross_attn"], ctx_x, cfg)

    h = h + ffn.apply_mlp(lp["mlp"], apply_norm(lp["ln2"], h, cfg), cfg)
    cross_coll = {"k": kx, "v": vx} if (collect and cross_kv is None) else None
    return h, collected, cross_coll, new_self


def forward(params, batch, cfg, *, remat_policy="full", collect_cache=False,
            logits_last_only=False, **_):
    enc_out = encode(params, batch["enc_frames"], cfg, remat_policy)
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens, cfg).astype(pdtype(cfg))
    h = h + params["dec_pos"][None, :tokens.shape[1]]

    def body(carry, lp):
        out, coll, cross, _ = _dec_block(lp, carry, enc_out, cfg,
                                         collect=collect_cache)
        ys = {"self": coll, "cross": cross} if collect_cache else {}
        return out, ys

    fn = jax.checkpoint(body) if remat_policy != "none" else body
    h, ys = jax.lax.scan(fn, h, params["dec_layers"])
    if logits_last_only:
        h = h[:, -1:]
    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params, params["embed"], h, cfg)
    return logits, jnp.float32(0), (ys if collect_cache else None)


def prefill(params, batch, cfg, *, kv_dtype="bfloat16", last_only=False,
            **_):
    logits, _, pieces = forward(params, batch, cfg, remat_policy="none",
                                collect_cache=True,
                                logits_last_only=last_only)
    b, s = batch["tokens"].shape
    cache_dt = jnp.bfloat16 if kv_dtype == "int8" else jnp.dtype(kv_dtype)
    cache = {
        "pos": jnp.full((b,), s, jnp.int32),
        "self": jax.tree.map(lambda x: x.astype(cache_dt), pieces["self"]),
        "cross": jax.tree.map(lambda x: x.astype(cache_dt),
                              pieces["cross"]),
    }
    return logits[:, -1], cache


def decode_step(params, cache, batch, cfg, **_):
    tokens = batch["tokens"]
    pos = cache["pos"]
    h = embed_tokens(params["embed"], tokens, cfg).astype(pdtype(cfg))
    h = h + jnp.take(params["dec_pos"], pos, axis=0)[:, None]

    def body(carry, xs):
        lp, self_c, cross_c = xs
        kx, vx = kvcache.read_kv_layer(cross_c, carry.dtype)
        out, _, _, new_self = _dec_block(lp, carry, None, cfg,
                                         self_cache=self_c,
                                         cross_kv=(kx, vx), pos=pos)
        return out, new_self

    h, new_self = jax.lax.scan(
        body, h, (params["dec_layers"], cache["self"], cache["cross"]))
    h = apply_norm(params["final_norm"], h, cfg)
    logits = lm_logits(params, params["embed"], h, cfg)
    return logits[:, -1], {**cache, "self": new_self, "pos": pos + 1}
