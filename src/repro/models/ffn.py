"""MLPs and Mixture-of-Experts.

MoE design (DESIGN.md §6): GShard-style *grouped capacity routing* written
entirely in pjit-friendly ops so XLA SPMD keeps every gather/scatter local:

* tokens are reshaped to (G, Tg, d) routing groups; the step builder picks
  G = data-parallel shard count for train/prefill (groups never cross a
  shard) and G = 1 for decode (tiny token counts; one all-gather is cheap);
* each expert takes its top-C tokens per group, C = ceil(Tg*k/E * factor)
  (over-capacity assignments are dropped — standard GShard semantics);
* expert weights are stacked (E, ...) and sharded over the 'model' axis
  (expert parallelism); the batched einsum over E runs one shard's experts
  on that shard, and the scatter-add back induces the expected
  reduce/all-reduce of activation size only.

Returns an auxiliary load-balance loss (Switch-style) for training.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init, pdtype
from repro.sharding import policy


# ------------------------------------------------------------- dense MLP ---
def init_mlp(key, cfg, d_ff: int | None = None, gated: bool | None = None):
    d_ff = d_ff or cfg.d_ff
    gated = (cfg.act == "silu") if gated is None else gated
    ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    if gated:
        return {"w_gate": dense_init(ks[0], (cfg.d_model, d_ff), 0, dt),
                "w_up": dense_init(ks[1], (cfg.d_model, d_ff), 0, dt),
                "w_down": dense_init(ks[2], (d_ff, cfg.d_model), 0, dt)}
    return {"w_in": dense_init(ks[0], (cfg.d_model, d_ff), 0, dt),
            "b_in": jnp.zeros((d_ff,), dt),
            "w_out": dense_init(ks[1], (d_ff, cfg.d_model), 0, dt),
            "b_out": jnp.zeros((cfg.d_model,), dt)}


def apply_mlp(p, x, cfg):
    act = activation(cfg.act)
    if "w_gate" in p:
        h = act(jnp.einsum("...d,df->...f", x, p["w_gate"]))
        h = h * jnp.einsum("...d,df->...f", x, p["w_up"])
        return jnp.einsum("...f,fd->...d", h, p["w_down"])
    h = act(jnp.einsum("...d,df->...f", x, p["w_in"]) + p["b_in"])
    return jnp.einsum("...f,fd->...d", h, p["w_out"]) + p["b_out"]


# ------------------------------------------------------------------- MoE ---
def init_moe(key, cfg):
    moe = cfg.moe
    ks = jax.random.split(key, 5)
    dt = pdtype(cfg)
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.num_experts

    def stacked(k, shape, in_axis):
        keys = jax.random.split(k, e)
        return jnp.stack([dense_init(kk, shape, in_axis, dt) for kk in keys])

    p = {
        "w_router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "w_gate_e": stacked(ks[1], (d, f), 0),
        "w_up_e": stacked(ks[2], (d, f), 0),
        "w_down_e": stacked(ks[3], (f, d), 0),
    }
    if moe.num_shared_experts:
        # n shared silu-gated experts of width w are algebraically one
        # gated MLP of width n*w (outputs sum).
        p["shared"] = init_mlp(
            ks[4], cfg, d_ff=moe.num_shared_experts * moe.d_ff_shared,
            gated=True)
    return p


def moe_capacity(tokens_per_group: int, cfg) -> int:
    moe = cfg.moe
    c = math.ceil(tokens_per_group * moe.top_k / moe.num_experts
                  * moe.capacity_factor)
    return max(1, min(c, tokens_per_group))


def apply_moe(p, x, cfg, n_groups: int):
    """x (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    g = max(1, min(n_groups, t))
    while t % g:            # always divisible in practice; safe fallback
        g -= 1
    tg = t // g
    cap = moe_capacity(tg, cfg)
    xg = x.reshape(g, tg, d)

    dp = policy.ctx_dp_axes() or None

    # bf16 inputs + f32 accumulation: casting xg itself to f32 makes its
    # cotangent an f32 (G,Tg,d) tensor whose cross-shard reductions double
    # the dominant collective volume (EXPERIMENTS.md §Perf cell B, iter B5).
    logits = jnp.einsum("gtd,de->gte", xg,
                        p["w_router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Tg,E)
    topv, topi = jax.lax.top_k(probs, moe.top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)        # renorm
    # gate[g,t,e] = routing weight of expert e for token t (0 if unrouted)
    gate = jnp.sum(jax.nn.one_hot(topi, moe.num_experts, dtype=jnp.float32)
                   * topv[..., None], axis=2)                  # (G,Tg,E)
    gate = policy.ctx_constrain(gate, dp, None, "model")

    # per-expert top-C token selection (per group). Keeping E sharded over
    # 'model' makes each shard gather ONLY its own experts' tokens (EP).
    sel_gate, sel_idx = jax.lax.top_k(gate.transpose(0, 2, 1), cap)  # (G,E,C)
    sel_gate = policy.ctx_constrain(sel_gate, dp, "model", None)
    sel_idx = policy.ctx_constrain(sel_idx, dp, "model", None)
    xe = jnp.take_along_axis(
        xg, sel_idx.reshape(g, moe.num_experts * cap)[..., None],
        axis=1).reshape(g, moe.num_experts, cap, d)
    xe = policy.ctx_constrain(xe, dp, "model", None, None)

    act = activation(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate_e"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up_e"])
    h = policy.ctx_constrain(h, dp, "model", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down_e"])
    ye = ye * sel_gate[..., None].astype(ye.dtype)             # weight+mask
    ye = policy.ctx_constrain(ye, dp, "model", None, None)

    out = jnp.zeros_like(xg)
    gidx = jnp.arange(g)[:, None, None]
    out = out.at[gidx, sel_idx].add(ye)
    out = policy.ctx_constrain(out, dp, None, None)

    if "shared" in p:
        out = out + apply_mlp(p["shared"], xg, cfg)

    # Switch-style load-balance aux loss
    token_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, moe.num_experts, dtype=jnp.float32),
                axis=2), axis=(0, 1))                          # (E,)
    prob_frac = jnp.mean(probs, axis=(0, 1))
    aux = moe.num_experts * jnp.sum(token_frac * prob_frac) / moe.top_k
    return out.reshape(b, s, d), aux
