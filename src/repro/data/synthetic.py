"""Synthetic labeled image corpora with *representation-sensitive* class
signal, standing in for the paper's ImageNet predicates on this offline
1-core container (EXPERIMENTS.md notes the substitution).

Each binary predicate k is parameterized by a color channel c_k and a
spatial frequency f_k. Positive images carry a sinusoidal texture of
frequency f_k in channel c_k (plus clutter); negatives carry clutter only.
Consequences mirror the paper's tradeoffs:
  * low-frequency predicates survive aggressive downscaling (30px models
    work) while high-frequency ones need resolution — resolution/accuracy
    tradeoff exists;
  * the signal lives in ONE channel — single-channel and grayscale
    representations differ in accuracy per predicate — color tradeoff
    exists;
  * clutter makes the task non-trivial so small CNNs are imperfect.

Also provides token-stream batches for the LM substrate examples/tests.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class PredicateSpec:
    name: str
    channel: int       # 0/1/2
    freq: float        # cycles across the image
    amplitude: float = 1.1


DEFAULT_PREDICATES = (
    PredicateSpec("acorn", 0, 2.0),
    PredicateSpec("ferret", 1, 4.0),
    PredicateSpec("pinwheel", 2, 8.0),
    PredicateSpec("scorpion", 0, 12.0),
    PredicateSpec("wallet", 1, 3.0),
    PredicateSpec("fence", 2, 6.0),
    PredicateSpec("cloak", 0, 5.0),
    PredicateSpec("coho", 1, 10.0),
    PredicateSpec("komondor", 2, 2.5),
    PredicateSpec("amphibian", 0, 7.0),
)


def _clutter(rng, n, hw):
    """Smooth random background clutter (shared by both classes)."""
    small = rng.normal(0.0, 0.8, size=(n, 8, 8, 3))
    k = hw // 8
    big = np.repeat(np.repeat(small, k, axis=1), k, axis=2)
    big += rng.normal(0.0, 0.18, size=(n, hw, hw, 3))
    return big


def make_corpus(spec: PredicateSpec, n: int, hw: int = 64, seed: int = 0,
                augment_flip: bool = False):
    """Balanced corpus: (images (N,hw,hw,3) float32 in [0,1], labels)."""
    rng = np.random.default_rng(seed + zlib.crc32(spec.name.encode())
                                % 100000)
    labels = np.zeros(n, np.int32)
    labels[: n // 2] = 1
    rng.shuffle(labels)
    x = _clutter(rng, n, hw)
    yy, xx = np.meshgrid(np.arange(hw), np.arange(hw), indexing="ij")
    phase = rng.uniform(0, 2 * np.pi, size=n)
    theta = rng.uniform(0, np.pi, size=n)
    for i in np.where(labels == 1)[0]:
        g = (np.cos(theta[i]) * xx + np.sin(theta[i]) * yy) / hw
        tex = np.sin(2 * np.pi * spec.freq * g + phase[i])
        x[i, :, :, spec.channel] += spec.amplitude * tex
    x = 0.5 + 0.18 * x
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    if augment_flip:  # paper §VII-A1 left-right flip augmentation
        x = np.concatenate([x, x[:, :, ::-1]], axis=0)
        labels = np.concatenate([labels, labels])
    return x, labels


def make_multi_corpus(specs, n: int, hw: int = 32, seed: int = 0,
                      positive_rate: float = 0.5, quantize: bool = True):
    """One corpus carrying SEVERAL independent predicate signals — the
    multi-predicate query workload (engine/): each spec's texture is
    injected into its own random row subset. Returns (images (N,hw,hw,3),
    labels (N, K) int32). quantize rounds pixels to k/256 dyadics (the
    uint8-sensor regime), keeping pyramid derivation bit-exact
    (DESIGN.md §3.1) so engine and naive scans select identical rows."""
    rng = np.random.default_rng(seed)
    x = _clutter(rng, n, hw)
    labels = np.zeros((n, len(specs)), np.int32)
    yy, xx = np.meshgrid(np.arange(hw), np.arange(hw), indexing="ij")
    for k, spec in enumerate(specs):
        pos = rng.random(n) < positive_rate
        labels[:, k] = pos
        phase = rng.uniform(0, 2 * np.pi, size=n)
        theta = rng.uniform(0, np.pi, size=n)
        for i in np.where(pos)[0]:
            g = (np.cos(theta[i]) * xx + np.sin(theta[i]) * yy) / hw
            tex = np.sin(2 * np.pi * spec.freq * g + phase[i])
            x[i, :, :, spec.channel] += spec.amplitude * tex
    x = 0.5 + 0.18 * x
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    if quantize:
        x = (np.floor(x * 256.0).clip(0, 255) / 256.0).astype(np.float32)
    return x, labels


def make_camera_stream(specs, n_frames: int, hw: int = 32, seed: int = 0,
                       positive_rate: float = 0.5, hold_max: int = 4,
                       jitter: int = 1):
    """Simulated camera stream for the ingest pipeline (engine/ingest.py):
    piecewise-constant scenes. Each DISTINCT scene frame is drawn like a
    ``make_multi_corpus`` row (quantized to k/256 dyadics) and held for a
    random 1..hold_max consecutive frames; held repeats get independent
    per-pixel ±jitter/256 sensor noise — dyadic steps on the dyadic grid,
    so pyramid derivation stays bit-exact (DESIGN.md §3.1) while frames
    within a scene are near- but not bit-identical (what a temporal
    difference detector must tolerate). Scene CHANGES replace the clutter
    and the predicate textures entirely, so cross-scene frame differences
    are orders of magnitude above the jitter — the detector's separation
    margin. Returns (frames (N,hw,hw,3), labels (N,K) int32,
    scene_id (N,) int64); held frames share their scene's labels."""
    rng = np.random.default_rng(seed + 1_000_003)
    holds = []
    while sum(holds) < n_frames:
        holds.append(int(rng.integers(1, max(2, hold_max + 1))))
    scenes_x, scenes_y = make_multi_corpus(specs, len(holds), hw=hw,
                                           seed=seed,
                                           positive_rate=positive_rate,
                                           quantize=True)
    frames = np.empty((n_frames, hw, hw, 3), np.float32)
    labels = np.empty((n_frames, len(specs)), np.int32)
    scene_id = np.empty(n_frames, np.int64)
    t = 0
    for s, hold in enumerate(holds):
        for _ in range(hold):
            if t == n_frames:
                break
            f = scenes_x[s]
            if jitter and t and scene_id[t - 1] == s:
                # held repeat: ±jitter/256 dyadic sensor noise
                delta = rng.integers(-jitter, jitter + 1,
                                     size=f.shape).astype(np.float32)
                f = np.clip(f + delta / 256.0, 0.0, 1.0)
            frames[t] = f
            labels[t] = scenes_y[s]
            scene_id[t] = s
            t += 1
    return frames, labels, scene_id


def make_two_camera_corpus(specs, n: int, hw: int = 32, seed: int = 0,
                           positive_rate: float = 0.4, corr: float = 0.6,
                           dt_max: int = 2, gap: int = 8):
    """Two correlated camera corpora for the cross-corpus temporal join
    workload (engine/algebra.Join, DESIGN.md §15.3): camera A records
    ``n`` frames at (jittered) timestamps ~``gap`` apart; a ``corr``
    fraction of camera B's ``n`` frames are PAIRED with an A frame —
    same predicate label vector, a timestamp within ±``dt_max`` of the
    partner — while the rest carry independent labels at independent
    timestamps. Both cameras render their frames independently
    (separate clutter/phase — two viewpoints of one scene, not pixel
    copies), quantized to k/256 dyadics like ``make_multi_corpus`` so
    engine and naive scans stay bit-exact. Paired rows make a
    ``Join(contains(X), contains(X), delta_t=dt_max)`` non-trivially
    selective: matches exist, but only where the correlation put them.

    Returns ``((frames_a, labels_a, t_a), (frames_b, labels_b, t_b))``
    with labels (N, K) int32 and timestamps (N,) int64, each camera
    sorted by its own timestamps."""
    rng = np.random.default_rng(seed + 7_654_321)
    t_a = (np.arange(n, dtype=np.int64) * gap
           + rng.integers(0, max(gap // 2, 1), size=n))
    lab_a = (rng.random((n, len(specs))) < positive_rate).astype(np.int32)
    paired = rng.random(n) < corr
    lab_b = np.empty_like(lab_a)
    t_b = np.empty(n, np.int64)
    lab_b[paired] = lab_a[paired]
    t_b[paired] = t_a[paired] + rng.integers(-dt_max, dt_max + 1,
                                             size=int(paired.sum()))
    free = ~paired
    lab_b[free] = (rng.random((int(free.sum()), len(specs)))
                   < positive_rate).astype(np.int32)
    # independent timestamps, offset half a gap so free frames rarely
    # fall inside a window by accident (but occasionally do — the join
    # must verify, not assume)
    t_b[free] = (rng.integers(0, n, size=int(free.sum())) * gap
                 + gap // 2)
    out = []
    for cam, (labels, t) in enumerate(((lab_a, t_a), (lab_b, t_b))):
        x = _render_labeled(specs, labels, hw,
                            np.random.default_rng(seed + 31 * (cam + 1)))
        order = np.argsort(t, kind="stable")
        out.append((x[order], labels[order], t[order]))
    return out[0], out[1]


def _render_labeled(specs, labels, hw, rng):
    """Render frames carrying exactly ``labels``'s texture signals —
    the ``make_multi_corpus`` image model with the label draw hoisted
    out (so two cameras can share labels but not pixels)."""
    n = len(labels)
    x = _clutter(rng, n, hw)
    yy, xx = np.meshgrid(np.arange(hw), np.arange(hw), indexing="ij")
    for k, spec in enumerate(specs):
        phase = rng.uniform(0, 2 * np.pi, size=n)
        theta = rng.uniform(0, np.pi, size=n)
        for i in np.where(labels[:, k] == 1)[0]:
            g = (np.cos(theta[i]) * xx + np.sin(theta[i]) * yy) / hw
            tex = np.sin(2 * np.pi * spec.freq * g + phase[i])
            x[i, :, :, spec.channel] += spec.amplitude * tex
    x = 0.5 + 0.18 * x
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    return (np.floor(x * 256.0).clip(0, 255) / 256.0).astype(np.float32)


def three_way_split(x, y, seed: int = 0, frac=(0.5, 0.25, 0.25)):
    """train / config(thresholds) / eval — paper §V-A's three splits."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n1 = int(len(x) * frac[0])
    n2 = n1 + int(len(x) * frac[1])
    tr, cf, ev = idx[:n1], idx[n1:n2], idx[n2:]
    return (x[tr], y[tr]), (x[cf], y[cf]), (x[ev], y[ev])


def lm_token_batches(vocab: int, batch: int, seq: int, steps: int,
                     seed: int = 0):
    """Markov-ish synthetic token stream for LM training examples."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=(steps, batch, seq + 1),
                        dtype=np.int32)
    # inject learnable structure: every even position repeats prev token
    base[:, :, 2::2] = base[:, :, 1:-1:2]
    for s in range(steps):
        yield {"tokens": base[s, :, :-1], "labels": base[s, :, 1:]}
