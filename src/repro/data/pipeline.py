"""Host data pipeline: sharding-aware batching + background prefetch
(compute/IO overlap — DESIGN.md §6)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class Prefetcher:
    """Runs the producer iterator on a background thread with a bounded
    buffer, overlapping host batch preparation with device compute."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.done = object()
        self.err = None

        def worker():
            try:
                for item in it:
                    self.q.put(item)
            except BaseException as e:  # propagate to consumer
                self.err = e
            finally:
                self.q.put(self.done)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is self.done:
                if self.err:
                    raise self.err
                return
            yield item


def shard_batch(batch: dict, mesh):
    """Place a host batch onto the mesh with the policy batch sharding."""
    from jax.sharding import NamedSharding
    from repro.sharding.policy import batch_spec
    return {k: jax.device_put(
        v, NamedSharding(mesh, batch_spec(mesh, np.ndim(v))))
        for k, v in batch.items()}


def batched(x, y, batch: int, *, seed: int = 0, epochs: int | None = None):
    """Shuffled epoch iterator over (x, y) host arrays."""
    rng = np.random.default_rng(seed)
    n = len(x)
    e = 0
    while epochs is None or e < epochs:
        idx = rng.permutation(n)
        for lo in range(0, n - batch + 1, batch):
            sel = idx[lo:lo + batch]
            yield {"images": x[sel], "labels": y[sel]}
        e += 1
