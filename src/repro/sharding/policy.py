"""Sharding policy: how this system's state is split across devices.

Two independent layers live here:

1. **Param sharding** (train/serve): every param leaf name maps to
   logical axes, logical axes map to mesh axes with divisibility checks
   (indivisible dims gracefully replicate). One policy serves train
   (TP + FSDP/ZeRO) and serve (2D TP) — XLA SPMD picks
   all-gather-weights vs psum-partials per context.

   Logical axes:
     tp    -> 'model'         (heads / d_ff / experts / vocab columns)
     fsdp  -> ('pod','data')  (ZeRO-style param+grad+opt-state sharding)
     None  -> replicated

   Mesh: (data, model) single-pod, (pod, data, model) multi-pod
   (launch/mesh.py). Batch/activation/cache specs live in
   launch/steps.py.

2. **Corpus row sharding** (query engine, DESIGN.md §9): `ShardPlan` /
   `plan_shards` partition a scan's metadata-survivor row set across
   shard executors. Range partitioning splits the (sorted) id list into
   contiguous runs balanced by a per-row weight — skew-aware when the
   caller supplies the planner's expected per-row evaluation cost — and
   hash partitioning assigns each row id a stable pseudo-random shard so
   a row keeps its shard (and its shard-side caches) across queries.
   Both are exact partitions: every row lands in exactly one shard.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# leaf name -> logical axes per dim (suffix match on the param path).
RULES: dict[str, tuple] = {
    # embeddings / heads
    "embedding": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    "dec_pos": ("fsdp", None),
    # attention (column-parallel in, row-parallel out)
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": (None,), "bv": (None,),
    # MLA
    "w_dq": ("fsdp", None), "w_uq": (None, "tp"),
    "w_dkv": ("fsdp", None), "w_uk": (None, "tp"), "w_uv": (None, "tp"),
    "q_norm": (None,), "kv_norm": (None,),
    # MLP
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp"),
    "w_in": ("fsdp", "tp"), "b_in": ("tp",),
    "w_out": ("tp", "fsdp"), "b_out": (None,),
    # MoE (stacked experts: EP over 'model', expert-width over fsdp)
    "w_router": (None, None),
    "w_gate_e": ("tp", None, "fsdp"), "w_up_e": ("tp", None, "fsdp"),
    "w_down_e": ("tp", "fsdp", None),
    # SSM
    "w_z": ("fsdp", "tp"), "w_x": ("fsdp", "tp"), "w_dt": ("fsdp", "tp"),
    "w_b": ("fsdp", None), "w_c": ("fsdp", None),
    "conv_x": ("tp", None), "conv_b": (None, None), "conv_c": (None, None),
    "conv_x_b": ("tp",), "conv_b_b": (None,), "conv_c_b": (None,),
    "a_log": ("tp",), "dt_bias": ("tp",), "d_skip": ("tp",),
    "norm_scale": ("tp",),
    # norms
    "scale": (None,), "bias": (None,),
}

LOGICAL = {"tp": ("model",), "fsdp": ("pod", "data")}


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve_dim(logical, dim_size: int, sizes: dict):
    """logical axis name -> concrete mesh axes (or None), honoring
    divisibility. fsdp degrades ('pod','data') -> ('data',) -> ('pod',)."""
    if logical is None:
        return None
    # candidates: the full combo first, then single axes largest-first
    singles = sorted(LOGICAL[logical], key=lambda a: -sizes.get(a, 0))
    for axes in (LOGICAL[logical],) + tuple((a,) for a in singles):
        axes = tuple(a for a in axes if a in sizes)
        if not axes:
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if prod > 1 and dim_size % prod == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def spec_for(name: str, shape, mesh) -> P:
    """PartitionSpec for one param leaf. Stacked leaves (layer or expert
    scan) have one more leading dim than the rule — leading dims are
    replicated (layer axis)."""
    rule = RULES.get(name)
    if rule is None or not shape:
        return P()
    sizes = _axis_sizes(mesh)
    extra = len(shape) - len(rule)
    if extra < 0:
        return P()
    parts = [None] * extra + [
        _resolve_dim(lg, shape[extra + i], sizes)
        for i, lg in enumerate(rule)]
    # 'layers' stacking: the leading scan dim stays replicated, but the
    # expert rules already include their stack dim so only true layer
    # stacking lands in `extra`.
    return P(*parts)


def param_pspecs(params_or_shapes, mesh):
    """Tree of PartitionSpec matching the params tree (arrays or
    ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(leaf_name(path), x.shape, mesh),
        params_or_shapes)


def param_shardings(params_or_shapes, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_or_shapes, mesh))


# ---- trace-time mesh context (lets model-internal code add constraints
# without threading the mesh through every signature) ----
_CTX_MESH = None


class use_ctx_mesh:
    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        global _CTX_MESH
        self._prev = _CTX_MESH
        _CTX_MESH = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _CTX_MESH
        _CTX_MESH = self._prev


def ctx_constrain(x, *parts):
    """with_sharding_constraint against the ambient mesh; no-op when no
    mesh context is active (single-device tests) or axes are missing/
    indivisible."""
    mesh = _CTX_MESH
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = []
    for dim, part in zip(x.shape, parts):
        if part is None:
            resolved.append(None)
            continue
        axes = tuple(a for a in ((part,) if isinstance(part, str) else part)
                     if a in sizes)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        resolved.append((axes if len(axes) > 1 else axes[0])
                        if axes and prod > 1 and dim % prod == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def ctx_dp_axes():
    return dp_axes(_CTX_MESH) if _CTX_MESH is not None else ()


def batch_spec(mesh, ndim: int, batch_axis: int = 0) -> P:
    """Shard the batch dim over all data-parallel axes."""
    dp = dp_axes(mesh)
    parts = [None] * ndim
    parts[batch_axis] = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(*parts)


def constrain_batch(x, mesh):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, batch_spec(mesh, x.ndim)))


# ======================================================================
# Corpus row sharding (scan engine, DESIGN.md §9)
# ======================================================================
SHARD_STRATEGIES = ("range", "hash")


@dataclass(frozen=True)
class ShardPlan:
    """An exact partition of a scan's surviving row ids across shards.

    ``shards[i]`` is the i-th shard's row-id array (sorted ascending,
    possibly empty); the arrays are disjoint and their union is exactly
    the planned id set. ``weights[i]`` is the shard's total estimated
    evaluation cost under the weighting used to build the plan (row
    counts when the caller gave no weights)."""
    n_shards: int
    strategy: str
    shards: tuple
    weights: tuple

    @property
    def sizes(self) -> list[int]:
        return [len(s) for s in self.shards]

    @property
    def n_rows(self) -> int:
        return sum(self.sizes)

    @property
    def balance(self) -> float:
        """max/mean shard weight over non-degenerate plans; 1.0 is a
        perfectly even split, higher means skew."""
        mean = sum(self.weights) / max(self.n_shards, 1)
        return max(self.weights) / mean if mean > 0 else 1.0

    def all_rows(self) -> np.ndarray:
        """The planned id set, sorted (partition invariant: equals the
        ids the plan was built from)."""
        parts = [s for s in self.shards if len(s)]
        if not parts:
            return np.empty(0, np.int64)
        return np.sort(np.concatenate(parts))

    def validate(self, ids=None) -> None:
        """Check the partition invariants (cheap; guards caller-supplied
        plans in ShardedScanEngine.execute). Raises ValueError — not
        assert, which python -O strips — because a bad plan silently
        returns a wrong row set otherwise."""
        cat = self.all_rows()
        if len(np.unique(cat)) != len(cat):
            raise ValueError("invalid ShardPlan: a row is assigned to "
                             "more than one shard")
        if ids is not None and not np.array_equal(
                cat, np.sort(np.asarray(ids))):
            raise ValueError("invalid ShardPlan: partition does not "
                             "cover the id set (stale plan?)")

    def describe(self) -> str:
        sz = self.sizes
        lo, hi = (min(sz), max(sz)) if sz else (0, 0)
        return (f"{self.n_shards} shards ({self.strategy})  rows "
                f"min/max={lo}/{hi}  balance={self.balance:.2f}")


def _hash_ids(ids: np.ndarray) -> np.ndarray:
    """Stable 64-bit mix (splitmix64 finalizer) so hash shards spread
    contiguous id runs without Python-hash salt dependence."""
    h = ids.astype(np.uint64, copy=True)
    h = (h ^ (h >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    h = (h ^ (h >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return h ^ (h >> np.uint64(31))


def shard_route(ids, n_shards: int) -> np.ndarray:
    """Stationary hash routing of individual row ids: the shard that
    owns each row under ``strategy='hash'`` partitioning, WITHOUT
    building a plan. ``plan_shards(ids, n, 'hash').shards[s]`` contains
    exactly the ids with ``shard_route(ids, n) == s`` — the serving
    path (serve/service.py) routes single-row requests with this and
    lands on the same shard (hence the same shard-local virtual
    columns) every scan-time hash plan used."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    ids = np.atleast_1d(np.asarray(ids, np.int64))
    return (_hash_ids(ids) % np.uint64(n_shards)).astype(np.int64)


def plan_shards(ids, n_shards: int, *, strategy: str = "range",
                weights=None) -> ShardPlan:
    """Partition row ids into ``n_shards`` disjoint shards.

    ``strategy='range'``: contiguous runs of the sorted id list, with
    boundaries placed on the cumulative ``weights`` curve (uniform when
    None) — the skew-aware split: a run of expensive rows ends up in a
    smaller shard. ``strategy='hash'``: stable per-id hash mod
    ``n_shards`` — balanced in expectation and stationary across
    queries. Empty shards are legal (n_shards may exceed len(ids))."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if strategy not in SHARD_STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"expected one of {SHARD_STRATEGIES}")
    ids = np.asarray(ids, np.int64)
    if weights is None:
        order = np.argsort(ids)
        ids = ids[order]
        w = np.ones(len(ids))
    else:
        w = np.asarray(weights, np.float64)
        assert w.shape == ids.shape, "weights must align with ids"
        # keep each weight paired with its row while sorting
        order = np.argsort(ids)
        ids, w = ids[order], w[order]
        # degenerate/negative weights would break the cumulative split
        w = np.clip(w, 0.0, None) + 1e-12

    if strategy == "hash":
        shard_of = shard_route(ids, n_shards)
        parts = [ids[shard_of == s] for s in range(n_shards)]
        wsums = [float(w[shard_of == s].sum()) for s in range(n_shards)]
        return ShardPlan(n_shards, strategy, tuple(parts), tuple(wsums))

    cum = np.cumsum(w)
    total = cum[-1] if len(cum) else 0.0
    targets = total * np.arange(1, n_shards) / n_shards
    # boundary b_j = first index whose cumulative weight exceeds target j
    # (side='right': a row exactly on the target closes the shard)
    bounds = np.searchsorted(cum, targets, side="right")
    parts = np.split(ids, bounds)
    wparts = np.split(w, bounds)
    return ShardPlan(n_shards, strategy, tuple(parts),
                     tuple(float(p.sum()) for p in wparts))
