"""Name-driven sharding policy: every param leaf name maps to logical axes,
logical axes map to mesh axes with divisibility checks (indivisible dims
gracefully replicate). One policy serves train (TP + FSDP/ZeRO) and serve
(2D TP) — XLA SPMD picks all-gather-weights vs psum-partials per context.

Logical axes:
  tp    -> 'model'         (heads / d_ff / experts / vocab columns)
  fsdp  -> ('pod','data')  (ZeRO-style param+grad+opt-state sharding)
  None  -> replicated

Mesh: (data, model) single-pod, (pod, data, model) multi-pod
(launch/mesh.py). Batch/activation/cache specs live in launch/steps.py.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# leaf name -> logical axes per dim (suffix match on the param path).
RULES: dict[str, tuple] = {
    # embeddings / heads
    "embedding": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    "dec_pos": ("fsdp", None),
    # attention (column-parallel in, row-parallel out)
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": (None,), "bv": (None,),
    # MLA
    "w_dq": ("fsdp", None), "w_uq": (None, "tp"),
    "w_dkv": ("fsdp", None), "w_uk": (None, "tp"), "w_uv": (None, "tp"),
    "q_norm": (None,), "kv_norm": (None,),
    # MLP
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp"),
    "w_in": ("fsdp", "tp"), "b_in": ("tp",),
    "w_out": ("tp", "fsdp"), "b_out": (None,),
    # MoE (stacked experts: EP over 'model', expert-width over fsdp)
    "w_router": (None, None),
    "w_gate_e": ("tp", None, "fsdp"), "w_up_e": ("tp", None, "fsdp"),
    "w_down_e": ("tp", "fsdp", None),
    # SSM
    "w_z": ("fsdp", "tp"), "w_x": ("fsdp", "tp"), "w_dt": ("fsdp", "tp"),
    "w_b": ("fsdp", None), "w_c": ("fsdp", None),
    "conv_x": ("tp", None), "conv_b": (None, None), "conv_c": (None, None),
    "conv_x_b": ("tp",), "conv_b_b": (None,), "conv_c_b": (None,),
    "a_log": ("tp",), "dt_bias": ("tp",), "d_skip": ("tp",),
    "norm_scale": ("tp",),
    # norms
    "scale": (None,), "bias": (None,),
}

LOGICAL = {"tp": ("model",), "fsdp": ("pod", "data")}


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve_dim(logical, dim_size: int, sizes: dict):
    """logical axis name -> concrete mesh axes (or None), honoring
    divisibility. fsdp degrades ('pod','data') -> ('data',) -> ('pod',)."""
    if logical is None:
        return None
    # candidates: the full combo first, then single axes largest-first
    singles = sorted(LOGICAL[logical], key=lambda a: -sizes.get(a, 0))
    for axes in (LOGICAL[logical],) + tuple((a,) for a in singles):
        axes = tuple(a for a in axes if a in sizes)
        if not axes:
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if prod > 1 and dim_size % prod == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def spec_for(name: str, shape, mesh) -> P:
    """PartitionSpec for one param leaf. Stacked leaves (layer or expert
    scan) have one more leading dim than the rule — leading dims are
    replicated (layer axis)."""
    rule = RULES.get(name)
    if rule is None or not shape:
        return P()
    sizes = _axis_sizes(mesh)
    extra = len(shape) - len(rule)
    if extra < 0:
        return P()
    parts = [None] * extra + [
        _resolve_dim(lg, shape[extra + i], sizes)
        for i, lg in enumerate(rule)]
    # 'layers' stacking: the leading scan dim stays replicated, but the
    # expert rules already include their stack dim so only true layer
    # stacking lands in `extra`.
    return P(*parts)


def param_pspecs(params_or_shapes, mesh):
    """Tree of PartitionSpec matching the params tree (arrays or
    ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(leaf_name(path), x.shape, mesh),
        params_or_shapes)


def param_shardings(params_or_shapes, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_or_shapes, mesh))


# ---- trace-time mesh context (lets model-internal code add constraints
# without threading the mesh through every signature) ----
_CTX_MESH = None


class use_ctx_mesh:
    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        global _CTX_MESH
        self._prev = _CTX_MESH
        _CTX_MESH = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _CTX_MESH
        _CTX_MESH = self._prev


def ctx_constrain(x, *parts):
    """with_sharding_constraint against the ambient mesh; no-op when no
    mesh context is active (single-device tests) or axes are missing/
    indivisible."""
    mesh = _CTX_MESH
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = []
    for dim, part in zip(x.shape, parts):
        if part is None:
            resolved.append(None)
            continue
        axes = tuple(a for a in ((part,) if isinstance(part, str) else part)
                     if a in sizes)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        resolved.append((axes if len(axes) > 1 else axes[0])
                        if axes and prod > 1 and dim % prod == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def ctx_dp_axes():
    return dp_axes(_CTX_MESH) if _CTX_MESH is not None else ()


def batch_spec(mesh, ndim: int, batch_axis: int = 0) -> P:
    """Shard the batch dim over all data-parallel axes."""
    dp = dp_axes(mesh)
    parts = [None] * ndim
    parts[batch_axis] = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(*parts)


def constrain_batch(x, mesh):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, batch_spec(mesh, x.ndim)))
