"""KV / state cache layouts and physical representations.

The cache dtype is a *physical representation* choice in exactly the
paper's sense (§VI: representation affects data-handling cost, here HBM
traffic during decode). Supported: bfloat16 (default) and int8 with
per-(token, head) scales — the int8 path is one of the beyond-paper
hillclimb levers (EXPERIMENTS.md §Perf).

Layouts (stacked over layers so the layer scan can consume slices):
  attention: k/v (L, B, T, KHp, Dh) [+ k_scale/v_scale (L,B,T,KHp) if int8]
  MLA:       c_kv (L, B, T, r), k_rope (L, B, T, rope)
  SSM:       conv_x/b/c (L, B, ch, K-1), state (L, B, H, P, N) fp32
  hybrid:    SSM stack + shared-attn k/v (J, B, T, KHp, Dh), J = invocations
  pos:       (B,) int32 — number of valid tokens (same for all layers)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import layout_from_cfg
from repro.models.ssm import init_ssm_cache


def _q8(x):
    """(..., Dh) -> int8 values + f32 scale over last axis."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _dq8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def init_attn_kv(cfg, batch: int, seq: int, kv_dtype: str = "bfloat16",
                 n_layers: int | None = None, n_kv: int | None = None):
    lo = layout_from_cfg(cfg)
    l = n_layers if n_layers is not None else cfg.n_layers
    kh = n_kv if n_kv is not None else lo.khp
    dh = cfg.head_dim
    if kv_dtype == "int8":
        z8 = jnp.zeros((l, batch, seq, kh, dh), jnp.int8)
        zs = jnp.zeros((l, batch, seq, kh), jnp.float32)
        return {"k": z8, "v": jnp.zeros_like(z8), "k_scale": zs,
                "v_scale": jnp.zeros_like(zs)}
    z = jnp.zeros((l, batch, seq, kh, dh), jnp.dtype(kv_dtype))
    return {"k": z, "v": jnp.zeros_like(z)}


def write_kv_layer(layer_cache, k_new, v_new, pos):
    """layer_cache: slices (B,T,KH,Dh) [+ scales]; k_new/v_new (B,1,KH,Dh);
    pos (B,) write index. Returns updated layer cache dict."""
    bidx = jnp.arange(k_new.shape[0])
    out = dict(layer_cache)
    if "k_scale" in layer_cache:
        kq, ks = _q8(k_new)
        vq, vs = _q8(v_new)
        out["k"] = layer_cache["k"].at[bidx, pos].set(kq[:, 0])
        out["v"] = layer_cache["v"].at[bidx, pos].set(vq[:, 0])
        out["k_scale"] = layer_cache["k_scale"].at[bidx, pos].set(ks[:, 0])
        out["v_scale"] = layer_cache["v_scale"].at[bidx, pos].set(vs[:, 0])
    else:
        dt = layer_cache["k"].dtype
        out["k"] = layer_cache["k"].at[bidx, pos].set(k_new[:, 0].astype(dt))
        out["v"] = layer_cache["v"].at[bidx, pos].set(v_new[:, 0].astype(dt))
    return out


def read_kv_layer(layer_cache, dtype=jnp.bfloat16):
    """-> k, v (B,T,KH,Dh) in compute dtype."""
    if "k_scale" in layer_cache:
        return (_dq8(layer_cache["k"], layer_cache["k_scale"], dtype),
                _dq8(layer_cache["v"], layer_cache["v_scale"], dtype))
    return (layer_cache["k"].astype(dtype), layer_cache["v"].astype(dtype))


def init_mla_kv(cfg, batch: int, seq: int, kv_dtype: str = "bfloat16"):
    m = cfg.mla
    dt = jnp.bfloat16 if kv_dtype == "int8" else jnp.dtype(kv_dtype)
    return {
        "c_kv": jnp.zeros((cfg.n_layers, batch, seq, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((cfg.n_layers, batch, seq, m.qk_rope_head_dim),
                            dt),
    }


def init_cache(cfg, batch: int, seq: int, kv_dtype: str = "bfloat16"):
    """Full decode cache for any family. 'pos' counts valid tokens."""
    cache: dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
            init_ssm_cache(cfg, batch))
    elif cfg.family == "hybrid":
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
            init_ssm_cache(cfg, batch))
        n_inv = cfg.n_layers // cfg.hybrid_attn_every
        cache["shared_attn"] = init_attn_kv(cfg, batch, seq, kv_dtype,
                                            n_layers=n_inv)
    elif cfg.mla is not None:
        cache["mla"] = init_mla_kv(cfg, batch, seq, kv_dtype)
    elif cfg.family == "audio":
        cache["self"] = init_attn_kv(cfg, batch, seq, kv_dtype)
        cache["cross"] = init_attn_kv(cfg, batch, cfg.encoder.n_frames,
                                      "bfloat16")
    else:
        cache["kv"] = init_attn_kv(cfg, batch, seq, kv_dtype)
    return cache
