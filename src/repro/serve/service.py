"""Shard-aware async cascade serving (DESIGN.md §10, hardening §12).

``AsyncCascadeService`` replaces the synchronous-polling
``CascadeService`` (serve/batcher.py) for request streams over a
resident corpus ("does frame ROW contain CONCEPT?"):

* **shard routing** — requests are routed by the ShardPlan's stationary
  hash partitioning (`sharding/policy.shard_route`) to one queue PER
  SHARD DEVICE. A row's shard owns its virtual columns (the same
  ownership the sharded scan engine uses), so the store lookup on
  submit is a shard-local read, and an offline hash-sharded scan leaves
  its labels exactly where the serving path will look for them.
* **deadline scheduling** — a deadline wheel (serve/scheduler.py) holds
  one entry per non-empty (shard, concept) queue group; a group flushes
  when ``batch_size`` requests are waiting OR when its oldest request's
  deadline (``arrival + max_wait_s``) comes due on ``poll()``. Flushed
  batches are assembled with the lockstep's bucketed power-of-2 slab
  builder (`engine/sharded.slab_width`/`pad_rows`), so a
  deadline-triggered partial flush pays bucket-width compute, not the
  sync batcher's full pad-to-capacity. ``poll()`` only runs when a
  caller ticks it — the wall-clock event host (serve/host.py) drives it
  autonomously in production.
* **dispatch-ahead** — one in-flight batch per device:
  ``block_until_ready`` is deferred to result delivery, so host-side
  routing and gather of the next batch overlap the device compute of
  the previous one. Exactness is untouched: deferral changes WHEN a
  label array is read, never its value, and per-device delivery is FIFO
  (a device's in-flight batch is delivered before it accepts the next),
  so evaluated results are delivered in submission order per queue.
* **post-flush commit** — labels are recorded into the shard-local
  store and committed corpus-wide via ``VirtualColumnStore.merge_from``
  (the sharded scan's merge semantics: computed labels never
  overwritten). A re-submitted decided row is answered on submit with
  ZERO model invocations.
* **representation reuse** — an optional cross-query
  ``RepresentationCache`` (serve/repcache.py) backs batch assembly:
  when every row of a flush already has every non-base pooled level
  cached, the batch runs the from-pyramid variant (no re-pooling);
  otherwise the from-base variant runs and publishes its freshly pooled
  levels. The same cache object can back a ``ScanEngine``, so offline
  scans warm the online path.

Overload/fault hardening (all OFF by default — the default-parameter
service is request-for-request bit-identical to the pre-hardening one):

* **admission control** — ``queue_limit`` bounds every (shard, concept)
  queue; a full queue rejects with a typed ``Shed`` result
  (serve/faults.py) instead of growing without bound. Queue-depth and
  in-flight gauges are exposed via ``summary()``.
* **degradation ladder** — ``ladders[concept]`` lists cheaper
  Pareto-frontier cascades (core/selector.degradation_ladder) below the
  primary; a per-concept load controller watches queue depth / observed
  flush latency at flush time and steps the ACTIVE cascade down under
  pressure (and back up after ``recover_after`` calm flushes) — trading
  accuracy for latency exactly the way the paper's frontier is meant to
  be used. Degraded labels commit under the degraded cascade's OWN
  ``casc.key`` — the (concept, cascade-id) store keying means they can
  never poison the primary's virtual column — and are counted
  separately (``ServiceStats.degraded_rows``).
* **fault recovery** — ``batch_timeout_s`` bounds every in-flight
  batch: a batch that isn't ready by its timeout marks its device
  failed and is re-dispatched to a healthy device (bounded by
  ``dispatch_retries``), else its requests complete with a typed
  ``TimedOut`` result. ``request_deadline_s`` bounds time-in-queue the
  same way. Dispatch-time faults (``DeviceError``,
  ``TransientComputeError`` — injectable via serve/faults.FaultPlan)
  retry/re-route under the same budget. Nothing hangs: every request
  terminates with a label, a ``Shed``, or a ``TimedOut``.

Exactness: batches run full-width cascade levels
(``caps = [width] * (L-1)``), deliberately ignoring
``CompiledCascade.capacities`` exactly like the scan paths — labels are
per-row independent of batch packing, hence bit-identical to
``ScanEngine``/``naive_scan`` and safe to commit as virtual columns
(the sync batcher's capped-overflow trick trades that exactness for
bounded tail compute; see CompiledCascade).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.engine.scan import CompiledCascade, VirtualColumnStore
from repro.engine.sharded import pad_rows, slab_width
from repro.serve.batcher import Request
from repro.serve.faults import (DeviceError, Shed, TimedOut,
                                TransientComputeError)
from repro.serve.scheduler import DeadlineWheel
from repro.sharding.policy import shard_route


@dataclass
class ServiceStats:
    """Per-concept serving counters."""
    requests: int = 0
    store_hits: int = 0        # answered on submit, zero invocations
    rep_hit_rows: int = 0      # rows assembled from the repcache
    rows_evaluated: int = 0
    batches: int = 0
    padded_slots: int = 0
    size_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    # hardening counters (all stay 0 on the default-parameter service)
    shed: int = 0              # admission-rejected (typed Shed result)
    expired: int = 0           # in-queue request deadline expiries
    timeouts: int = 0          # batch-timeout completions (TimedOut)
    retries: int = 0           # batch re-dispatches (fault/timeout)
    degraded_rows: int = 0     # rows answered by a non-primary rung
    degraded_batches: int = 0
    degrade_steps: int = 0     # ladder step-downs
    recover_steps: int = 0     # ladder step-ups
    depth_max: int = 0         # max queued (all shards) for this concept
    # bounded window (newest first out the back) so a resident service
    # can't grow a float per request forever
    latencies: deque = field(
        default_factory=lambda: deque(maxlen=65536))


@dataclass
class DegradeConfig:
    """Load-controller thresholds for the degradation ladder: step DOWN
    one rung when a concept's total queued depth reaches ``high_depth``
    (or a delivered flush took ``high_latency_s``+); step back UP after
    ``recover_after`` consecutive flushes observed at ``low_depth`` or
    less. Observations happen at flush time, so recovery needs traffic
    — which is exactly when the rung matters."""
    high_depth: int = 64
    low_depth: int = 4
    high_latency_s: float | None = None
    recover_after: int = 4


class _LoadController:
    """Per-concept hysteresis controller over ladder rung indices
    (0 = primary). One step per observation, calm-streak recovery."""

    def __init__(self, cfg: DegradeConfig, n_levels: int):
        self.cfg = cfg
        self.n_levels = n_levels
        self.level = 0
        self._calm = 0

    def force_down(self) -> bool:
        """Immediate step-down (admission pressure). True if it moved."""
        self._calm = 0
        if self.level < self.n_levels - 1:
            self.level += 1
            return True
        return False

    def observe(self, depth: int, latency_s: float | None = None) -> int:
        cfg = self.cfg
        hot = depth >= cfg.high_depth or (
            cfg.high_latency_s is not None and latency_s is not None
            and latency_s >= cfg.high_latency_s)
        if hot:
            self.force_down()
        elif depth <= cfg.low_depth:
            self._calm += 1
            if self._calm >= cfg.recover_after and self.level > 0:
                self.level -= 1
                self._calm = 0
        else:
            self._calm = 0
        return self.level


@dataclass
class _InFlight:
    """A dispatched, not-yet-delivered batch parked on its device."""
    shard: int
    concept: str
    casc: CompiledCascade      # the rung that ran (commit under ITS key)
    take: list                 # the batch's Requests (arrival order)
    rows: np.ndarray           # their row ids (unpadded)
    labels: object             # device array; forced at delivery
    levels: dict | None        # device arrays for the repcache, or None
    t_dispatch: float = 0.0    # clock() at dispatch (batch timeout base)
    retries: int = 0           # re-dispatches already burned


class AsyncCascadeService:
    """Deadline-scheduled, shard-routed serving over a resident corpus.

    ``submit(concept, Request(rid, row_id))`` answers immediately from
    the row's shard-local virtual columns when the label is known;
    otherwise the request joins its (shard, concept) queue. ``poll()``
    fires due deadlines, expires over-deadline work, recovers timed-out
    batches, and harvests finished batches; ``drain()`` flushes and
    delivers everything. Results land on ``Request.result`` exactly
    like the sync service — a 0/1 label, or a typed ``Shed``/
    ``TimedOut`` when hardening knobs reject/expire the request."""

    def __init__(self, images, cascades: Mapping[str, CompiledCascade],
                 *, shards: int | None = None, batch_size: int = 32,
                 max_wait_s: float = 0.005, clock=time.perf_counter,
                 repcache=None, store: VirtualColumnStore | None = None,
                 jit: bool = True, devices: Sequence | None = None,
                 fn_cache: dict | None = None,
                 queue_limit: int | None = None, overload: str = "shed",
                 ladders: Mapping[str, Sequence[CompiledCascade]]
                 | None = None,
                 degrade: DegradeConfig | None = None,
                 batch_timeout_s: float | None = None,
                 request_deadline_s: float | None = None,
                 dispatch_retries: int = 2, faults=None,
                 ingest_index=None, ingest_exact: bool = True):
        from repro.launch.mesh import shard_devices

        self.images = np.asarray(images, np.float32)
        self.cascades = dict(cascades)
        self.devices = list(devices) if devices is not None \
            else shard_devices(shards)
        self.n_shards = int(shards) if shards is not None \
            else len(self.devices)
        if self.n_shards < 1:
            raise ValueError("need at least one shard")
        self.batch_size = int(batch_size)
        self.max_wait_s = float(max_wait_s)
        self.clock = clock
        self.jit = jit
        self.repcache = repcache
        if repcache is not None:
            from repro.serve.repcache import corpus_token
            repcache.bind_corpus(corpus_token(self.images))
        self.wheel = DeadlineWheel(granularity=max(self.max_wait_s / 4,
                                                   1e-6))

        # ------------------------------------------ hardening knobs --
        if overload not in ("shed", "degrade"):
            raise ValueError(f"unknown overload policy {overload!r}")
        self.queue_limit = None if queue_limit is None \
            else max(1, int(queue_limit))
        self.overload = overload
        self.batch_timeout_s = batch_timeout_s
        self.request_deadline_s = request_deadline_s
        self.dispatch_retries = int(dispatch_retries)
        self.faults = faults
        # ladder[0] is always the primary cascade; load controllers
        # exist only when there is anything to step down to
        self._ladder: dict[str, list[CompiledCascade]] = {
            c: [casc, *((ladders or {}).get(c, ()))]
            for c, casc in self.cascades.items()}
        self._ctl: dict[str, _LoadController | None] = {
            c: (_LoadController(degrade or DegradeConfig(), len(rungs))
                if len(rungs) > 1 else None)
            for c, rungs in self._ladder.items()}
        self._last_flush_lat: dict[str, float] = {}
        # device health: indices into the unique-device ordering; a
        # failed device is never dispatched to again this session
        self._unique_devices = list(dict.fromkeys(self.devices))
        self._dev_index = {d: i for i, d in
                           enumerate(self._unique_devices)}
        self._failed: set[int] = set()
        self._inflight_max = 0

        # corpus-wide store (shared with the caller when given, so a
        # scan engine's virtual columns serve requests directly) plus
        # shard-local stores seeded with each shard's own partition —
        # all a shard's queue will ever look up
        self.store = store if store is not None \
            else VirtualColumnStore(len(self.images))
        # ingest-time label index (engine/ingest.CandidateIndex):
        # stage-0 decisions made at ingest seed the corpus-wide store
        # BEFORE the shard seeds are sliced, so indexed rows are
        # answered at submit with zero model invocations (store_hits).
        # ingest_exact=True seeds only own-pixel decided labels
        # (bit-identical to what the cascade would compute);
        # False additionally propagates skip-alias labels (approx).
        if ingest_index is not None:
            ingest_index.seed_store(self.store, exact=ingest_exact)
        self._row_shard = shard_route(np.arange(len(self.images)),
                                      self.n_shards)
        self._shard_stores = []
        for s in range(self.n_shards):
            st = VirtualColumnStore(len(self.images))
            st.seed_from(self.store, np.where(self._row_shard == s)[0])
            self._shard_stores.append(st)

        self._queues: list[dict[str, list]] = [
            {} for _ in range(self.n_shards)]
        self._inflight: dict = {}          # device -> _InFlight
        # (concept, width, variant) -> compiled runner; pass a shared
        # dict (naive_scan's _fn_cache idiom) so fresh-state benchmark
        # services don't re-pay jit compilation
        self._fns: dict = fn_cache if fn_cache is not None else {}
        self.stats = {c: ServiceStats() for c in self.cascades}
        # rids in delivery order — an observability window (FIFO tests,
        # debugging), bounded so a long-lived service can't leak
        self.delivered: deque = deque(maxlen=65536)

    # ---------------------------------------------------------- plumbing --
    @property
    def concepts(self) -> list[str]:
        return list(self.cascades)

    def shard_of(self, row: int) -> int:
        return int(self._row_shard[int(row)])

    def active_level(self, concept: str) -> int:
        ctl = self._ctl[concept]
        return ctl.level if ctl is not None else 0

    def _active_cascade(self, concept: str) -> CompiledCascade:
        return self._ladder[concept][self.active_level(concept)]

    def _all_cascades(self) -> dict:
        """Every distinct ladder rung across concepts, keyed by
        casc.key (warmup target)."""
        out = {}
        for rungs in self._ladder.values():
            for casc in rungs:
                out[casc.key] = casc
        return out

    def _device_for(self, shard: int):
        """The shard's device, re-routed past failed devices: the first
        healthy device by a shard-stable rotation, or None when every
        device has failed."""
        dev = self.devices[shard]
        if self._dev_index[dev] not in self._failed:
            return dev
        healthy = [d for d in self._unique_devices
                   if self._dev_index[d] not in self._failed]
        if not healthy:
            return None
        return healthy[shard % len(healthy)]

    def _commit(self, x, dev):
        if not self.jit:
            return np.asarray(x)
        import jax
        return jax.device_put(np.asarray(x), dev)

    def _fn(self, casc: CompiledCascade, width: int, variant: str):
        """Compiled batch runner, cached per (cascade key, slab width,
        variant) — the cascade's (concept, cascade-id) key, not the
        bare concept, so a shared fn_cache can never serve a retrained
        cascade's labels from a stale compile (same reason naive_scan's
        _fn_cache keys by casc.key; ladder rungs land on their own
        entries the same way). 'base': raw rows in, labels + freshly
        pooled non-base levels out. 'pyr': cached pooled levels in,
        labels out."""
        key = (casc.key, width, variant)
        if key not in self._fns:
            from repro.core.executor import (make_fused_ingest,
                                             run_cascade_on_pyramid)

            res = tuple(casc.resolutions)
            base_hw = self.images.shape[1]
            small = tuple(r for r in res if r != base_hw)
            caps = [width] * (len(casc.model_fns) - 1)

            if variant == "base":
                # the same fused flush-assembly program the scan
                # engines' chunk ingest uses (executor.make_fused_ingest
                # — the Pallas pyramid+stage-0 pass on TPU with real
                # CNN params): one program pools the pyramid, runs the
                # cascade, and emits the freshly pooled small levels
                # for the repcache
                fn = make_fused_ingest(
                    casc.model_fns, casc.thresholds, casc.reps, caps,
                    small, stage0=casc.stage0, jit=self.jit)
            else:
                def fn(pyr):
                    return run_cascade_on_pyramid(
                        pyr, casc.model_fns, casc.thresholds, casc.reps,
                        caps)[0]
                if self.jit:
                    import jax
                    fn = jax.jit(fn)
            self._fns[key] = fn
        return self._fns[key]

    def warmup(self, widths: Sequence[int] | None = None) -> int:
        """Pre-compile AND execute one dummy batch per (device, cascade
        rung, slab width, variant) so live traffic never hits a compile
        stall — serving cold-start elimination, degradation rungs
        included (stepping down must not stall on a compile exactly
        when the service is overloaded). Default widths: every bucket
        ``slab_width`` can emit for this batch_size. Dummy batches
        never touch the stores or the repcache. Returns the number of
        executables exercised."""
        if widths is None:
            widths = sorted({slab_width(n, self.batch_size)
                             for n in range(1, self.batch_size + 1)})
        base_hw = self.images.shape[1]
        rows = np.zeros(max(widths), np.int64)
        n = 0
        for casc in self._all_cascades().values():
            small = [r for r in casc.resolutions if r != base_hw]
            for width in widths:
                imgs = self.images[rows[:width]]
                for dev in dict.fromkeys(self.devices):
                    lab, _ = self._fn(casc, width, "base")(
                        self._commit(imgs, dev))
                    np.asarray(lab)
                    n += 1
                    if not small:
                        continue
                    pyr = {r: np.zeros((width, r, r, 3), np.float32)
                           for r in small}
                    if base_hw in casc.resolutions:
                        pyr[base_hw] = imgs
                    np.asarray(self._fn(casc, width, "pyr")(
                        {r: self._commit(v, dev)
                         for r, v in pyr.items()}))
                    n += 1
        return n

    # ------------------------------------------------------ request path --
    def submit(self, concept: str, req: Request) -> None:
        req.t_arrival = self.clock()
        st = self.stats[concept]
        st.requests += 1
        row = int(req.payload)
        s = self.shard_of(row)
        # answer from the most accurate decided rung: primary first,
        # then any active degraded rung (a degraded label is still a
        # valid answer for a degraded-mode service, and it lives under
        # its own key, so the primary column is never consulted wrongly)
        rungs = self._ladder[concept][: self.active_level(concept) + 1]
        for casc in rungs:
            cached = int(self._shard_stores[s].column(casc.key)[row])
            if cached < 0:
                # the shard seed is a snapshot: a co-owning scan engine
                # may have decided this row in the SHARED store after
                # service construction — adopt the late write into the
                # shard's own columns so the next lookup is local again
                cached = int(self.store.column(casc.key)[row])
                if cached >= 0:
                    self._shard_stores[s].record(
                        casc.key, np.array([row]), [cached])
            if cached >= 0:                # shard-owned read, no model
                req.result = cached
                req.t_done = req.t_arrival
                st.store_hits += 1
                st.latencies.append(0.0)
                self.delivered.append(req.rid)
                return
        q = self._queues[s].setdefault(concept, [])
        if self.queue_limit is not None and len(q) >= self.queue_limit:
            # admission control: the queue is bounded — shed with a
            # typed result; under the 'degrade' policy, also step the
            # ladder down so FUTURE flushes get cheaper
            if self.overload == "degrade":
                ctl = self._ctl[concept]
                if ctl is not None and ctl.force_down():
                    st.degrade_steps += 1
            self._finish_rejected([req], concept, Shed("queue-full"))
            return
        q.append(req)
        depth = self._concept_depth(concept)
        if depth > st.depth_max:
            st.depth_max = depth
        if len(q) == 1:
            self.wheel.schedule((s, concept),
                                req.t_arrival + self.max_wait_s)
        if len(q) >= self.batch_size:
            self._flush(s, concept, "size")

    def poll(self) -> None:
        """Expire over-deadline queued requests, fire due flush
        deadlines, recover timed-out batches, then harvest any finished
        batches without blocking on in-flight device compute."""
        now = self.clock()
        self._expire_requests(now)
        for s, concept in self.wheel.pop_due(now):
            if self._queues[s].get(concept):
                self._flush(s, concept, "deadline")
        self._check_batch_timeouts(now)
        self.deliver_ready()

    def drain(self) -> None:
        """Flush every queue and deliver every in-flight batch. With a
        ``batch_timeout_s`` configured, an expired in-flight batch is
        recovered (retry on a healthy device, else TimedOut) instead of
        blocked on — a dead device can no longer hang drain()."""
        for s in range(self.n_shards):
            for concept in list(self._queues[s]):
                while self._queues[s][concept]:
                    self._flush(s, concept, "drain")
        while self._inflight:
            for dev in list(self._inflight):
                inf = self._inflight.get(dev)
                if inf is None:
                    continue
                if self._batch_timed_out(inf):
                    self._recover_batch(dev)
                else:
                    # blocks until the device finishes — the production
                    # path; a NeverReady label without a configured
                    # timeout raises loudly instead of hanging
                    self._deliver(dev)

    # ----------------------------------------------------- flush/deliver --
    def _concept_depth(self, concept: str) -> int:
        return sum(len(self._queues[s].get(concept, ()))
                   for s in range(self.n_shards))

    def _queued_total(self) -> int:
        return sum(len(q) for qs in self._queues for q in qs.values())

    def _expire_requests(self, now: float) -> None:
        if self.request_deadline_s is None:
            return
        for s in range(self.n_shards):
            for concept, q in self._queues[s].items():
                expired = []
                while q and now - q[0].t_arrival > self.request_deadline_s:
                    expired.append(q.pop(0))
                if not expired:
                    continue
                self._finish_rejected(expired, concept,
                                      TimedOut("request-deadline"))
                key = (s, concept)
                self.wheel.cancel(key)
                if q:                     # new head keeps its deadline
                    self.wheel.schedule(key,
                                        q[0].t_arrival + self.max_wait_s)

    def _finish_rejected(self, reqs: list, concept: str, result) -> None:
        """Complete requests with a typed non-label result — the only
        exits besides a real label; nothing is left pending forever."""
        st = self.stats[concept]
        now = self.clock()
        for req in reqs:
            req.result = result
            req.t_done = now
            self.delivered.append(req.rid)
        if isinstance(result, Shed):
            st.shed += len(reqs)
        elif result.reason == "request-deadline":
            st.expired += len(reqs)
        else:
            st.timeouts += len(reqs)

    def _flush(self, s: int, concept: str, reason: str) -> None:
        st = self.stats[concept]
        ctl = self._ctl[concept]
        if ctl is not None:
            # load control observes at flush time: backlog across the
            # concept's shards + the latency of the last delivered flush
            before = ctl.level
            level = ctl.observe(self._concept_depth(concept),
                                self._last_flush_lat.get(concept))
            if level > before:
                st.degrade_steps += 1
            elif level < before:
                st.recover_steps += 1
        q = self._queues[s][concept]
        take, self._queues[s][concept] = \
            q[:self.batch_size], q[self.batch_size:]
        key = (s, concept)
        self.wheel.cancel(key)
        rest = self._queues[s][concept]
        if rest:                           # new head keeps its deadline
            self.wheel.schedule(key, rest[0].t_arrival + self.max_wait_s)
        setattr(st, f"{reason}_flushes",
                getattr(st, f"{reason}_flushes") + 1)
        self._dispatch(s, concept, take)

    def _dispatch(self, s: int, concept: str, take: list,
                  casc: CompiledCascade | None = None,
                  retries: int = 0, count_rows: bool = True) -> None:
        casc = casc if casc is not None else self._active_cascade(concept)
        st = self.stats[concept]
        nv = len(take)
        width = slab_width(nv, self.batch_size)
        rows = np.array([int(r.payload) for r in take], np.int64)
        rows_p = pad_rows(rows, width)

        base_hw = self.images.shape[1]
        small = [r for r in casc.resolutions if r != base_hw]
        # probe the cache with the VALID rows only (the pad repeats the
        # last row — probing it would double-count its entries), then
        # pad the gathered blocks to slab width
        cached = (self.repcache.lookup_rows(rows, small)
                  if self.repcache is not None and small else None)

        attempts = 0
        while True:
            dev = self._device_for(s)
            if dev is None:                # every device failed
                self._finish_rejected(take, concept,
                                      Shed("no-healthy-device"))
                return
            if dev in self._inflight:      # one in-flight batch per device
                if self._batch_timed_out(self._inflight[dev]):
                    self._recover_batch(dev)
                    if self._dev_index[dev] in self._failed:
                        continue           # recovery failed it: re-pick
                else:
                    self._deliver(dev)
            try:
                if self.faults is not None:
                    self.faults.on_dispatch(self._dev_index[dev])
                if cached is not None:
                    pyr = {r: (np.concatenate(
                                   [v, np.repeat(v[-1:], width - nv,
                                                 axis=0)])
                               if width > nv else v)
                           for r, v in cached.items()}
                    if base_hw in casc.resolutions:
                        pyr[base_hw] = self.images[rows_p]
                    labels = self._fn(casc, width, "pyr")(
                        {r: self._commit(v, dev) for r, v in pyr.items()})
                    levels = None
                else:
                    labels, levels = self._fn(casc, width, "base")(
                        self._commit(self.images[rows_p], dev))
            except (DeviceError, TransientComputeError) as e:
                attempts += 1
                st.retries += 1
                if isinstance(e, DeviceError):
                    # dispatch-time device failure: fail the device so
                    # every future dispatch re-routes around it
                    self._failed.add(self._dev_index[dev])
                if attempts > self.dispatch_retries:
                    self._finish_rejected(take, concept,
                                          Shed("dispatch-failed"))
                    return
                continue
            break

        if self.faults is not None:
            labels = self.faults.wrap_labels(labels,
                                             self._dev_index[dev])
        st.batches += 1
        if count_rows:
            st.rows_evaluated += nv
            st.padded_slots += width - nv
            if cached is not None:
                st.rep_hit_rows += nv
        self._inflight[dev] = _InFlight(s, concept, casc, take, rows,
                                        labels, levels,
                                        t_dispatch=self.clock(),
                                        retries=retries)
        if len(self._inflight) > self._inflight_max:
            self._inflight_max = len(self._inflight)

    def _ready(self, labels) -> bool:
        return not hasattr(labels, "is_ready") or labels.is_ready()

    def _batch_timed_out(self, inf: _InFlight) -> bool:
        return (self.batch_timeout_s is not None
                and not self._ready(inf.labels)
                and self.clock() - inf.t_dispatch > self.batch_timeout_s)

    def _check_batch_timeouts(self, now: float) -> None:
        if self.batch_timeout_s is None:
            return
        for dev in list(self._inflight):
            inf = self._inflight.get(dev)
            if inf is not None and self._batch_timed_out(inf):
                self._recover_batch(dev)

    def _recover_batch(self, dev) -> None:
        """A timed-out in-flight batch: fail its device, then re-route
        to a healthy one (bounded by ``dispatch_retries``) or complete
        its requests with a typed ``TimedOut``. Re-dispatch re-runs the
        SAME rung, so labels stay identical to an un-faulted run."""
        inf = self._inflight.pop(dev)
        self._failed.add(self._dev_index[dev])
        st = self.stats[inf.concept]
        if (inf.retries < self.dispatch_retries
                and self._device_for(inf.shard) is not None):
            st.retries += 1
            self._dispatch(inf.shard, inf.concept, inf.take,
                           casc=inf.casc, retries=inf.retries + 1,
                           count_rows=False)
        else:
            self._finish_rejected(inf.take, inf.concept,
                                  TimedOut("batch-timeout"))

    def deliver_ready(self) -> None:
        """Deliver finished in-flight batches; leave running ones in
        flight (the dispatch-ahead overlap window)."""
        for dev in list(self._inflight):
            if self._ready(self._inflight[dev].labels):
                self._deliver(dev)

    def _deliver(self, dev) -> None:
        inf = self._inflight.pop(dev, None)
        if inf is None:
            return
        casc = inf.casc
        nv = len(inf.take)
        labels = np.asarray(inf.labels)[:nv]    # deferred sync happens here
        sstore = self._shard_stores[inf.shard]
        sstore.record(casc.key, inf.rows, labels)
        # post-flush commit: shard-store merge semantics restricted to
        # the delivered rows (O(batch), not O(corpus), per delivery) —
        # a degraded rung commits under its OWN casc.key, so degraded
        # labels can never poison the primary's virtual column
        self.store.merge_rows_from(sstore, inf.rows)
        if inf.levels is not None and self.repcache is not None:
            for r, v in inf.levels.items():
                self.repcache.put_rows(inf.rows, r, np.asarray(v)[:nv])
        now = self.clock()
        st = self.stats[inf.concept]
        if casc is not self._ladder[inf.concept][0]:
            st.degraded_rows += nv
            st.degraded_batches += 1
        self._last_flush_lat[inf.concept] = now - inf.t_dispatch
        for req, lab in zip(inf.take, labels):
            req.result = int(lab)
            req.t_done = now
            st.latencies.append(now - req.t_arrival)
            self.delivered.append(req.rid)

    # --------------------------------------------------- host interface --
    def next_event_time(self) -> float | None:
        """Earliest instant at which time-driven work comes due: a flush
        deadline, a batch timeout, or a request deadline. None when no
        timed work is pending — the event host (serve/host.py) sleeps
        exactly until this."""
        cands = []
        nd = self.wheel.next_deadline()
        if nd is not None:
            cands.append(nd)
        if self.batch_timeout_s is not None:
            cands.extend(inf.t_dispatch + self.batch_timeout_s
                         for inf in self._inflight.values())
        if self.request_deadline_s is not None:
            cands.extend(q[0].t_arrival + self.request_deadline_s
                         for qs in self._queues
                         for q in qs.values() if q)
        return min(cands, default=None)

    def busy(self) -> bool:
        """True while any request is queued or any batch is in flight."""
        return bool(self._inflight) or any(
            q for qs in self._queues for q in qs.values())

    # ------------------------------------------------------------- stats --
    def latencies(self) -> list:
        out = []
        for st in self.stats.values():
            out.extend(st.latencies)
        return out

    def summary(self) -> dict:
        agg = {k: sum(getattr(st, k) for st in self.stats.values())
               for k in ("requests", "store_hits", "rep_hit_rows",
                         "rows_evaluated", "batches", "padded_slots",
                         "size_flushes", "deadline_flushes",
                         "drain_flushes", "shed", "expired", "timeouts",
                         "retries", "degraded_rows", "degraded_batches",
                         "degrade_steps", "recover_steps")}
        agg["shards"] = self.n_shards
        agg["devices"] = len(set(self.devices))
        agg["store_hit_rate"] = (agg["store_hits"] / agg["requests"]
                                 if agg["requests"] else 0.0)
        agg["goodput_requests"] = (agg["requests"] - agg["shed"]
                                   - agg["expired"] - agg["timeouts"])
        agg["degraded_fraction"] = (agg["degraded_rows"] / agg["requests"]
                                    if agg["requests"] else 0.0)
        # gauges (current + high-water): queue depth, in-flight batches
        agg["queue_depth"] = {
            "current": self._queued_total(),
            "max": max((st.depth_max for st in self.stats.values()),
                       default=0)}
        agg["in_flight"] = {"current": len(self._inflight),
                            "max": self._inflight_max}
        agg["failed_devices"] = sorted(self._failed)
        agg["active_levels"] = {c: self.active_level(c)
                                for c in self.cascades}
        lat = self.latencies()
        if lat:
            ms = np.asarray(lat, np.float64) * 1e3
            agg["latency_ms"] = {
                "p50": round(float(np.percentile(ms, 50)), 3),
                "p95": round(float(np.percentile(ms, 95)), 3),
                "p99": round(float(np.percentile(ms, 99)), 3)}
        else:
            agg["latency_ms"] = None
        if self.repcache is not None:
            agg["repcache"] = self.repcache.stats()
        if self.faults is not None:
            agg["faults_injected"] = dict(self.faults.injected)
        return agg
