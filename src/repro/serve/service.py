"""Shard-aware async cascade serving (DESIGN.md §10).

``AsyncCascadeService`` replaces the synchronous-polling
``CascadeService`` (serve/batcher.py) for request streams over a
resident corpus ("does frame ROW contain CONCEPT?"):

* **shard routing** — requests are routed by the ShardPlan's stationary
  hash partitioning (`sharding/policy.shard_route`) to one queue PER
  SHARD DEVICE. A row's shard owns its virtual columns (the same
  ownership the sharded scan engine uses), so the store lookup on
  submit is a shard-local read, and an offline hash-sharded scan leaves
  its labels exactly where the serving path will look for them.
* **deadline scheduling** — a deadline wheel (serve/scheduler.py) holds
  one entry per non-empty (shard, concept) queue group; a group flushes
  when ``batch_size`` requests are waiting OR when its oldest request's
  deadline (``arrival + max_wait_s``) comes due on ``poll()``. Flushed
  batches are assembled with the lockstep's bucketed power-of-2 slab
  builder (`engine/sharded.slab_width`/`pad_rows`), so a
  deadline-triggered partial flush pays bucket-width compute, not the
  sync batcher's full pad-to-capacity.
* **dispatch-ahead** — one in-flight batch per device:
  ``block_until_ready`` is deferred to result delivery, so host-side
  routing and gather of the next batch overlap the device compute of
  the previous one. Exactness is untouched: deferral changes WHEN a
  label array is read, never its value, and per-device delivery is FIFO
  (a device's in-flight batch is delivered before it accepts the next),
  so evaluated results are delivered in submission order per queue.
* **post-flush commit** — labels are recorded into the shard-local
  store and committed corpus-wide via ``VirtualColumnStore.merge_from``
  (the sharded scan's merge semantics: computed labels never
  overwritten). A re-submitted decided row is answered on submit with
  ZERO model invocations.
* **representation reuse** — an optional cross-query
  ``RepresentationCache`` (serve/repcache.py) backs batch assembly:
  when every row of a flush already has every non-base pooled level
  cached, the batch runs the from-pyramid variant (no re-pooling);
  otherwise the from-base variant runs and publishes its freshly pooled
  levels. The same cache object can back a ``ScanEngine``, so offline
  scans warm the online path.

Exactness: batches run full-width cascade levels
(``caps = [width] * (L-1)``), deliberately ignoring
``CompiledCascade.capacities`` exactly like the scan paths — labels are
per-row independent of batch packing, hence bit-identical to
``ScanEngine``/``naive_scan`` and safe to commit as virtual columns
(the sync batcher's capped-overflow trick trades that exactness for
bounded tail compute; see CompiledCascade).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.engine.scan import CompiledCascade, VirtualColumnStore
from repro.engine.sharded import pad_rows, slab_width
from repro.serve.batcher import Request
from repro.serve.scheduler import DeadlineWheel
from repro.sharding.policy import shard_route


@dataclass
class ServiceStats:
    """Per-concept serving counters."""
    requests: int = 0
    store_hits: int = 0        # answered on submit, zero invocations
    rep_hit_rows: int = 0      # rows assembled from the repcache
    rows_evaluated: int = 0
    batches: int = 0
    padded_slots: int = 0
    size_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    # bounded window (newest first out the back) so a resident service
    # can't grow a float per request forever
    latencies: deque = field(
        default_factory=lambda: deque(maxlen=65536))


@dataclass
class _InFlight:
    """A dispatched, not-yet-delivered batch parked on its device."""
    shard: int
    concept: str
    take: list                 # the batch's Requests (arrival order)
    rows: np.ndarray           # their row ids (unpadded)
    labels: object             # device array; forced at delivery
    levels: dict | None        # device arrays for the repcache, or None


class AsyncCascadeService:
    """Deadline-scheduled, shard-routed serving over a resident corpus.

    ``submit(concept, Request(rid, row_id))`` answers immediately from
    the row's shard-local virtual columns when the label is known;
    otherwise the request joins its (shard, concept) queue. ``poll()``
    fires due deadlines and harvests finished batches; ``drain()``
    flushes and delivers everything. Results land on ``Request.result``
    exactly like the sync service."""

    def __init__(self, images, cascades: Mapping[str, CompiledCascade],
                 *, shards: int | None = None, batch_size: int = 32,
                 max_wait_s: float = 0.005, clock=time.perf_counter,
                 repcache=None, store: VirtualColumnStore | None = None,
                 jit: bool = True, devices: Sequence | None = None,
                 fn_cache: dict | None = None):
        from repro.launch.mesh import shard_devices

        self.images = np.asarray(images, np.float32)
        self.cascades = dict(cascades)
        self.devices = list(devices) if devices is not None \
            else shard_devices(shards)
        self.n_shards = int(shards) if shards is not None \
            else len(self.devices)
        if self.n_shards < 1:
            raise ValueError("need at least one shard")
        self.batch_size = int(batch_size)
        self.max_wait_s = float(max_wait_s)
        self.clock = clock
        self.jit = jit
        self.repcache = repcache
        if repcache is not None:
            from repro.serve.repcache import corpus_token
            repcache.bind_corpus(corpus_token(self.images))
        self.wheel = DeadlineWheel(granularity=max(self.max_wait_s / 4,
                                                   1e-6))

        # corpus-wide store (shared with the caller when given, so a
        # scan engine's virtual columns serve requests directly) plus
        # shard-local stores seeded with each shard's own partition —
        # all a shard's queue will ever look up
        self.store = store if store is not None \
            else VirtualColumnStore(len(self.images))
        self._row_shard = shard_route(np.arange(len(self.images)),
                                      self.n_shards)
        self._shard_stores = []
        for s in range(self.n_shards):
            st = VirtualColumnStore(len(self.images))
            st.seed_from(self.store, np.where(self._row_shard == s)[0])
            self._shard_stores.append(st)

        self._queues: list[dict[str, list]] = [
            {} for _ in range(self.n_shards)]
        self._inflight: dict = {}          # device -> _InFlight
        # (concept, width, variant) -> compiled runner; pass a shared
        # dict (naive_scan's _fn_cache idiom) so fresh-state benchmark
        # services don't re-pay jit compilation
        self._fns: dict = fn_cache if fn_cache is not None else {}
        self.stats = {c: ServiceStats() for c in self.cascades}
        # rids in delivery order — an observability window (FIFO tests,
        # debugging), bounded so a long-lived service can't leak
        self.delivered: deque = deque(maxlen=65536)

    # ---------------------------------------------------------- plumbing --
    @property
    def concepts(self) -> list[str]:
        return list(self.cascades)

    def shard_of(self, row: int) -> int:
        return int(self._row_shard[int(row)])

    def _commit(self, x, dev):
        if not self.jit:
            return np.asarray(x)
        import jax
        return jax.device_put(np.asarray(x), dev)

    def _fn(self, concept: str, width: int, variant: str):
        """Compiled batch runner, cached per (cascade key, slab width,
        variant) — the cascade's (concept, cascade-id) key, not the
        bare concept, so a shared fn_cache can never serve a retrained
        cascade's labels from a stale compile (same reason naive_scan's
        _fn_cache keys by casc.key). 'base': raw rows in, labels +
        freshly pooled non-base levels out. 'pyr': cached pooled levels
        in, labels out."""
        key = (self.cascades[concept].key, width, variant)
        if key not in self._fns:
            from repro.core.executor import run_cascade_on_pyramid
            from repro.core.transforms import materialize_pyramid

            casc = self.cascades[concept]
            res = tuple(casc.resolutions)
            base_hw = self.images.shape[1]
            small = tuple(r for r in res if r != base_hw)
            caps = [width] * (len(casc.model_fns) - 1)

            if variant == "base":
                def fn(imgs):
                    pyr = materialize_pyramid(imgs, res)
                    labels = run_cascade_on_pyramid(
                        {r: pyr[r] for r in res}, casc.model_fns,
                        casc.thresholds, casc.reps, caps)[0]
                    return labels, {r: pyr[r] for r in small}
            else:
                def fn(pyr):
                    return run_cascade_on_pyramid(
                        pyr, casc.model_fns, casc.thresholds, casc.reps,
                        caps)[0]
            if self.jit:
                import jax
                fn = jax.jit(fn)
            self._fns[key] = fn
        return self._fns[key]

    def warmup(self, widths: Sequence[int] | None = None) -> int:
        """Pre-compile AND execute one dummy batch per (device, concept,
        slab width, variant) so live traffic never hits a compile
        stall — serving cold-start elimination. Default widths: every
        bucket ``slab_width`` can emit for this batch_size. Dummy
        batches never touch the stores or the repcache. Returns the
        number of executables exercised."""
        if widths is None:
            widths = sorted({slab_width(n, self.batch_size)
                             for n in range(1, self.batch_size + 1)})
        base_hw = self.images.shape[1]
        rows = np.zeros(max(widths), np.int64)
        n = 0
        for concept, casc in self.cascades.items():
            small = [r for r in casc.resolutions if r != base_hw]
            for width in widths:
                imgs = self.images[rows[:width]]
                for dev in dict.fromkeys(self.devices):
                    lab, _ = self._fn(concept, width, "base")(
                        self._commit(imgs, dev))
                    np.asarray(lab)
                    n += 1
                    if not small:
                        continue
                    pyr = {r: np.zeros((width, r, r, 3), np.float32)
                           for r in small}
                    if base_hw in casc.resolutions:
                        pyr[base_hw] = imgs
                    np.asarray(self._fn(concept, width, "pyr")(
                        {r: self._commit(v, dev)
                         for r, v in pyr.items()}))
                    n += 1
        return n

    # ------------------------------------------------------ request path --
    def submit(self, concept: str, req: Request) -> None:
        req.t_arrival = self.clock()
        casc = self.cascades[concept]
        st = self.stats[concept]
        st.requests += 1
        row = int(req.payload)
        s = self.shard_of(row)
        cached = int(self._shard_stores[s].column(casc.key)[row])
        if cached < 0:
            # the shard seed is a snapshot: a co-owning scan engine may
            # have decided this row in the SHARED store after service
            # construction — adopt the late write into the shard's own
            # columns so the next lookup is local again
            cached = int(self.store.column(casc.key)[row])
            if cached >= 0:
                self._shard_stores[s].record(casc.key,
                                             np.array([row]), [cached])
        if cached >= 0:                    # shard-owned read, no model
            req.result = cached
            req.t_done = req.t_arrival
            st.store_hits += 1
            st.latencies.append(0.0)
            self.delivered.append(req.rid)
            return
        q = self._queues[s].setdefault(concept, [])
        q.append(req)
        if len(q) == 1:
            self.wheel.schedule((s, concept),
                                req.t_arrival + self.max_wait_s)
        if len(q) >= self.batch_size:
            self._flush(s, concept, "size")

    def poll(self) -> None:
        """Fire due deadlines, then harvest any finished batches without
        blocking on in-flight device compute."""
        now = self.clock()
        for s, concept in self.wheel.pop_due(now):
            if self._queues[s].get(concept):
                self._flush(s, concept, "deadline")
        self.deliver_ready()

    def drain(self) -> None:
        """Flush every queue and deliver every in-flight batch."""
        for s in range(self.n_shards):
            for concept in list(self._queues[s]):
                while self._queues[s][concept]:
                    self._flush(s, concept, "drain")
        for dev in list(self._inflight):
            self._deliver(dev)

    # ----------------------------------------------------- flush/deliver --
    def _flush(self, s: int, concept: str, reason: str) -> None:
        q = self._queues[s][concept]
        take, self._queues[s][concept] = \
            q[:self.batch_size], q[self.batch_size:]
        key = (s, concept)
        self.wheel.cancel(key)
        rest = self._queues[s][concept]
        if rest:                           # new head keeps its deadline
            self.wheel.schedule(key, rest[0].t_arrival + self.max_wait_s)
        st = self.stats[concept]
        setattr(st, f"{reason}_flushes",
                getattr(st, f"{reason}_flushes") + 1)
        self._dispatch(s, concept, take)

    def _dispatch(self, s: int, concept: str, take: list) -> None:
        casc = self.cascades[concept]
        st = self.stats[concept]
        nv = len(take)
        width = slab_width(nv, self.batch_size)
        rows = np.array([int(r.payload) for r in take], np.int64)
        rows_p = pad_rows(rows, width)
        dev = self.devices[s]
        if dev in self._inflight:          # one in-flight batch per device
            self._deliver(dev)

        base_hw = self.images.shape[1]
        small = [r for r in casc.resolutions if r != base_hw]
        # probe the cache with the VALID rows only (the pad repeats the
        # last row — probing it would double-count its entries), then
        # pad the gathered blocks to slab width
        cached = (self.repcache.lookup_rows(rows, small)
                  if self.repcache is not None and small else None)
        if cached is not None:
            pyr = {r: (np.concatenate(
                           [v, np.repeat(v[-1:], width - nv, axis=0)])
                       if width > nv else v)
                   for r, v in cached.items()}
            if base_hw in casc.resolutions:
                pyr[base_hw] = self.images[rows_p]
            labels = self._fn(concept, width, "pyr")(
                {r: self._commit(v, dev) for r, v in pyr.items()})
            levels = None
            st.rep_hit_rows += nv
        else:
            labels, levels = self._fn(concept, width, "base")(
                self._commit(self.images[rows_p], dev))
        st.batches += 1
        st.rows_evaluated += nv
        st.padded_slots += width - nv
        self._inflight[dev] = _InFlight(s, concept, take, rows, labels,
                                        levels)

    def deliver_ready(self) -> None:
        """Deliver finished in-flight batches; leave running ones in
        flight (the dispatch-ahead overlap window)."""
        for dev in list(self._inflight):
            lab = self._inflight[dev].labels
            if not hasattr(lab, "is_ready") or lab.is_ready():
                self._deliver(dev)

    def _deliver(self, dev) -> None:
        inf = self._inflight.pop(dev, None)
        if inf is None:
            return
        casc = self.cascades[inf.concept]
        nv = len(inf.take)
        labels = np.asarray(inf.labels)[:nv]    # deferred sync happens here
        sstore = self._shard_stores[inf.shard]
        sstore.record(casc.key, inf.rows, labels)
        # post-flush commit: shard-store merge semantics restricted to
        # the delivered rows (O(batch), not O(corpus), per delivery)
        self.store.merge_rows_from(sstore, inf.rows)
        if inf.levels is not None and self.repcache is not None:
            for r, v in inf.levels.items():
                self.repcache.put_rows(inf.rows, r, np.asarray(v)[:nv])
        now = self.clock()
        st = self.stats[inf.concept]
        for req, lab in zip(inf.take, labels):
            req.result = int(lab)
            req.t_done = now
            st.latencies.append(now - req.t_arrival)
            self.delivered.append(req.rid)

    # ------------------------------------------------------------- stats --
    def latencies(self) -> list:
        out = []
        for st in self.stats.values():
            out.extend(st.latencies)
        return out

    def summary(self) -> dict:
        agg = {k: sum(getattr(st, k) for st in self.stats.values())
               for k in ("requests", "store_hits", "rep_hit_rows",
                         "rows_evaluated", "batches", "padded_slots",
                         "size_flushes", "deadline_flushes",
                         "drain_flushes")}
        agg["shards"] = self.n_shards
        agg["devices"] = len(set(self.devices))
        agg["store_hit_rate"] = (agg["store_hits"] / agg["requests"]
                                 if agg["requests"] else 0.0)
        if self.repcache is not None:
            agg["repcache"] = self.repcache.stats()
        return agg
