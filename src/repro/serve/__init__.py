"""Serving layer: the sync size-or-deadline batcher (batcher.py), the
shard-aware async service (service.py, DESIGN.md §10) with its deadline
scheduler (scheduler.py) and cross-query representation cache
(repcache.py), plus LM-serving pieces (continuous batching, KV cache,
speculative decoding)."""
from repro.serve.batcher import Batcher, BatcherStats, CascadeService, Request
from repro.serve.repcache import RepresentationCache
from repro.serve.scheduler import DeadlineWheel, ManualClock
from repro.serve.service import AsyncCascadeService, ServiceStats

__all__ = [
    "AsyncCascadeService", "Batcher", "BatcherStats", "CascadeService",
    "DeadlineWheel", "ManualClock", "RepresentationCache", "Request",
    "ServiceStats",
]
