"""Serving layer: the sync size-or-deadline batcher (batcher.py), the
shard-aware async service (service.py, DESIGN.md §10) with its deadline
scheduler (scheduler.py), cross-query representation cache
(repcache.py), wall-clock event host (host.py), overload/fault
hardening (faults.py — typed Shed/TimedOut results, fault plans;
DESIGN.md §12), plus LM-serving pieces (continuous batching, KV cache,
speculative decoding)."""
from repro.serve.batcher import Batcher, BatcherStats, CascadeService, Request
from repro.serve.faults import (DeviceError, FaultInjector, FaultPlan,
                                NeverReadyLabels, Shed, TimedOut,
                                TransientComputeError, is_label)
from repro.serve.host import EventHost, FakeTimer, WallTimer
from repro.serve.repcache import RepresentationCache
from repro.serve.scheduler import DeadlineWheel, ManualClock
from repro.serve.service import (AsyncCascadeService, DegradeConfig,
                                 ServiceStats)

__all__ = [
    "AsyncCascadeService", "Batcher", "BatcherStats", "CascadeService",
    "DeadlineWheel", "DegradeConfig", "DeviceError", "EventHost",
    "FakeTimer", "FaultInjector", "FaultPlan", "ManualClock",
    "NeverReadyLabels", "RepresentationCache", "Request", "ServiceStats",
    "Shed", "TimedOut", "TransientComputeError", "WallTimer", "is_label",
]
