"""Wall-clock event host for the async serving subsystem (DESIGN.md
§12.1).

``AsyncCascadeService.poll()`` only runs when a caller ticks it — a
stalled or departed client silently rots every queued deadline.
``EventHost`` closes that hole: a timer-driven loop that sleeps until
``service.next_event_time()`` (flush deadlines, batch timeouts, request
deadlines — whichever comes first) and fires ``poll()`` WITHOUT caller
cooperation. Submitting through the host wakes the timer so an
earlier-than-expected deadline re-arms immediately.

Everything time-shaped is injected, so the loop body is fully testable
with zero wall-clock sleeps: the CLOCK (``ManualClock`` in tests) feeds
the service, and the TIMER (``FakeTimer`` in tests, ``WallTimer`` — a
``threading.Event`` — in production) is where the loop parks between
events. Tests drive ``step()`` directly: advance the virtual clock,
step once, and assert what fired and how long the host ASKED to sleep;
the background thread is nothing but ``while running: wait(step())``.

Thread safety: the service is single-threaded by design; the host
serializes every service call (its own ``submit``/``drain``/``step``)
behind one lock, so callers interact with the service only through the
host while it runs.
"""
from __future__ import annotations

import threading


class WallTimer:
    """Production timer: ``wait(timeout)`` parks on a threading.Event;
    ``wake()`` fires it early (new work arrived). Returns True when
    woken early, False on timeout — the loop doesn't care, it re-polls
    either way."""

    def __init__(self):
        self._ev = threading.Event()

    def wait(self, timeout: float | None) -> bool:
        fired = self._ev.wait(timeout)
        self._ev.clear()
        return fired

    def wake(self) -> None:
        self._ev.set()


class FakeTimer:
    """Test timer: records every wait the host asked for and never
    blocks — the test advances the ManualClock itself and calls
    ``step()`` again. ``waits`` is the host's requested sleep schedule,
    directly assertable."""

    def __init__(self):
        self.waits: list = []
        self.wakes = 0

    def wait(self, timeout: float | None) -> bool:
        self.waits.append(timeout)
        return False

    def wake(self) -> None:
        self.wakes += 1


class EventHost:
    """Timer-driven serving loop around an ``AsyncCascadeService``.

    * ``submit(concept, req)`` — thread-safe submit + timer wake;
    * ``step()`` — ONE loop iteration: poll the service, then return
      how long to sleep until the next timed event (None = idle). This
      is the unit tests drive deterministically;
    * ``start()``/``stop()`` — run ``step`` on a daemon thread parked
      on the timer between events;
    * ``wait_idle(timeout)`` — block the CALLER until the service has
      no queued or in-flight work (delivery condition for examples and
      integration tests; not a sleep — it returns the instant the host
      finishes the last delivery).
    """

    def __init__(self, service, *, timer=None, clock=None,
                 idle_interval_s: float = 0.05):
        self.service = service
        self.timer = timer if timer is not None else WallTimer()
        self.clock = clock if clock is not None else service.clock
        # in-flight batches have no timed deadline unless batch_timeout
        # is set; the idle interval bounds how long a finished batch can
        # sit undelivered with no other event to wake the loop
        self.idle_interval_s = float(idle_interval_s)
        self._lock = threading.RLock()
        self._running = False
        self._thread: threading.Thread | None = None
        self._idle = threading.Event()
        self._idle.set()
        self.steps = 0

    # ------------------------------------------------------- client face --
    def submit(self, concept: str, req) -> None:
        with self._lock:
            self.service.submit(concept, req)
            busy = self.service.busy()
        if busy:
            self._idle.clear()
        self.timer.wake()

    def drain(self) -> None:
        with self._lock:
            self.service.drain()
        self._idle.set()

    def summary(self) -> dict:
        with self._lock:
            return self.service.summary()

    # --------------------------------------------------------- loop body --
    def step(self) -> float | None:
        """Fire everything due, then compute the sleep until the next
        timed event: ``next_event_time() - now`` (floored at 0), the
        idle interval while batches are in flight with nothing timed,
        or None when the service is fully idle."""
        with self._lock:
            self.service.poll()
            nxt = self.service.next_event_time()
            busy = self.service.busy()
            now = self.clock()
        self.steps += 1
        if not busy:
            self._idle.set()
            return None
        self._idle.clear()
        sleep = None if nxt is None else max(nxt - now, 0.0)
        if self.service._inflight and self.service.batch_timeout_s is None:
            # in-flight work with no timed deadline: re-poll at the idle
            # interval so finished batches get harvested promptly
            sleep = self.idle_interval_s if sleep is None \
                else min(sleep, self.idle_interval_s)
        return self.idle_interval_s if sleep is None else sleep

    def _run(self) -> None:
        while self._running:
            timeout = self.step()
            if not self._running:
                break
            self.timer.wait(self.idle_interval_s
                            if timeout is None else timeout)

    # ------------------------------------------------------- lifecycle ----
    def start(self) -> "EventHost":
        if self._running:
            return self
        self._running = True
        self._thread = threading.Thread(target=self._run,
                                        name="serve-event-host",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._running = False
        self.timer.wake()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until no work is queued or in flight (event-driven —
        set by the host thread the moment the last delivery lands)."""
        return self._idle.wait(timeout)

    def __enter__(self) -> "EventHost":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
