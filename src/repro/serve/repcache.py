"""Cross-query representation cache (DESIGN.md §10.3, ROADMAP item).

The scan engine materializes the shared RGB pyramid per chunk per query
and the serving path re-pools every request batch from the raw base
images — in an interactive session (the paper's ONGOING scenario) the
same hot rows are pooled again and again. ``RepresentationCache`` is an
LRU over ``(row, resolution) -> pooled RGB level row`` with a byte
budget, shared across queries AND requests: one object can back a
``ScanEngine`` (per-chunk pyramid hook) and an ``AsyncCascadeService``
(per-flush batch assembly) simultaneously, so an offline scan warms the
online path and vice versa.

Exactness: an entry is the deterministic progressive box-filter pooling
of the row's base image (core/transforms.materialize_pyramid), so a
cache hit is bit-identical to recomputation in the dyadic-pixel regime
every corpus in this repo uses — reuse changes bytes moved, never
labels. Entries are stored pre-color-transform (RGB), the same shared
level every color representation projects from, so concepts with
different color reps share entries.

Accounting is all-or-none per lookup: ``lookup_rows`` returns stacked
blocks only when EVERY (row, level) entry is present — the batch then
skips pooling entirely — and counts hits/misses at entry granularity.

Joint planning alignment (DESIGN.md §11.2): keys are plain
``(row, resolution)``, and the scan engine publishes exactly the
non-base levels of the plan it executes (``PhysicalPlan.level_set`` for
a planned query — the same union ``stage_needs`` materializes per
chunk). So a joint-planned scan warms serving for precisely the level
set the joint optimizer chose, and a smaller joint level union means
fewer bytes cached per row — no key-space change was needed for joint
plans (tests/test_joint_planner.py covers the scan→service handoff).
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


def corpus_token(images) -> tuple:
    """Cheap deterministic corpus fingerprint: shape plus a strided
    sample checksum. The same pixel data in a different buffer (engines
    copy on construction) maps to the same token; two different corpora
    virtually never collide."""
    arr = np.asarray(images)
    step = max(1, len(arr) // 17)
    return tuple(arr.shape) + (float(np.float64(arr[::step].sum())),)


class RepresentationCache:
    """Byte-budgeted LRU of pooled pyramid level rows keyed by
    ``(row, resolution)``. Arrays are copied on insert (a cached level
    must not pin the flush-sized block it was sliced from) and returned
    by reference (callers stack them into fresh batch tensors).

    Keys carry no corpus identity, so every consumer binds its corpus
    fingerprint on attach (``bind_corpus``): sharing one cache between
    a scan engine and a service over the SAME corpus is the designed
    use; attaching a second, different corpus raises instead of
    silently serving another corpus's pixels (whose labels would then
    be committed as virtual columns permanently)."""

    def __init__(self, budget_bytes: int = 64 << 20):
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        self.budget_bytes = int(budget_bytes)
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserts = 0
        self._od: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._corpus: tuple | None = None

    def bind_corpus(self, token: tuple) -> None:
        """First binder wins; a different corpus raises ValueError."""
        if self._corpus is None:
            self._corpus = token
        elif self._corpus != token:
            raise ValueError(
                "RepresentationCache is already bound to a different "
                "corpus — its (row, resolution) keys would collide; "
                "use one cache per corpus")

    def __len__(self) -> int:
        return len(self._od)

    def __contains__(self, key: tuple) -> bool:
        return key in self._od

    # ------------------------------------------------------ single entry --
    def get(self, row: int, resolution: int):
        """The level row, or None. A hit refreshes LRU recency."""
        key = (int(row), int(resolution))
        arr = self._od.get(key)
        if arr is None:
            self.misses += 1
            return None
        self._od.move_to_end(key)
        self.hits += 1
        return arr

    def put(self, row: int, resolution: int, level) -> None:
        key = (int(row), int(resolution))
        arr = np.array(level, np.float32)   # own copy, never a view
        if arr.nbytes > self.budget_bytes:
            return                           # would evict everything for one row
        old = self._od.pop(key, None)
        if old is not None:
            self.nbytes -= old.nbytes
        self._od[key] = arr
        self.nbytes += arr.nbytes
        self.inserts += 1
        while self.nbytes > self.budget_bytes:
            _, victim = self._od.popitem(last=False)
            self.nbytes -= victim.nbytes
            self.evictions += 1

    # ------------------------------------------------------- batch entry --
    def lookup_rows(self, ids, resolutions) -> dict | None:
        """All-or-none batch lookup: ``{resolution: (len(ids), r, r, 3)}``
        stacked blocks when every (row, level) entry is cached, else
        None. Counters move at (row, level) granularity, and a failed
        lookup serves NOTHING — every probed entry of a failed batch
        counts as a miss, so ``hit_rate`` is exactly the fraction of
        entry lookups actually served from cache."""
        ids = np.asarray(ids, np.int64)
        resolutions = [int(r) for r in resolutions]
        if any((int(i), r) not in self._od
               for r in resolutions for i in ids):
            self.misses += len(ids) * len(resolutions)
            return None
        out = {}
        for r in resolutions:
            rows = [self.get(int(i), r) for i in ids]
            out[r] = (np.stack(rows) if rows
                      else np.empty((0, r, r, 3), np.float32))
        return out

    def put_rows(self, ids, resolution: int, block) -> None:
        """Insert one pooled level for a batch of rows; ``block`` is
        ``(len(ids), r, r, 3)`` (each row copied out of the block)."""
        block = np.asarray(block)
        for i, row in enumerate(np.asarray(ids, np.int64)):
            self.put(int(row), resolution, block[i])

    # ------------------------------------------------------- persistence --
    def save(self, path) -> None:
        """Persist the cache as an npz: entries in LRU order (oldest
        first, so a budget-trimmed load evicts the same victims the
        live cache would), plus the bound corpus token. Entries are
        deterministic poolings of the corpus, so a reload serves
        bit-identical levels."""
        token = () if self._corpus is None else self._corpus
        data = {"budget_bytes": np.int64(self.budget_bytes),
                "token": np.asarray(token, np.float64),
                "keys": np.asarray(list(self._od), np.int64)}
        for i, arr in enumerate(self._od.values()):
            data[f"ent_{i}"] = arr
        np.savez(path, **data)

    @classmethod
    def load(cls, path, token: tuple | None = None
             ) -> "RepresentationCache":
        """Inverse of ``save``; reuses the ``bind_corpus`` contract:
        pass the attaching corpus's token and a snapshot saved for a
        different corpus refuses to load (its (row, resolution) keys
        would serve another corpus's pixels). ``token=None`` skips the
        check and re-binds lazily on first attach."""
        with np.load(path, allow_pickle=False) as z:
            cache = cls(int(z["budget_bytes"]))
            saved = tuple(float(v) for v in z["token"])
            if saved:
                cache._corpus = saved
                if token is not None:
                    cache.bind_corpus(tuple(token))
            for i, (row, res) in enumerate(z["keys"]):
                cache._od[(int(row), int(res))] = z[f"ent_{i}"]
                cache.nbytes += z[f"ent_{i}"].nbytes
        return cache

    # ------------------------------------------------------------- stats --
    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "entries": len(self._od),
            "bytes": int(self.nbytes),
            "budget_bytes": self.budget_bytes,
            "hits": int(self.hits),
            "misses": int(self.misses),
            "hit_rate": round(self.hit_rate, 4),
            "inserts": int(self.inserts),
            "evictions": int(self.evictions),
        }
