"""Deadline scheduling for the async serving subsystem (DESIGN.md §10.2).

``DeadlineWheel`` is a hashed timer wheel: deadlines land in coarse
slots of ``granularity`` seconds, ``pop_due(now)`` sweeps only the slots
at or before ``now`` and returns the keys whose exact deadline has
passed. Scheduling, cancelling, and re-scheduling are O(1) (stale slot
entries are lazily discarded on sweep — a key's live deadline is the
last one scheduled). The service keys entries by (shard, concept) queue
group: one entry per non-empty group, not per request, so the wheel
stays tiny under load.

Everything is driven by an injected ``clock`` callable — production uses
``time.perf_counter``, tests use ``ManualClock`` and advance virtual
time explicitly, so deadline semantics are tested without a single
wall-clock sleep.
"""
from __future__ import annotations


class ManualClock:
    """Injectable fake clock: ``clock()`` reads virtual time,
    ``advance`` moves it. Lets tests drive deadline-triggered flushes
    deterministically."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("time cannot move backwards")
        self.t += dt
        return self.t


class DeadlineWheel:
    """Bucketed deadline index over opaque hashable keys.

    Stale entries (cancelled or superseded schedules) are normally
    discarded lazily when their slot is swept — but cancel-heavy load
    (every size-triggered serving flush cancels its group's deadline)
    can park garbage tuples in FUTURE slots that a sweep never reaches
    until their slot time passes. ``schedule``/``cancel`` therefore
    compact eagerly once the stale count exceeds
    ``max(COMPACT_MIN, COMPACT_FACTOR * live)``: the slots are rebuilt
    from the live map in O(live), so total slot storage stays bounded
    by O(live) regardless of the schedule/cancel churn rate."""

    COMPACT_MIN = 64
    COMPACT_FACTOR = 4

    def __init__(self, granularity: float = 0.001):
        if granularity <= 0:
            raise ValueError("granularity must be positive")
        self.granularity = float(granularity)
        self._slots: dict[int, list] = {}      # slot -> [(deadline, key)]
        self._live: dict = {}                  # key -> its live deadline
        self._entries = 0                      # tuples stored across slots
        self.compactions = 0

    def __len__(self) -> int:
        return len(self._live)

    @property
    def stored_entries(self) -> int:
        """Slot tuples currently held (live + stale) — the quantity the
        compaction bound caps (regression-tested)."""
        return self._entries

    def _slot(self, t: float) -> int:
        return int(t / self.granularity)

    def _maybe_compact(self) -> None:
        stale = self._entries - len(self._live)
        if stale <= max(self.COMPACT_MIN,
                        self.COMPACT_FACTOR * len(self._live)):
            return
        self._slots = {}
        for key, deadline in self._live.items():
            self._slots.setdefault(self._slot(deadline), []).append(
                (deadline, key))
        self._entries = len(self._live)
        self.compactions += 1

    def schedule(self, key, deadline: float) -> None:
        """(Re-)schedule ``key``; the newest deadline wins, any earlier
        slot entry for the key turns stale and is dropped on sweep (or
        eagerly, by compaction)."""
        deadline = float(deadline)
        self._live[key] = deadline
        self._slots.setdefault(self._slot(deadline), []).append(
            (deadline, key))
        self._entries += 1
        self._maybe_compact()

    def cancel(self, key) -> None:
        """Forget ``key`` (no-op if absent) — the size-triggered flush
        path cancels the group's deadline."""
        self._live.pop(key, None)
        self._maybe_compact()

    def pop_due(self, now: float) -> list:
        """Remove and return every key whose live deadline is <= now,
        in deadline order. Slots strictly in the future are not touched."""
        horizon = self._slot(now)
        due = []
        for slot in sorted(s for s in self._slots if s <= horizon):
            keep = []
            for deadline, key in self._slots[slot]:
                if self._live.get(key) != deadline:
                    continue                   # stale or cancelled
                if deadline <= now:
                    due.append((deadline, key))
                    del self._live[key]
                else:
                    keep.append((deadline, key))
            self._entries -= len(self._slots[slot]) - len(keep)
            if keep:
                self._slots[slot] = keep
            else:
                del self._slots[slot]
        due.sort(key=lambda dk: dk[0])
        return [key for _, key in due]

    def next_deadline(self) -> float | None:
        """Earliest live deadline (None when idle) — lets a serving loop
        sleep exactly until the next flush is due."""
        return min(self._live.values(), default=None)
