"""Request batching for serving (paper-kind: inference over a corpus /
request stream). Size-or-deadline batching with fixed TPU-friendly batch
shapes (pad-to-capacity), plus simple latency accounting for tests and
the serve_cascade example. ``CascadeService`` stacks one Batcher per
predicate so a mixed request stream ("does this frame contain a?" /
"...contain b?") is routed into per-cascade batches — the online face of
the query engine (engine/scan.make_batch_runner builds the runners)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping


@dataclass
class Request:
    rid: int
    payload: Any
    t_arrival: float = 0.0
    result: Any = None
    t_done: float = 0.0


@dataclass
class BatcherStats:
    batches: int = 0
    padded_slots: int = 0
    latencies: list = field(default_factory=list)


class Batcher:
    """Collects requests; flushes when ``batch_size`` are waiting or the
    oldest request exceeds ``max_wait_s`` (checked on submit/flush)."""

    def __init__(self, run_batch: Callable[[list], list], batch_size: int,
                 max_wait_s: float = 0.01, clock=time.perf_counter):
        self.run_batch = run_batch
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.clock = clock
        self.pending: list[Request] = []
        self.stats = BatcherStats()

    def submit(self, req: Request):
        req.t_arrival = self.clock()
        self.pending.append(req)
        if len(self.pending) >= self.batch_size:
            self._flush()

    def poll(self):
        if self.pending and \
                self.clock() - self.pending[0].t_arrival >= self.max_wait_s:
            self._flush()

    def drain(self):
        while self.pending:
            self._flush()

    def _flush(self):
        batch = self.pending[: self.batch_size]
        self.pending = self.pending[self.batch_size:]
        pad = self.batch_size - len(batch)
        payloads = [r.payload for r in batch] + [batch[-1].payload] * pad
        results = self.run_batch(payloads)
        now = self.clock()
        for r, res in zip(batch, results):
            r.result = res
            r.t_done = now
            self.stats.latencies.append(now - r.t_arrival)
        self.stats.batches += 1
        self.stats.padded_slots += pad


class CascadeService:
    """Multi-predicate serving front: one Batcher per predicate, all
    sharing the caller's runner table ({concept -> run_batch}, e.g.
    jitted cascade executors from engine/scan.make_batch_runner).
    ``submit`` routes a request to its predicate's batch; poll/drain fan
    out to every batcher so deadlines hold across concepts.

    Batchers are keyed END-TO-END by ``(concept, cascade-id)``, never by
    cascade id alone: physical cascade ids (the planner's grid
    coordinates, pipeline.compiled_cascade) are concept-independent, so
    two predicates routinely select the SAME id. A cascade-id-keyed
    dedupe would merge both concepts into one batch queue, interleaving
    their results and dropping per-request arrival order per concept —
    ``from_cascades`` instead dedupes only the COMPILED RUNNER, and only
    for a genuinely shared CompiledCascade object, while keeping queues,
    order, and stats per (concept, cascade-id)
    (tests/test_serve_async.py regression)."""

    def __init__(self, runners: Mapping[str, Callable[[list], list]],
                 batch_size: int, max_wait_s: float = 0.01,
                 clock=time.perf_counter,
                 cascade_ids: Mapping[str, tuple] | None = None):
        self._key_of = {c: (c, tuple((cascade_ids or {}).get(c, ())))
                        for c in runners}
        self.batchers = {self._key_of[c]: Batcher(fn, batch_size,
                                                  max_wait_s, clock)
                         for c, fn in runners.items()}

    @classmethod
    def from_cascades(cls, cascades: Mapping[str, "object"],
                      batch_size: int, max_wait_s: float = 0.01,
                      clock=time.perf_counter, jit: bool = True):
        """Build from {concept -> CompiledCascade}: one batcher per
        (concept, cascade-id). The compiled runner is shared only when
        two concepts hand in the SAME CompiledCascade object — a bare
        cascade-id match is NOT sufficient to share models (grid
        coordinates repeat across concepts with different params)."""
        from repro.engine.scan import make_batch_runner

        compiled: dict[int, Callable] = {}
        runners, ids = {}, {}
        for concept, casc in cascades.items():
            if id(casc) not in compiled:
                compiled[id(casc)] = make_batch_runner(casc, batch_size,
                                                       jit=jit)
            runners[concept] = compiled[id(casc)]
            ids[concept] = tuple(casc.cascade_id)
        return cls(runners, batch_size, max_wait_s, clock,
                   cascade_ids=ids)

    @property
    def concepts(self):
        return list(self._key_of)

    def submit(self, concept: str, req: Request):
        self.batchers[self._key_of[concept]].submit(req)

    def poll(self):
        for b in self.batchers.values():
            b.poll()

    def drain(self):
        for b in self.batchers.values():
            b.drain()

    @property
    def stats(self) -> dict[str, BatcherStats]:
        return {c: self.batchers[k].stats
                for c, k in self._key_of.items()}

    def latencies(self) -> list:
        out = []
        for b in self.batchers.values():
            out.extend(b.stats.latencies)
        return out
