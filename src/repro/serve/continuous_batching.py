"""Continuous (slot-based) batching for decode serving.

The decode step always runs at a FIXED batch of ``n_slots`` (TPU-friendly
static shapes). Requests stream in with different prompt lengths and
generation budgets; finished slots are immediately refilled from the
queue instead of waiting for the whole batch to drain — the standard
production serving discipline (vLLM-style, without paging here; the KV
capacity is the per-slot max length).

The engine is model-agnostic: it drives the public Model API via a
prefill-one/decode-batch pair and keeps per-slot caches merged into the
batched cache tree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False
    t_enqueued: float = 0.0
    t_done: float = 0.0


@dataclass
class EngineStats:
    steps: int = 0
    slot_occupancy: list = field(default_factory=list)
    finished: int = 0

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.slot_occupancy)) if self.slot_occupancy \
            else 0.0


class ContinuousBatcher:
    """model: factory Model; capacity: per-slot KV capacity (max prompt +
    max_new must fit)."""

    def __init__(self, model, params, n_slots: int, capacity: int,
                 kv_dtype: str = "bfloat16", eos_token: int | None = None):
        self.model = model
        self.params = params
        self.n = n_slots
        self.cap = capacity
        self.eos = eos_token
        self.queue: list[GenRequest] = []
        self.slots: list[Optional[GenRequest]] = [None] * n_slots
        self.cache = model.init_cache(n_slots, capacity, kv_dtype)
        self.last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.active = np.zeros(n_slots, bool)
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, c, b: model.decode(p, c, b))

    def submit(self, req: GenRequest):
        self.queue.append(req)

    # ---- slot management -------------------------------------------------
    def _prefill_into_slot(self, slot: int, req: GenRequest):
        """Run a single-sequence prefill and splice its cache into the
        batched cache at ``slot``."""
        batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
        logits, cache1 = self.model.prefill(self.params, batch)

        # splice the single-row prefill cache into the batched cache:
        # (L, 1, T1, ...) leaves pad their seq dim to capacity and land in
        # batch row `slot`; the scalar pos lands at index `slot`.
        def splice_leaf(big, small):
            if small.ndim == big.ndim and small.shape[0] == big.shape[0] \
                    and big.ndim >= 3:
                # (L, 1, T1, ...) -> write into (L, n, T, ...)
                if small.shape[1] == 1:
                    if small.shape[2] < big.shape[2]:
                        pad = [(0, 0)] * small.ndim
                        pad[2] = (0, big.shape[2] - small.shape[2])
                        small = jnp.pad(small, pad)
                    return big.at[:, slot].set(small[:, 0].astype(big.dtype))
            if small.ndim == 1 and big.ndim == 1:      # pos (B,)
                return big.at[slot].set(small[0])
            return big

        self.cache = jax.tree.map(splice_leaf, self.cache, cache1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        self.last_tok = self.last_tok.at[slot, 0].set(tok[0])
        self.slots[slot] = req
        self.active[slot] = True

    def _refill(self):
        for s in range(self.n):
            if not self.active[s] and self.queue:
                self._prefill_into_slot(s, self.queue.pop(0))

    # ---- main loop --------------------------------------------------------
    def step(self):
        """One decode step for all active slots."""
        self._refill()
        if not self.active.any():
            return False
        self.stats.slot_occupancy.append(self.active.mean())
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": self.last_tok})
        nxt = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        for s in range(self.n):
            req = self.slots[s]
            if req is None:
                continue
            tok = int(self.last_tok[s, 0])
            req.out.append(tok)
            finished = len(req.out) >= req.max_new or \
                (self.eos is not None and tok == self.eos) or \
                int(self.cache["pos"][s]) >= self.cap
            if finished:
                req.done = True
                self.slots[s] = None
                self.active[s] = False
                self.stats.finished += 1
        self.last_tok = jnp.asarray(nxt)[:, None]
        self.stats.steps += 1
        return True

    def run_to_completion(self, max_steps: int = 10_000):
        while (self.queue or self.active.any()) and \
                self.stats.steps < max_steps:
            if not self.step():
                break
        return self.stats
