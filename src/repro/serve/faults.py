"""Fault injection and typed non-label results for the serving layer
(DESIGN.md §12.4).

Production devices slow down, die, and throw transient compute errors;
the service must convert every one of those into a typed, bounded
outcome instead of a hang. This module holds

* the **typed non-label results** a request can carry instead of a 0/1
  label: ``Shed`` (admission control rejected it — queue full or
  dispatch permanently failed) and ``TimedOut`` (its deadline expired
  in-queue, or its batch exceeded the per-batch timeout with no healthy
  device left). Both are falsy and compare by (kind, reason), so caller
  code can branch on ``isinstance``/truthiness without magic ints;
* an injectable **fault plan** (``FaultPlan`` + ``FaultInjector``)
  exercised by the service's dispatch path: per-device dispatch
  failures, transient compute errors, device slowdowns (labels not
  ready until a virtual delay passes), and dead devices (labels NEVER
  ready — any accidental blocking read raises instead of hanging).

Everything is clock-injected: with a ``ManualClock`` a "slow" device is
one whose wrapped labels report ``is_ready() == False`` until virtual
time passes ``dispatch + delay`` — no wall-clock sleeps anywhere in the
tests (DESIGN.md §10.2 discipline carried to the fault model).
"""
from __future__ import annotations

from dataclasses import dataclass, field


# ------------------------------------------------- typed non-label results --
@dataclass(frozen=True)
class Shed:
    """Admission control rejected the request (queue full, or dispatch
    exhausted every healthy device). The request was NOT evaluated."""
    reason: str = "queue-full"

    def __bool__(self) -> bool:
        return False


@dataclass(frozen=True)
class TimedOut:
    """The request's deadline expired (in-queue) or its batch exceeded
    the per-batch timeout with retries exhausted. NOT evaluated."""
    reason: str = "deadline"

    def __bool__(self) -> bool:
        return False


def is_label(result) -> bool:
    """True when ``result`` is an actual 0/1 cascade label (goodput),
    False for None/Shed/TimedOut."""
    return result is not None and not isinstance(result, (Shed, TimedOut))


# ----------------------------------------------------------- fault errors --
class DeviceError(RuntimeError):
    """A device failed at dispatch (injected: ``FaultPlan.fail_dispatch``
    / ``dead_devices``). The service re-routes to a healthy device."""


class TransientComputeError(RuntimeError):
    """A one-off compute error (injected: ``FaultPlan.transient_errors``).
    Retrying — same device or another — succeeds once the budget drains."""


# ------------------------------------------------------------ label proxies --
class _SlowLabels:
    """Device-slowdown proxy: wraps a real label array but reports
    not-ready until virtual ``ready_at``; forcing it early is allowed
    (the values are exact — slowness changes WHEN, never WHAT)."""

    def __init__(self, labels, ready_at: float, clock):
        self._labels = labels
        self._ready_at = ready_at
        self._clock = clock

    def is_ready(self) -> bool:
        if self._clock() < self._ready_at:
            return False
        return not hasattr(self._labels, "is_ready") \
            or self._labels.is_ready()

    def __array__(self, dtype=None):
        import numpy as np
        a = np.asarray(self._labels)
        return a if dtype is None else a.astype(dtype)


class NeverReadyLabels:
    """Dead-device proxy: ``is_ready()`` is False forever and any
    blocking read RAISES — a hang converted into a loud failure. The
    per-batch timeout path must fire before anyone forces this."""

    def is_ready(self) -> bool:
        return False

    def __array__(self, dtype=None):
        raise DeviceError("dead device: labels will never be ready")


# -------------------------------------------------------------- fault plan --
@dataclass
class FaultPlan:
    """Declarative fault schedule, keyed by device INDEX (the service's
    unique-device ordering). All counters are consumed as faults fire,
    so a plan naturally describes transient outages.

    * ``slow_devices``  — device -> extra virtual seconds before a
      dispatched batch's labels become ready;
    * ``fail_dispatch`` — device -> how many dispatches raise
      ``DeviceError`` (``-1`` = permanently failing);
    * ``dead_devices``  — devices whose dispatches "succeed" but whose
      labels are never ready (silent stall: only the per-batch timeout
      can detect it);
    * ``transient_errors`` — first N dispatches ANYWHERE raise
      ``TransientComputeError`` (retry succeeds once drained)."""
    slow_devices: dict = field(default_factory=dict)
    fail_dispatch: dict = field(default_factory=dict)
    dead_devices: set = field(default_factory=set)
    transient_errors: int = 0


class FaultInjector:
    """Stateful executor of a FaultPlan, called from the service's
    dispatch path. Counts every injected fault for test assertions."""

    def __init__(self, plan: FaultPlan, clock=None):
        import time
        self.plan = plan
        self.clock = clock or time.perf_counter
        self.injected = {"dispatch_failures": 0, "transient_errors": 0,
                         "slowdowns": 0, "dead_batches": 0}

    def on_dispatch(self, device_index: int) -> None:
        """Raise the fault (if any) this dispatch is scheduled to hit."""
        if self.plan.transient_errors > 0:
            self.plan.transient_errors -= 1
            self.injected["transient_errors"] += 1
            raise TransientComputeError(
                f"injected transient error (device {device_index})")
        left = self.plan.fail_dispatch.get(device_index, 0)
        if left:
            if left > 0:
                self.plan.fail_dispatch[device_index] = left - 1
            self.injected["dispatch_failures"] += 1
            raise DeviceError(
                f"injected dispatch failure (device {device_index})")

    def wrap_labels(self, labels, device_index: int):
        """Apply post-dispatch faults: dead devices never deliver, slow
        devices deliver late (values exact)."""
        if device_index in self.plan.dead_devices:
            self.injected["dead_batches"] += 1
            return NeverReadyLabels()
        delay = self.plan.slow_devices.get(device_index)
        if delay:
            self.injected["slowdowns"] += 1
            return _SlowLabels(labels, self.clock() + float(delay), self.clock)
        return labels
