"""Speculative decoding — the paper's cascade idea applied to generation
(DESIGN.md §5): a cheap DRAFT model proposes gamma tokens; the TRUSTED
model verifies them in one batched forward; the accepted prefix advances
the sequence. With greedy decoding the output is PROVABLY identical to
decoding the trusted model alone (tested), while the trusted model runs
once per ~(accepted+1) tokens instead of once per token — the same
accuracy-preserving early-exit economics as TAHOMA's classifier cascades.

Built on the public Model API (prefill/decode/forward), so any pair of
assigned architectures can be composed (e.g. mamba2-130m drafting for
deepseek-7b).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.factory import Model


@dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    target_calls: int = 0
    draft_calls: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)


def _greedy(logits) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def generate_greedy(model: Model, params, prompt: np.ndarray,
                    n_tokens: int) -> np.ndarray:
    """Reference: plain greedy decode of ``model`` (B=1)."""
    tokens = jnp.asarray(prompt)[None, :]
    out = []
    logits, _, _ = model.forward(params, {"tokens": tokens},
                                 remat_policy="none",
                                 logits_last_only=True)
    tok = _greedy(logits[:, -1])
    for _ in range(n_tokens):
        out.append(int(tok[0]))
        tokens = jnp.concatenate([tokens, tok[:, None]], axis=1)
        logits, _, _ = model.forward(params, {"tokens": tokens},
                                     remat_policy="none",
                                     logits_last_only=True)
        tok = _greedy(logits[:, -1])
    return np.array(out, np.int32)


def generate_speculative(draft: Model, draft_params, target: Model,
                         target_params, prompt: np.ndarray,
                         n_tokens: int, gamma: int = 4
                         ) -> tuple[np.ndarray, SpecStats]:
    """Greedy speculative decoding (B=1, full-forward verification —
    cache-based verification plugs into the same accept logic).
    Returns (generated tokens, stats)."""
    stats = SpecStats()
    seq = list(np.asarray(prompt, np.int32))
    out: list[int] = []
    while len(out) < n_tokens:
        g = min(gamma, n_tokens - len(out))
        # 1. draft proposes g tokens autoregressively
        dseq = list(seq)
        proposals = []
        for _ in range(g):
            logits, _, _ = draft.forward(
                draft_params, {"tokens": jnp.asarray(dseq)[None]},
                remat_policy="none", logits_last_only=True)
            stats.draft_calls += 1
            t = int(_greedy(logits[0, -1][None])[0])
            proposals.append(t)
            dseq.append(t)
        stats.proposed += g
        # 2. ONE target forward over prompt + proposals scores g+1 slots
        full = jnp.asarray(seq + proposals)[None]
        logits, _, _ = target.forward(target_params, {"tokens": full},
                                      remat_policy="none")
        stats.target_calls += 1
        base = len(seq) - 1
        tgt = np.asarray(_greedy(logits[0, base:base + g + 1]))
        # 3. accept the longest prefix where draft == target-greedy
        n_acc = 0
        while n_acc < g and proposals[n_acc] == int(tgt[n_acc]):
            n_acc += 1
        stats.accepted += n_acc
        accepted = proposals[:n_acc] + [int(tgt[n_acc])]
        for t in accepted:
            if len(out) < n_tokens:
                out.append(t)
                seq.append(t)
    return np.array(out, np.int32), stats
