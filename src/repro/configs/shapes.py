"""The four assigned input-shape cells (shared by all 10 architectures).

``decode_32k``/``long_500k`` lower ``decode_step`` (one new token against a
KV/state cache of seq_len), ``prefill_32k`` lowers ``prefill_step``, and
``train_4k`` lowers ``train_step``.
"""
from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig(
    name="train_4k", kind="train", seq_len=4096, global_batch=256,
    microbatch_seqs_per_shard=1, remat_policy="full",
)
PREFILL_32K = ShapeConfig(
    name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32,
    attn_chunk=2048,
)
DECODE_32K = ShapeConfig(
    name="decode_32k", kind="decode", seq_len=32768, global_batch=128,
)
LONG_500K = ShapeConfig(
    name="long_500k", kind="decode", seq_len=524288, global_batch=1,
)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (SSM/hybrid); pure
    full-attention archs skip it (recorded, per DESIGN.md §5)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "SKIPPED: pure full-attention arch; long_500k needs sub-quadratic attention"
    return True, ""
