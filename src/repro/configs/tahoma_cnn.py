"""The paper's own model grid: A (architecture space) and the reduced grids
used for CPU-scale experiments. F (representation space) lives in
core/transforms.py; the model design space is A x F (paper §IV Def. 5/6).
"""
from __future__ import annotations

import itertools

from repro.configs.base import TahomaCNNConfig

# Paper §VII-A2 settings (360 models = 18 archs x 20 representations).
PAPER_CONV_LAYERS = (1, 2, 4)
PAPER_CONV_NODES = (16, 32)
PAPER_DENSE_NODES = (16, 32, 64)
PAPER_RESOLUTIONS = (30, 60, 120, 224)
PAPER_COLOR_REPS = ("rgb", "r", "g", "b", "gray")

# Reduced grid for the 1-core CPU container (structure-preserving subset).
SMALL_CONV_LAYERS = (1, 2)
SMALL_CONV_NODES = (8, 16)
SMALL_DENSE_NODES = (16, 32)
SMALL_RESOLUTIONS = (16, 32, 64)
SMALL_COLOR_REPS = ("rgb", "r", "g", "b", "gray")


def architecture_space(small: bool = True) -> list[TahomaCNNConfig]:
    layers = SMALL_CONV_LAYERS if small else PAPER_CONV_LAYERS
    conv = SMALL_CONV_NODES if small else PAPER_CONV_NODES
    dense = SMALL_DENSE_NODES if small else PAPER_DENSE_NODES
    return [
        TahomaCNNConfig(n_conv_layers=l, conv_nodes=c, dense_nodes=d)
        for l, c, d in itertools.product(layers, conv, dense)
    ]


def representation_space(small: bool = True) -> list[tuple[int, str]]:
    res = SMALL_RESOLUTIONS if small else PAPER_RESOLUTIONS
    col = SMALL_COLOR_REPS if small else PAPER_COLOR_REPS
    return list(itertools.product(res, col))
