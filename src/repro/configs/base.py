"""Config dataclasses for every architecture the framework can lower.

All configs are frozen dataclasses so they can be hashed into jit static
arguments and used as dict keys in the registry.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0          # per shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyperparameters."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The modality frontend
    (mel conv stack) is a STUB: input_specs() feeds precomputed frame
    embeddings of shape (B, n_frames, d_model)."""
    n_layers: int = 4
    n_frames: int = 1500


@dataclass(frozen=True)
class VisionConfig:
    """VLM frontend STUB: input_specs() feeds precomputed patch embeddings
    (B, n_patches, d_model) merged into the token stream; M-RoPE position
    ids are supplied as (3, B, S)."""
    n_patches: int = 256
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # over head_dim/2


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu (gated) | gelu (non-gated)
    norm_eps: float = 1e-5
    max_seq_len: int = 524288
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one SHARED attention+MLP block applied every k SSM
    # blocks (weight re-use across depth).
    hybrid_attn_every: int = 0
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    # long_500k applicability: sub-quadratic sequence mixing available?
    subquadratic: bool = False
    dtype: str = "bfloat16"
    source: str = ""               # provenance tag [arXiv/hf; tier]
    # Tensor-parallel head padding: q/ssm heads are zero-masked-padded up to
    # a multiple of this so the 'model' mesh axis always divides them
    # (numerics preserved via an output head mask; see models/attention.py).
    head_pad_to: int = 1

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived ----
    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    @property
    def conv_dim(self) -> int:
        assert self.ssm is not None
        return self.d_inner + 2 * self.ssm.n_groups * self.ssm.d_state

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    @staticmethod
    def _pad_to(n: int, m: int) -> int:
        return ((n + m - 1) // m) * m

    @property
    def n_heads_padded(self) -> int:
        return self._pad_to(self.n_heads, self.head_pad_to)

    @property
    def ssm_heads_padded(self) -> int:
        return self._pad_to(self.ssm_heads, self.head_pad_to)

    @property
    def d_inner_padded(self) -> int:
        assert self.ssm is not None
        return self.ssm_heads_padded * self.ssm.head_dim

    @property
    def conv_dim_padded(self) -> int:
        assert self.ssm is not None
        return self.d_inner_padded + 2 * self.ssm.n_groups * self.ssm.d_state


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell. kind determines which step fn is lowered:
    train -> train_step, prefill -> prefill_step, decode -> decode_step."""
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int
    # training controls
    microbatch_seqs_per_shard: int = 1   # grad-accum granularity
    remat_policy: str = "full"           # full | dots | none
    train_attn_chunk: int = 0            # >0: chunked (flash) train attention
    grad_accum_dtype: str = "float32"    # fp32 | bfloat16 accumulation
    # serving controls
    kv_dtype: str = "bfloat16"           # physical representation of cache
    attn_chunk: int = 1024               # jnp-flash chunk for long prefill
    params_tp_only: bool = False         # serve: drop ZeRO/FSDP weight axes
    prefill_last_only: bool = False      # prefill: head on last token only


@dataclass(frozen=True)
class TahomaCNNConfig:
    """Paper Fig. 3 family: [conv->relu->maxpool] x L -> dense relu -> sigmoid.

    A (architecture space): n_conv_layers x conv_nodes x dense_nodes.
    F (representation space) lives in core/transforms.py, not here.
    """
    n_conv_layers: int = 2
    conv_nodes: int = 32
    dense_nodes: int = 32
    kernel_size: int = 3
    input_hw: int = 60
    input_channels: int = 3

    @property
    def arch_id(self) -> str:
        return f"cnn_l{self.n_conv_layers}_c{self.conv_nodes}_d{self.dense_nodes}"
