"""mamba2-130m [ssm]: attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=0,                     # no MLP; SSD block only
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    subquadratic=True,
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)
