"""--arch <id> registry over the 10 assigned architectures.

Also provides reduced ("smoke") variants of every arch: same family and
block structure, tiny widths/depths, so one forward/train step runs on CPU.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, EncoderConfig, MLAConfig, MoEConfig, SSMConfig, VisionConfig
from repro.configs import (
    whisper_tiny, mamba2_130m, granite_20b, deepseek_7b, qwen2_5_32b,
    minitron_4b, deepseek_v2_236b, phi3_5_moe, qwen2_vl_72b, zamba2_1_2b,
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        whisper_tiny.CONFIG,
        mamba2_130m.CONFIG,
        granite_20b.CONFIG,
        deepseek_7b.CONFIG,
        qwen2_5_32b.CONFIG,
        minitron_4b.CONFIG,
        deepseek_v2_236b.CONFIG,
        phi3_5_moe.CONFIG,
        qwen2_vl_72b.CONFIG,
        zamba2_1_2b.CONFIG,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown --arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced config of the same family for CPU smoke tests."""
    c = get_arch(name)
    kw: dict = dict(
        n_layers=2, d_model=64, vocab_size=503,  # odd vocab exercises padding
        max_seq_len=256,
    )
    if c.uses_attention:
        kw.update(n_heads=4, n_kv_heads=min(c.n_kv_heads, 2) or 2, head_dim=16,
                  d_ff=128)
    if c.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=4, top_k=2, d_ff_expert=32,
            num_shared_experts=c.moe.num_shared_experts,
            d_ff_shared=32 if c.moe.num_shared_experts else 0)
        kw["d_ff"] = 32
    if c.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
        kw["head_dim"] = 16
    if c.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                              n_groups=1, chunk_size=32)
        if c.family == "ssm":
            kw.pop("n_heads", None)
    if c.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_frames=32)
    if c.vision is not None:
        kw["vision"] = VisionConfig(n_patches=8, mrope_sections=(2, 3, 3))
    if c.hybrid_attn_every:
        kw["n_layers"] = 4
        kw["hybrid_attn_every"] = 2
    return dataclasses.replace(c, **kw)
