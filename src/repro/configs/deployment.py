"""Per-(arch-family x phase) deployment configurations — the §Perf
hillclimb results codified (EXPERIMENTS.md §Roofline-optimized).

``tuned_shape(arch, shape)`` returns the ShapeConfig a production launch
should actually use:

* decode: TP-resident weights (no ZeRO gathers at serve time) + int8 KV
  cache — EXCEPT tiny-model long-context cells, where replicating weights
  across the data axis amplifies weight reads past the cache savings;
* prefill: TP-resident weights + last-token-only LM head;
* train: MoE archs get chunked (flash) attention, dots-remat and 4-seq
  microbatches (targets ZeRO expert-weight regathers); dense/SSM archs
  keep the baseline (their collective floor is per-layer activation
  reductions, which these knobs cannot reduce — measured, not assumed).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig


def tuned_shape(arch: ArchConfig, shape: ShapeConfig) -> ShapeConfig:
    kw: dict = {}
    if shape.kind == "decode":
        small_long = shape.global_batch == 1 and arch.subquadratic
        if not small_long:
            kw.update(params_tp_only=True, kv_dtype="int8")
    elif shape.kind == "prefill":
        kw.update(params_tp_only=True, prefill_last_only=True)
    elif shape.kind == "train" and arch.moe is not None:
        kw.update(train_attn_chunk=1024, remat_policy="dots",
                  microbatch_seqs_per_shard=4)
    return dataclasses.replace(shape, **kw) if kw else shape
