"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution; transformer BACKBONE only
(patch frontend is a STUB: input_specs() provides precomputed patch
embeddings + 3-axis position ids). [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig, VisionConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    vision=VisionConfig(n_patches=256, mrope_sections=(16, 24, 24)),
    source="[arXiv:2409.12191; hf]",
)
