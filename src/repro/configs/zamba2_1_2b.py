"""zamba2-1.2b [hybrid]: Mamba2 backbone + ONE shared attention(+MLP) block
applied every 6 SSM blocks (weight re-use across depth; per-invocation LoRA
omitted — noted in DESIGN.md). [arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,                # mamba2 blocks
    d_model=2048,
    n_heads=32,                 # shared attn block (MHA kv=32)
    n_kv_heads=32,
    d_ff=8192,                  # shared block MLP
    vocab_size=32000,
    head_dim=64,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
    hybrid_attn_every=6,
    subquadratic=True,
    source="[arXiv:2411.15242; hf]",
)
