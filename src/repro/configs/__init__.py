from repro.configs.base import (  # noqa: F401
    ArchConfig, EncoderConfig, MLAConfig, MoEConfig, SSMConfig, ShapeConfig,
    TahomaCNNConfig, VisionConfig,
)
