"""whisper-tiny [audio]: enc-dec, conv frontend stubbed as precomputed
frame embeddings. [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,               # GQA kv=6 (== MHA at this size)
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    qkv_bias=True,              # whisper uses biases on q/v
    tie_embeddings=True,
    norm="layernorm",
    act="gelu",
    rope_theta=0.0,             # whisper uses absolute (sinusoidal) positions
    encoder=EncoderConfig(n_layers=4, n_frames=1500),
    max_seq_len=32768,          # learned decoder positions sized for decode_32k
    source="[arXiv:2212.04356; unverified]",
)
