"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + MoE 2 shared + 160 routed
top-6, expert d_ff=1536. [arXiv:2405.04434; hf]

MLA's latent KV cache (c_kv=512 + k_rope=64 per token instead of
2*128heads*128dim) is itself a *physical-representation* optimization of
the cache — the paper's core idea applied inside the model (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,             # MLA; kv heads notional
    d_ff=1536,                  # per routed expert
    vocab_size=102400,
    head_dim=128,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                  num_shared_experts=2, d_ff_shared=1536),
    source="[arXiv:2405.04434; hf]",
)
