"""Query-time cascade selection (paper Fig. 2 'cascade selector').

Because per-model inference on the eval split is cached, selection —
including re-costing every cascade under the CURRENT deployment scenario —
is cheap enough to run inside query planning (paper §V-E)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.cascade import CascadeSpace, spec_levels
from repro.core.pareto import pareto_indices


@dataclass
class Selection:
    index: int
    accuracy: float
    throughput: float


def pareto_set(space: CascadeSpace) -> np.ndarray:
    return pareto_indices(space.acc, space.throughput)


def select(space: CascadeSpace, *, min_accuracy: float | None = None,
           min_throughput: float | None = None) -> Selection:
    """Pick from the Pareto set: with a min_accuracy constraint return the
    fastest qualifying cascade; with min_throughput the most accurate
    qualifying one; with neither, the most accurate overall. Implemented
    as a pick from ``select_candidates`` (the pool is fastest-first and
    the frontier is strictly ordered, so the ends are exactly those two
    rules) — the joint planner's never-worse guarantee depends on this
    pick being a MEMBER of the candidate pool, which is now true by
    construction."""
    pool = select_candidates(space, min_accuracy=min_accuracy,
                             min_throughput=min_throughput)
    return pool[0] if min_accuracy is not None else pool[-1]


def select_candidates(space: CascadeSpace, *,
                      min_accuracy: float | None = None,
                      min_throughput: float | None = None
                      ) -> list[Selection]:
    """EVERY Pareto-frontier cascade satisfying the clause constraints,
    fastest-first — the joint planner's per-predicate candidate pool
    (engine/planner.plan_query joint=True). ``select`` picks one element
    of this pool (the independent rule); joint selection searches the
    product of pools instead, so the independent pick is always a member
    and the joint plan can never be priced worse."""
    idx = pareto_set(space)
    acc = space.acc[idx]
    thr = space.throughput[idx]
    mask = np.ones(len(idx), bool)
    if min_accuracy is not None:
        mask &= acc >= min_accuracy
    if min_throughput is not None:
        mask &= thr >= min_throughput
    if not mask.any():
        raise ValueError("no cascade satisfies the constraints")
    cand = idx[np.where(mask)[0]]
    cand = cand[np.argsort(space.time_s[cand], kind="stable")]
    return [Selection(int(i), float(space.acc[i]),
                      float(space.throughput[i])) for i in cand]


def degradation_ladder(space: CascadeSpace, primary_index: int, *,
                       min_accuracy: float | None = None,
                       max_rungs: int | None = None) -> list[Selection]:
    """The overload degradation ladder for a selected cascade: every
    Pareto-frontier cascade STRICTLY CHEAPER than the primary, ordered
    nearest-cost-first (gentlest accuracy sacrifice first), optionally
    floored at ``min_accuracy`` and truncated to ``max_rungs``. The
    serving layer (serve/service.py) steps down this list under load
    and back up on recovery — trading accuracy for latency exactly the
    way the paper's frontier is meant to be used. The primary itself is
    never in the ladder; an empty list means the primary is already the
    cheapest qualifying frontier point (nothing to degrade to)."""
    idx = pareto_set(space)
    t0 = float(space.time_s[primary_index])
    rungs = [int(i) for i in idx
             if float(space.time_s[i]) < t0 and int(i) != int(primary_index)]
    if min_accuracy is not None:
        rungs = [i for i in rungs if space.acc[i] >= min_accuracy]
    rungs.sort(key=lambda i: -float(space.time_s[i]))
    if max_rungs is not None:
        rungs = rungs[:max_rungs]
    return [Selection(i, float(space.acc[i]), float(space.throughput[i]))
            for i in rungs]


# --------------------------------------------- planner-facing estimates ----
def cascade_eval_labels(space: CascadeSpace, i: int, scores_eval,
                        p_low, p_high) -> np.ndarray:
    """Labels cascade ``i`` would emit on the eval split, simulated from
    the cached score matrix (paper §V-D: no inference needed). Vectorized
    per-level walk with the exact Def. 7 semantics."""
    levels = spec_levels(space, i, p_low, p_high)
    s = np.asarray(scores_eval)
    n = s.shape[1]
    labels = np.zeros(n, np.int32)
    active = np.ones(n, bool)
    for m, lo, hi in levels:
        o = s[m]
        if lo is None:
            labels[active] = (o >= 0.5)[active]
            active[:] = False
            break
        dec = active & ((o <= lo) | (o >= hi))
        labels[dec] = (o >= hi)[dec]
        active &= ~dec
    return labels


def estimate_selectivity(space: CascadeSpace, i: int, scores_eval,
                         p_low, p_high) -> float:
    """Estimated P(predicate true) = positive fraction the cascade labels
    on the eval split — the statistic the query planner orders binary
    predicates by (selectivity x per-row cost)."""
    return float(cascade_eval_labels(space, i, scores_eval,
                                     p_low, p_high).mean())
