"""The paper's technique as a first-class feature for the assigned LM
architectures (DESIGN.md §5): a *predicate cascade over language models*.

A contains-concept predicate over text/media is scored by asking a model
to choose between a YES token and a NO token; P(yes) is the probabilistic
output of Def. 7. A cheap model (small arch, truncated context — the
token-domain analogue of the paper's resolution scaling) answers first;
inputs whose score falls inside (p_low, p_high) fall through to the
trusted model. Thresholds are calibrated per model with the SAME
Algorithm 1 used for the CNN cascades — the core library is
classifier-agnostic, exactly as the paper claims (§VIII).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.thresholds import compute_thresholds
from repro.models.factory import Model


@dataclass
class LMLevel:
    model: Model
    params: object
    yes_token: int
    no_token: int
    max_context: int | None = None   # truncation = representation knob
    p_low: float | None = None
    p_high: float | None = None


def lm_predicate_score(level: LMLevel, tokens: np.ndarray) -> np.ndarray:
    """tokens (B, S) -> P(yes) (B,). Uses the last-position logits."""
    t = tokens
    if level.max_context is not None and t.shape[1] > level.max_context:
        t = t[:, -level.max_context:]
    logits, _, _ = level.model.forward(
        level.params, {"tokens": jnp.asarray(t)}, remat_policy="none",
        logits_last_only=True)
    pair = logits[:, -1, jnp.asarray([level.yes_token, level.no_token])]
    return np.asarray(jax.nn.softmax(pair.astype(jnp.float32), -1)[:, 0])


def calibrate(levels: Sequence[LMLevel], tokens, truth,
              prec_target: float = 0.95) -> None:
    """Algorithm 1 per level (final level keeps None thresholds)."""
    for lvl in levels[:-1]:
        scores = lm_predicate_score(lvl, tokens)
        lvl.p_low, lvl.p_high = compute_thresholds(
            lambda _: scores, None, truth, prec_target)


def run_lm_cascade(levels: Sequence[LMLevel], tokens) -> tuple:
    """-> (labels (B,), level_used (B,)). Per-batch early exit with the
    same semantics as the CNN cascades."""
    b = tokens.shape[0]
    labels = np.zeros(b, np.int32)
    used = np.full(b, len(levels) - 1, np.int32)
    active = np.ones(b, bool)
    for li, lvl in enumerate(levels):
        if not active.any():
            break
        scores = lm_predicate_score(lvl, tokens)
        final = lvl.p_low is None
        if final:
            labels[active] = (scores >= 0.5)[active]
            used[active] = li
            active[:] = False
        else:
            certain = active & ((scores <= lvl.p_low)
                                | (scores >= lvl.p_high))
            labels[certain] = (scores >= lvl.p_high)[certain]
            used[certain] = li
            active &= ~certain
    return labels, used


def expected_cost(levels: Sequence[LMLevel], level_used,
                  infer_s: Sequence[float]) -> float:
    """Mean seconds/query given per-level inference costs: every input
    pays levels 0..used (the cascade cost model of §VI, inference-only)."""
    per = np.cumsum(np.asarray(infer_s))
    return float(per[np.asarray(level_used)].mean())
