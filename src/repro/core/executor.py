"""Online batched cascade execution — the TPU-native adaptation of the
paper's per-image early-exit control flow (DESIGN.md §3).

TPUs want static shapes, so instead of branching per image we run
two-phase batch compaction per level:
  1. classify the full (sub-)batch with level l;
  2. argsort the uncertainty mask, gather the uncertain prefix into a
     FIXED-CAPACITY sub-batch, run level l+1 on it, scatter results back.
Capacity per level is a knob calibrated offline (e.g. the p99 uncertain
fraction measured on I_config); overflow items keep level-l's forced
decision (o >= 0.5) and are counted in the returned stats.

Representation derivation (DESIGN.md §3): when levels are given as
``Representation``s instead of opaque transform callables, each level's
input is derived from the nearest already-materialized pyramid level
rather than by re-gathering and re-transforming the raw base images. The
executor maintains a full-batch RGB pyramid cache: running a level
materializes its resolution (pooled from the smallest cached level that
divides it — box filters nest, so derived inputs are exactly what
apply_transform would produce from raw), and later levels gather rows
from that level's (much smaller) tensor. For a 224px base with 56/28px
levels that is a 16-64x cut in gathered bytes, and the bytes read per
level are exactly what core/cascade's pyramid cost matrices price
(``derivation_sources``).

Everything here is jit-compatible; model_fns[l] maps the level's input
representation tensor (already transformed) to probabilistic scores.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.transforms import (Representation, color_transform,
                                   materialize_pyramid, resize_area)


def derivation_sources(res_seq: list[int], base: int) -> list[int]:
    """Source resolution each level's representation derives from: the
    smallest already-materialized pyramid level it divides (base is always
    materialized; running a level materializes its resolution). EXACTLY
    the policy core/cascade._cost_matrices prices — the executor and the
    cost model agree on bytes read per level."""
    out = []
    materialized = {base}
    for r in res_seq:
        usable = [m for m in materialized if m % r == 0]
        out.append(min(usable) if usable else base)
        materialized.add(r)
    return out


def run_cascade_on_pyramid(pyramid, model_fns: Sequence[Callable],
                           thresholds, reps: Sequence[Representation],
                           capacities: Sequence[int], level0_scores=None):
    """Run a cascade whose level inputs all derive from a CALLER-PROVIDED
    RGB pyramid cache ``{resolution: (B, r, r, 3) tensor}`` — the entry
    point the scan engine (engine/scan.py) uses so ONE materialized
    pyramid per corpus chunk serves every selected cascade. Missing
    levels are pooled on the fly from the nearest (smallest) cached level
    whose resolution they divide, exactly the derivation_sources policy,
    and cached back into a local copy (the caller's dict is not mutated).
    ``level0_scores``: precomputed level-0 probabilities (B,) — the fused
    Pallas pyramid+stage-0 kernel's epilogue output; when given, level 0's
    model is not invoked (its input derivation is skipped entirely).
    Returns (labels (B,), stats) like run_cascade_batch."""
    pyr_cache = dict(pyramid)
    base = max(pyr_cache)
    res_seq = [r.resolution for r in reps]

    def _pyramid_level(res: int):
        if res not in pyr_cache:
            usable = [m for m in pyr_cache if m % res == 0]
            src = min(usable) if usable else base
            pyr_cache[res] = resize_area(pyr_cache[src], res)
        return pyr_cache[res]

    def get_input(l: int, take):
        level = _pyramid_level(res_seq[l])
        # gather the (small) already-derived rows, not raw images
        sub = level if take is None else jnp.take(level, take, axis=0)
        return color_transform(sub, reps[l].color)

    b = next(iter(pyr_cache.values())).shape[0]
    return _cascade_loop(b, get_input, model_fns, thresholds, capacities,
                         level0_scores=level0_scores)


def run_cascade_batch(images, model_fns: Sequence[Callable],
                      thresholds: Sequence[tuple[float | None,
                                                 float | None]],
                      transforms, capacities: Sequence[int],
                      pyramid_cache=None):
    """images: raw batch (B, H, W, 3). Returns (labels (B,), stats).
    thresholds[l] = (p_low, p_high); final level may be (None, None).
    transforms: per-level transform callables, or per-level
    ``Representation``s (enables pyramid source derivation — see module
    docstring). capacities[l]: static sub-batch size for level l >= 1.
    pyramid_cache: optional pre-materialized {resolution: tensor} levels
    (merged with the raw base) for the Representation path — lets callers
    share one pyramid across several cascades."""
    pyramid = (len(transforms) > 0
               and isinstance(transforms[0], Representation))
    if pyramid:
        # full-batch RGB pyramid cache: each level's resolution is pooled
        # from the nearest (smallest) materialized level, then cached for
        # later levels — total extra memory is a geometric tail of the
        # base batch, and bytes read per level match the cost model's
        # derivation_sources policy
        pyr = {images.shape[1]: images}
        if pyramid_cache:
            pyr.update(pyramid_cache)
        return run_cascade_on_pyramid(pyr, model_fns, thresholds,
                                      list(transforms), capacities)

    def get_input(l: int, take):
        sub = images if take is None else jnp.take(images, take, axis=0)
        return transforms[l](sub)

    return _cascade_loop(images.shape[0], get_input, model_fns,
                         thresholds, capacities)


def _cascade_loop(b: int, get_input, model_fns, thresholds, capacities,
                  level0_scores=None):
    """Two-phase compaction loop shared by both input paths.
    get_input(l, take): level-l input representation for the full batch
    (take=None) or the gathered rows ``take``. level0_scores: optional
    precomputed level-0 probabilities (B,) — skips the level-0 model
    invocation (the fused-kernel ingest path)."""
    labels = jnp.zeros((b,), jnp.int32)
    decided = jnp.zeros((b,), bool)
    overflow = jnp.zeros((), jnp.int32)
    levels_used = jnp.zeros((len(model_fns),), jnp.int32)

    # level 0 on the full batch
    if level0_scores is None:
        o = model_fns[0](get_input(0, None))
    else:
        o = level0_scores
    lo, hi = thresholds[0]
    if lo is None:
        return (o >= 0.5).astype(jnp.int32), {
            "overflow": overflow,
            "levels_used": levels_used.at[0].set(b)}
    certain = (o <= lo) | (o >= hi)
    labels = jnp.where(o >= hi, 1, 0)
    forced = (o >= 0.5).astype(jnp.int32)   # fallback if never decided
    decided = certain
    levels_used = levels_used.at[0].set(b)

    active_mask = ~decided
    for l in range(1, len(model_fns)):
        cap = int(capacities[l - 1])
        # compact: uncertain items first (stable order)
        order = jnp.argsort(~active_mask, stable=True)
        take = order[:cap]
        valid = active_mask[take]
        overflow = overflow + jnp.sum(active_mask) - jnp.sum(valid)
        o = model_fns[l](get_input(l, take))
        levels_used = levels_used.at[l].set(jnp.sum(valid.astype(jnp.int32)))
        lo, hi = thresholds[l]
        final = lo is None
        if final:
            sub_decided = valid
            sub_labels = (o >= 0.5).astype(jnp.int32)
        else:
            cert = (o <= lo) | (o >= hi)
            sub_decided = valid & cert
            sub_labels = jnp.where(o >= hi, 1, 0)
        labels = labels.at[take].set(
            jnp.where(sub_decided, sub_labels, labels[take]))
        decided = decided.at[take].set(decided[take] | sub_decided)
        active_mask = active_mask.at[take].set(
            active_mask[take] & ~sub_decided)
        if final:
            break
    labels = jnp.where(decided, labels, forced)
    return labels, {"overflow": overflow, "levels_used": levels_used}


def calibrate_capacity(uncertain_fraction: float, batch: int,
                       quantile_margin: float = 1.3) -> int:
    """Capacity knob: expected uncertain count x a margin, clamped."""
    return int(min(batch, max(8, round(batch * uncertain_fraction
                                       * quantile_margin))))


# ------------------------------------------------- fused chunk ingest --
# The per-chunk hot path shared by the serial scan engine, the sharded
# lockstep ingest runner, and the serving flush assembly (DESIGN.md §13):
# ONE program per chunk does pyramid materialization + the full stage-0
# cascade + carried-level emission, instead of separate XLA dispatches
# with host round-trips between them. On TPU with real CNN params the
# pyramid + level-0 model run as ONE Pallas pass (kernels/image_transform
# .fused_pyramid_stage0, one HBM read of the base); elsewhere the same
# composition runs unfused inside one jit — bit-exact, since every stage
# is the identical jnp program.


@dataclass(frozen=True)
class Stage0:
    """The first cascade stage's model, in kernel-foldable form: the raw
    CNN parameter pytree + its input representation (CompiledCascade's
    model_fns are opaque closures — the Pallas epilogue needs the actual
    weights). ``qparams`` (models/cnn.quantize_cnn) enables the int8
    weight path."""
    params: Any
    rep: Representation
    qparams: Any = None


def make_fused_ingest(model_fns: Sequence[Callable], thresholds,
                      reps: Sequence[Representation],
                      capacities: Sequence[int], out_res,
                      *, stage0: Stage0 | None = None,
                      materialize: Callable | None = None,
                      use_kernel: bool | None = None, int8: bool = False,
                      jit: bool = True, emit_scores: bool = False):
    """Build the fused per-chunk ingest: fn(imgs (B,H,H,3)) ->
    (labels (B,), {res: (B,res,res,3) raw pooled level for res in
    out_res}).

    Runs the FULL stage-0 cascade (all its levels, full width — the
    engine's dense_levels execution) and emits the ``out_res`` pyramid
    levels the scan engine carries forward for later stages, in one
    program. ``materialize(imgs, resolutions) -> {res: level}`` overrides
    pyramid materialization on the unfused path (the scan engine injects
    its module-global so tests can count calls); default is
    core.transforms.materialize_pyramid. ``use_kernel=None`` resolves to
    True on TPU when ``stage0`` carries real CNN params. ``int8`` swaps
    stage-0's weights for the int8-quantized copy (dequantize-at-use;
    requires ``stage0.qparams``). ``emit_scores=True`` additionally
    returns the raw level-0 probability scores (B,) as a third output —
    on the kernel path they are the Pallas epilogue's ``s0`` for free;
    on the unfused path level 0 is scored explicitly and fed back via
    ``level0_scores`` so the composed program stays bit-identical. The
    ingest-time indexing pipeline (engine/ingest.py) consumes the
    scores for confident stage-0 decisions and candidate ranking."""
    out_res = [int(r) for r in out_res]
    need = sorted({r.resolution for r in reps} | set(out_res))
    if use_kernel is None:
        use_kernel = (stage0 is not None
                      and jax.default_backend() == "tpu")
    if use_kernel and stage0 is None:
        raise ValueError("use_kernel requires stage0 params")
    if int8 and (stage0 is None or stage0.qparams is None):
        raise ValueError("int8 requires stage0.qparams")
    mat = materialize if materialize is not None else materialize_pyramid

    model_fns = list(model_fns)
    if int8 and not use_kernel:
        # unfused int8: dequantize once at build, identical arithmetic
        # to the kernel's dequantize-at-use epilogue
        from repro.models.cnn import cnn_predict_proba, dequantize_cnn
        model_fns[0] = partial(cnn_predict_proba,
                               dequantize_cnn(stage0.qparams))

    if use_kernel:
        from repro.kernels.image_transform import fused_pyramid_stage0
        qp = stage0.qparams if int8 else None

        def run(imgs):
            base = imgs.shape[1]
            levels, s0 = fused_pyramid_stage0(
                imgs, [r for r in need if r != base],
                stage0.params, stage0.rep, qparams=qp)
            pyr = {base: imgs, **levels}
            labels, _ = run_cascade_on_pyramid(
                pyr, model_fns, thresholds, reps, capacities,
                level0_scores=s0)
            emitted = {r: pyr[r] for r in out_res}
            if emit_scores:
                return labels, emitted, s0
            return labels, emitted
    else:
        def run(imgs):
            base = imgs.shape[1]
            pyr = dict(mat(imgs, [r for r in need if r != base]))
            pyr.setdefault(base, imgs)
            s0 = None
            if emit_scores:
                # score level 0 explicitly (same input derivation as
                # run_cascade_on_pyramid's get_input) and feed it back
                # as level0_scores — the composition is the identical
                # jnp program, so labels stay bit-exact
                s0 = model_fns[0](color_transform(
                    pyr[reps[0].resolution], reps[0].color))
            labels, _ = run_cascade_on_pyramid(
                pyr, model_fns, thresholds, reps, capacities,
                level0_scores=s0)
            emitted = {r: pyr[r] for r in out_res}
            if emit_scores:
                return labels, emitted, s0
            return labels, emitted

    return jax.jit(run) if jit else run
