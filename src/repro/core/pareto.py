"""Pareto frontier (skyline) over (accuracy, throughput) — paper §V-E.

O(n log n) Kung/Luccio/Preparata sweep for two maximization criteria:
sort by accuracy descending (throughput descending tie-break) and keep
points whose throughput strictly exceeds the best seen so far; a point
dominates another iff >= on both attributes and > on at least one.
"""
from __future__ import annotations

import numpy as np


def pareto_indices(acc, thr) -> np.ndarray:
    """Indices of the non-dominated points, sorted by accuracy desc."""
    acc = np.asarray(acc, np.float64)
    thr = np.asarray(thr, np.float64)
    order = np.lexsort((-thr, -acc))        # acc desc, thr desc
    keep = []
    best_thr = -np.inf
    prev_acc = None
    for i in order:
        if thr[i] > best_thr:
            # equal-accuracy group: only the first (max-thr) survives, and
            # equal (acc,thr) duplicates collapse to one representative.
            if prev_acc is not None and acc[i] == prev_acc and keep and \
                    thr[keep[-1]] >= thr[i]:
                continue
            keep.append(i)
            best_thr = thr[i]
        prev_acc = acc[i]
    return np.asarray(keep, np.int64)


def dominates(a, b) -> bool:
    """a, b = (accuracy, throughput)."""
    return a[0] >= b[0] and a[1] >= b[1] and (a[0] > b[0] or a[1] > b[1])


def is_frontier(acc, thr, idx) -> bool:
    pts = list(zip(np.asarray(acc), np.asarray(thr)))
    p = pts[idx]
    return not any(dominates(q, p) for j, q in enumerate(pts) if j != idx)
