"""Decision thresholds (paper §V-C, Algorithm 1).

``compute_thresholds`` is a line-faithful port of Algorithm 1 including its
quirks (e.g. p_high records ``curThresh`` — the PREVIOUS step — while p_low
records ``currentThresh``; precision uses strict '>' for the positive side
and '>=' for the negative side, exactly as printed).

``compute_thresholds_batch`` vectorizes the sweep over many models at once
(numpy), producing identical results — property-tested against the port.

Semantics: output o >= p_high => accept positive; o <= p_low => accept
negative; otherwise the model is "uncertain" and the cascade falls through
to the next level. Thresholds are chosen per model to maximize recall
subject to precision >= precTarget on the config split (paper: validation
set), independently of any cascade (§V-D).
"""
from __future__ import annotations

import numpy as np

DEFAULT_STEP = 0.05
PRECISION_TARGETS = (0.91, 0.93, 0.95, 0.97, 0.99)


def _precision_recall(labels, truth, thresh, positive: bool):
    """Precision/recall of the 'certain' decision at ``thresh``.
    positive: predictions are o >= thresh claiming label 1;
    negative: predictions are o <= thresh claiming label 0."""
    labels = np.asarray(labels, np.float64)
    truth = np.asarray(truth)
    if positive:
        pred = labels >= thresh
        tp = float(np.sum(pred & (truth == 1)))
        denom_rec = float(np.sum(truth == 1))
    else:
        pred = labels <= thresh
        tp = float(np.sum(pred & (truth == 0)))
        denom_rec = float(np.sum(truth == 0))
    npred = float(np.sum(pred))
    prec = tp / npred if npred else 0.0
    rec = tp / denom_rec if denom_rec else 0.0
    return prec, rec


def compute_thresholds(model_predict, images, truth, prec_target: float,
                       step: float = DEFAULT_STEP):
    """Algorithm 1, line-faithful. model_predict(images) -> scores [0,1].
    Returns (p_low, p_high)."""
    num_steps = int(round(1.0 / step))
    cur_thresh = 0.0
    max_recall_pos = 0.0
    max_recall_neg = 0.0
    p_low, p_high = 0.0, 1.0
    labels = np.asarray(model_predict(images))
    for _ in range(1, num_steps + 1):
        current_thresh = cur_thresh + step
        if current_thresh > 0.5:
            prec_pos, recall_pos = _precision_recall(labels, truth,
                                                     cur_thresh, True)
            if prec_pos > prec_target and recall_pos > max_recall_pos:
                max_recall_pos = recall_pos
                p_high = cur_thresh          # NOTE: previous step (as printed)
        else:
            prec_neg, recall_neg = _precision_recall(labels, truth,
                                                     current_thresh, False)
            if prec_neg >= prec_target and recall_neg > max_recall_neg:
                max_recall_neg = recall_neg
                p_low = current_thresh
        cur_thresh = current_thresh
    return p_low, p_high


def compute_thresholds_batch(scores, truth, prec_targets,
                             step: float = DEFAULT_STEP):
    """Vectorized Algorithm 1 over (n_models, n_images) scores and multiple
    precision targets. Returns p_low/p_high arrays (n_models, n_targets).
    Matches ``compute_thresholds`` exactly (tests/test_thresholds.py)."""
    scores = np.asarray(scores, np.float64)
    truth = np.asarray(truth)
    n_models = scores.shape[0]
    num_steps = int(round(1.0 / step))
    # replicate the faithful port's float accumulation exactly
    grid = np.cumsum(np.full(num_steps, step))
    prev = np.concatenate(([0.0], grid[:-1]))
    pos_mask = grid > 0.5
    # positive sweep evaluates at the PREVIOUS thresh; negative at current
    pos_ts = prev[pos_mask]
    neg_ts = grid[~pos_mask]

    pos1 = truth == 1
    n_pos = max(pos1.sum(), 1)
    n_neg = max((~pos1).sum(), 1)

    def stats(ts, positive):
        # (n_models, n_ts) precision/recall
        if positive:
            pred = scores[:, None, :] >= ts[None, :, None]
            tp = (pred & pos1[None, None, :]).sum(-1).astype(np.float64)
            rec = tp / n_pos
        else:
            pred = scores[:, None, :] <= ts[None, :, None]
            tp = (pred & (~pos1)[None, None, :]).sum(-1).astype(np.float64)
            rec = tp / n_neg
        npred = pred.sum(-1)
        prec = np.divide(tp, npred, out=np.zeros_like(tp),
                         where=npred > 0)
        return prec, rec

    prec_p, rec_p = stats(pos_ts, True)
    prec_n, rec_n = stats(neg_ts, False)

    targets = np.asarray(prec_targets, np.float64)
    p_low = np.zeros((n_models, len(targets)))
    p_high = np.ones((n_models, len(targets)))
    for j, tgt in enumerate(targets):
        ok_p = prec_p > tgt
        ok_n = prec_n >= tgt
        rp = np.where(ok_p, rec_p, -1.0)
        rn = np.where(ok_n, rec_n, -1.0)
        # argmax keeps the FIRST maximum — matches the sequential
        # strictly-greater update in Algorithm 1.
        bi = rp.argmax(1)
        bj = rn.argmax(1)
        has_p = rp.max(1) > 0.0
        has_n = rn.max(1) > 0.0
        p_high[:, j] = np.where(has_p, pos_ts[bi], 1.0)
        p_low[:, j] = np.where(has_n, neg_ts[bj], 0.0)
    return p_low, p_high
