"""TAHOMA system initialization (paper Fig. 2): model trainer -> cost
profiler -> cascade builder -> cascade evaluator, per binary predicate.

Scaled to this container: base resolution and grid sizes come from the
caller (benchmarks use the reduced grid in configs/tahoma_cnn.py); the
structure (A x F model grid, three data splits, 5 precision targets,
per-scenario cost profiles, Pareto selection) is the paper's.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TahomaCNNConfig
from repro.core import thresholds as thr_mod
from repro.core.cascade import (CascadeSpace, evaluate_cascades,
                                evaluate_cascades_streaming)
from repro.core.costs import CostProfile
from repro.core.transforms import (Representation, apply_transform,
                                   materialize_representations)
from repro.models.cnn import bce_loss, cnn_predict_proba, init_cnn
from repro.train.optimizer import adamw


@dataclass
class ModelEntry:
    name: str
    arch: TahomaCNNConfig
    rep: Representation
    params: object
    trusted: bool = False

    def predict(self, raw_images) -> np.ndarray:
        x = apply_transform(jnp.asarray(raw_images), self.rep)
        return np.asarray(cnn_predict_proba(self.params, x))


@dataclass
class ModelBank:
    entries: list[ModelEntry] = field(default_factory=list)

    @property
    def names(self):
        return [e.name for e in self.entries]

    @property
    def reps(self):
        return [e.rep for e in self.entries]

    @property
    def trusted_index(self) -> int:
        return next(i for i, e in enumerate(self.entries) if e.trusted)

    def score_matrix(self, raw_images) -> np.ndarray:
        """(M, I): inference once per model (paper §V-D) — cached scores
        power every downstream cascade simulation. All representations
        the bank needs are materialized in ONE progressive pyramid pass
        (core/transforms.materialize_representations) instead of each
        model re-transforming from the raw base images."""
        rep_cache = materialize_representations(
            jnp.asarray(raw_images), [e.rep for e in self.entries])
        return np.stack([
            np.asarray(cnn_predict_proba(e.params, rep_cache[e.rep]))
            for e in self.entries])


# ------------------------------------------------------------- training ----
def train_cnn(arch: TahomaCNNConfig, x, y, *, steps: int = 120,
              batch: int = 16, lr: float = 3e-3, seed: int = 0):
    """Train one specialized classifier (paper: 1-20 min on K80; here a
    few seconds at reduced scale)."""
    params = init_cnn(jax.random.PRNGKey(seed), arch)
    opt = adamw(lr, weight_decay=1e-4)
    state = opt.init(params)
    x = jnp.asarray(x)
    y = jnp.asarray(y, jnp.float32)

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(bce_loss)(params, xb, yb)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    n = x.shape[0]
    rng = np.random.default_rng(seed)
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, state, _ = step(params, state, x[idx], y[idx])
    return params


def train_model_grid(train_x, train_y, archs: Sequence[TahomaCNNConfig],
                     reps: Sequence[Representation], *,
                     trusted_arch: TahomaCNNConfig | None = None,
                     steps: int = 120, seed: int = 0,
                     log: Callable[[str], None] | None = None) -> ModelBank:
    """The A x F grid (paper §V-B) + one trusted heavy model (ResNet50
    stand-in: deepest/widest CNN at full resolution, full color)."""
    bank = ModelBank()
    # one progressive pyramid pass materializes every training input
    rep_cache = {rep: np.asarray(x) for rep, x in
                 materialize_representations(jnp.asarray(train_x),
                                             reps).items()}
    for ai, arch0 in enumerate(archs):
        for rep in reps:
            arch = TahomaCNNConfig(
                n_conv_layers=arch0.n_conv_layers,
                conv_nodes=arch0.conv_nodes, dense_nodes=arch0.dense_nodes,
                input_hw=rep.resolution, input_channels=rep.channels)
            params = train_cnn(arch, rep_cache[rep], train_y, steps=steps,
                               seed=seed + ai)
            bank.entries.append(ModelEntry(
                f"{arch.arch_id}_{rep.name}", arch, rep, params))
            if log:
                log(f"trained {bank.entries[-1].name}")
    base_hw = train_x.shape[1]
    t_arch = trusted_arch or TahomaCNNConfig(
        n_conv_layers=3, conv_nodes=48, dense_nodes=64,
        input_hw=base_hw, input_channels=3)
    t_rep = Representation(base_hw, "rgb")
    t_params = train_cnn(t_arch, train_x, train_y, steps=steps * 3,
                         seed=seed + 999)
    bank.entries.append(ModelEntry(
        f"trusted_{t_arch.arch_id}", t_arch, t_rep, t_params, trusted=True))
    return bank


# -------------------------------------------------------------- profiling --
def profile_infer_costs(bank: ModelBank, sample_raw, *, batch: int = 32,
                        repeats: int = 3) -> dict[str, float]:
    """Measured seconds/image of pure inference (the cost profiler of
    Fig. 2, run in the current deployment)."""
    out = {}
    for e in bank.entries:
        x = apply_transform(jnp.asarray(sample_raw[:batch]), e.rep)
        fn = jax.jit(lambda p, xx: cnn_predict_proba(p, xx))
        fn(e.params, x).block_until_ready()
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(e.params, x).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        out[e.name] = best / batch
    return out


# ---------------------------------------------------------- full pipeline --
@dataclass
class TahomaSystem:
    bank: ModelBank
    p_low: np.ndarray
    p_high: np.ndarray
    infer_s: dict[str, float]
    profile: CostProfile
    eval_scores: np.ndarray
    eval_truth: np.ndarray
    targets: tuple
    space_cache: dict = field(default_factory=dict)
    dec_cache: dict = field(default_factory=dict)

    def cascade_space(self, scenario: str, *, max_level: int = 3,
                      reps_subset=None, streaming: bool = False,
                      **stream_kw) -> CascadeSpace:
        """Re-cost + re-evaluate all cascades under a deployment scenario
        (cheap: pure linear algebra over cached scores — §V-E).
        streaming=True runs the bounded-memory chunked evaluator and
        returns only the surviving (Pareto/top-K) cascades; extra kwargs
        (chunk, keep, top_k, ...) pass through. Plain evaluations (no
        subset/kwargs) are memoized per (scenario, max_level, streaming)
        so repeated query planning reuses the evaluated space."""
        plain = reps_subset is None and not stream_kw
        key = (scenario, max_level, streaming)
        if plain and key in self.space_cache:
            return self.space_cache[key]
        keep = None
        if reps_subset is not None:
            keep = [i for i, e in enumerate(self.bank.entries)
                    if e.rep in reps_subset or e.trusted]
        infer = np.array([self.infer_s[n] for n in self.bank.names])
        evaluate = (evaluate_cascades_streaming if streaming
                    else evaluate_cascades)
        space = evaluate(
            self.eval_scores, self.eval_truth, self.p_low, self.p_high,
            self.bank.reps, infer, self.profile, scenario,
            self.bank.trusted_index, max_level=max_level,
            first_level_models=keep, **stream_kw)
        if plain:
            self.space_cache[key] = space
        return space

    def decomposed_cost(self, space: CascadeSpace, index: int,
                        scenario: str, *, dense_levels: bool = False):
        """Cascade ``index``'s §VI cost split into inference vs
        per-pyramid-level representation handling
        (core/costs.DecomposedCost) — the joint planner's costing unit
        (DESIGN.md §11). ``dense_levels`` prices the scan engine's
        full-width level execution (every level at reach 1) instead of
        the paper's reach-weighted walk. Memoized per (scenario, mode,
        physical cascade): the walk re-simulates the cascade over the
        cached eval scores, and joint planning prices every
        candidate-pool member."""
        from repro.core.cascade import spec_levels
        from repro.core.costs import decompose_cascade_cost

        key = (scenario, bool(dense_levels), int(space.kind[index]),
               int(space.i1[index]), int(space.i2[index]))
        if key not in self.dec_cache:
            infer = np.array([self.infer_s[n] for n in self.bank.names])
            self.dec_cache[key] = decompose_cascade_cost(
                spec_levels(space, index, self.p_low, self.p_high),
                self.eval_scores, self.bank.reps, infer, self.profile,
                scenario, dense_levels=dense_levels)
        return self.dec_cache[key]

    def compiled_ladder(self, space: CascadeSpace, index: int, *,
                        concept: str = "pred",
                        min_accuracy: float | None = None,
                        max_rungs: int | None = None) -> list:
        """The serving degradation ladder for the cascade at ``index``:
        every strictly cheaper Pareto-frontier cascade (optionally
        floored/truncated), compiled to executables with DISTINCT
        cascade ids so their labels land in their own virtual columns
        (core/selector.degradation_ladder; serve/service.py ladders=)."""
        from repro.core.selector import degradation_ladder

        return [self.compiled_cascade(space, sel.index, concept=concept)
                for sel in degradation_ladder(space, index,
                                              min_accuracy=min_accuracy,
                                              max_rungs=max_rungs)]

    def compiled_cascade(self, space: CascadeSpace, index: int, *,
                         concept: str = "pred", capacities=None):
        """Bridge to the query engine (DESIGN.md §4): decode cascade
        ``index`` of an evaluated space into an executable
        engine.scan.CompiledCascade — per-level model closures over this
        bank's trained params, thresholds, representations, plus the
        planner's cost (expected s/row under the space's scenario) and
        selectivity (simulated over the cached eval scores) estimates.
        The level-0 model's raw params also ride along in kernel-
        foldable form (executor.Stage0, with an int8-quantized copy) so
        the scan engines' fused ingest can fold stage 0 into the Pallas
        pyramid kernel on TPU (DESIGN.md §13)."""
        from functools import partial

        from repro.core.cascade import spec_levels
        from repro.core.executor import Stage0
        from repro.core.selector import estimate_selectivity
        from repro.engine.scan import CompiledCascade
        from repro.models.cnn import quantize_cnn

        levels = spec_levels(space, index, self.p_low, self.p_high)
        reps, fns, ths = [], [], []
        for m, lo, hi in levels:
            e = self.bank.entries[m]
            reps.append(e.rep)
            fns.append(partial(cnn_predict_proba, e.params))
            ths.append((None if lo is None else float(lo),
                        None if hi is None else float(hi)))
        sel = estimate_selectivity(space, index, self.eval_scores,
                                   self.p_low, self.p_high)
        cascade_id = (int(space.kind[index]), int(space.i1[index]),
                      int(space.i2[index]))
        e0 = self.bank.entries[levels[0][0]]
        stage0 = Stage0(params=e0.params, rep=e0.rep,
                        qparams=quantize_cnn(e0.params))
        return CompiledCascade(
            concept=concept, cascade_id=cascade_id, reps=reps,
            model_fns=fns, thresholds=ths,
            cost_s=float(space.time_s[index]), selectivity=sel,
            capacities=capacities, stage0=stage0)


def initialize_system(train_split, config_split, eval_split,
                      archs, reps, *, targets=thr_mod.PRECISION_TARGETS,
                      steps: int = 120, seed: int = 0,
                      log=None) -> TahomaSystem:
    (tr_x, tr_y), (cf_x, cf_y), (ev_x, ev_y) = (train_split, config_split,
                                                eval_split)
    bank = train_model_grid(tr_x, tr_y, archs, reps, steps=steps,
                            seed=seed, log=log)
    cfg_scores = bank.score_matrix(cf_x)
    p_low, p_high = thr_mod.compute_thresholds_batch(cfg_scores, cf_y,
                                                     targets)
    infer_s = profile_infer_costs(bank, ev_x)
    profile = CostProfile.modeled(infer_s, list(set(bank.reps)),
                                  base_hw=tr_x.shape[1])
    eval_scores = bank.score_matrix(ev_x)
    return TahomaSystem(bank, p_low, p_high, infer_s, profile,
                        eval_scores, ev_y, tuple(targets))


def build_scan_engine(images, metadata=None, *, shards: int | None = None,
                      chunk: int = 64, jit: bool = True,
                      strategy: str = "range", repcache=None,
                      fused: bool = True, lazy: bool = True,
                      int8: bool = False, use_kernel: bool | None = None):
    """System-level scan-executor factory (the ``--shards N`` path in
    examples/ and benchmarks/): ``shards=None``/0 builds the single-host
    ScanEngine; any explicit shard count (including 1, for scaling-curve
    baselines) builds the sharded engine (DESIGN.md §9). Both share the
    same execute(cascades, metadata_eq) surface and virtual-column
    semantics. ``repcache`` (serial engine only) plugs a cross-query
    representation cache into per-chunk pyramid materialization
    (DESIGN.md §10.3). ``fused``/``lazy``/``int8``/``use_kernel`` are
    the hot-path knobs (DESIGN.md §13): fused single-program chunk
    ingest, lazy first-touch level materialization, int8 stage-0
    weights, and the Pallas pyramid+stage-0 kernel override."""
    from repro.engine.scan import ScanEngine
    from repro.engine.sharded import ShardedScanEngine

    if shards:
        return ShardedScanEngine(images, metadata, shards=int(shards),
                                 chunk=chunk, jit=jit, strategy=strategy,
                                 fused=fused, lazy=lazy, int8=int8,
                                 use_kernel=use_kernel)
    return ScanEngine(images, metadata, chunk=chunk, jit=jit,
                      repcache=repcache, fused=fused, lazy=lazy,
                      int8=int8, use_kernel=use_kernel)


def build_cascade_service(images, cascades, *, mode: str = "async",
                          shards: int | None = None, batch_size: int = 32,
                          max_wait_s: float = 0.005, clock=None,
                          repcache_bytes: int | None = 64 << 20,
                          repcache=None, store=None, jit: bool = True,
                          host: bool = False, **hardening):
    """System-level serving factory (DESIGN.md §10, §12):
    ``mode='async'`` builds the shard-aware AsyncCascadeService
    (deadline scheduler, per-shard device queues, cross-query
    representation cache — a fresh ``repcache_bytes``-budget cache
    unless the caller shares one via ``repcache``, e.g. the same object
    backing a ScanEngine); ``mode='sync'`` builds the legacy
    synchronous-polling CascadeService from the same
    {concept -> CompiledCascade} table. ``store`` shares a scan
    engine's virtual columns with the service so previously scanned
    rows are served with zero model invocations.

    Hardening (async only; DESIGN.md §12): extra keyword args pass
    straight to AsyncCascadeService — ``queue_limit``, ``overload``,
    ``ladders`` (e.g. from ``TahomaSystem.compiled_ladder``),
    ``degrade`` (a DegradeConfig), ``batch_timeout_s``,
    ``request_deadline_s``, ``dispatch_retries``, ``faults``, and the
    ingest-index seeds ``ingest_index``/``ingest_exact`` (DESIGN.md
    §14: a CandidateIndex built by build_ingest_pipeline seeds the
    service store so ingest-decided rows answer at submit with zero
    model invocations).
    ``host=True`` wraps the service in a started wall-clock EventHost
    (serve/host.py) so deadlines fire without caller cooperation; the
    caller gets the HOST (``host.service`` reaches the service) and
    must ``stop()`` it."""
    import time

    from repro.serve.batcher import CascadeService
    from repro.serve.repcache import RepresentationCache
    from repro.serve.service import AsyncCascadeService

    clock = clock or time.perf_counter
    if mode == "sync":
        if hardening or host:
            raise ValueError("hardening knobs require mode='async'")
        return CascadeService.from_cascades(cascades, batch_size,
                                            max_wait_s, clock, jit=jit)
    if mode != "async":
        raise ValueError(f"unknown serving mode {mode!r}")
    if repcache is None and repcache_bytes:
        repcache = RepresentationCache(repcache_bytes)
    service = AsyncCascadeService(images, cascades, shards=shards,
                                  batch_size=batch_size,
                                  max_wait_s=max_wait_s, clock=clock,
                                  repcache=repcache, store=store,
                                  jit=jit, **hardening)
    if host:
        from repro.serve.host import EventHost
        return EventHost(service).start()
    return service


def build_ingest_pipeline(cascades, n_rows: int, *, chunk: int = 64,
                          skip: bool = True,
                          skip_threshold: float | None = 0.008,
                          calib_frames: int = 48,
                          top_k: int | None = None,
                          prune_margin: float = 0.25, jit: bool = True,
                          int8: bool = False,
                          use_kernel: bool | None = None):
    """System-level ingest factory (DESIGN.md §14): a streaming
    IngestPipeline over the planned ``cascades`` (a sequence, or a
    {concept -> CompiledCascade} table as built for serving) for a
    corpus/stream of ``n_rows`` frames. Feed arriving frames with
    ``.ingest(frames, ids)`` (any batch granularity — the temporal skip
    detector chains across calls) or sweep a resident corpus with
    ``.run(images)``; the resulting ``.index`` plugs into
    ``plan_query(..., index=...)`` and ``build_cascade_service(...,
    ingest_index=...)``. The cascades must be the SAME physical
    cascades queries will select — labels are keyed by
    CompiledCascade.key. ``skip_threshold=None`` auto-calibrates the
    temporal-difference threshold per camera from the first
    ``calib_frames`` frames (IngestPipeline.calibrate_threshold)."""
    from repro.engine.ingest import IngestPipeline

    if isinstance(cascades, dict):
        cascades = list(cascades.values())
    return IngestPipeline(cascades, n_rows, chunk=chunk, skip=skip,
                          skip_threshold=skip_threshold,
                          calib_frames=calib_frames, top_k=top_k,
                          prune_margin=prune_margin, jit=jit, int8=int8,
                          use_kernel=use_kernel)
