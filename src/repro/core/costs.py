"""Deployment-scenario-aware data handling costs (paper §III issue 4, §VI).

t_classify = t_load + t_transform + t_infer, with the representation costs
charged ONCE per distinct representation per image (§VII-A3). Scenarios:

  INFER_ONLY - inference only (the computer-vision-literature convention)
  ARCHIVE    - load the full-size image from SSD once + transform into each
               distinct representation the cascade needs
  ONGOING    - representations were materialized at ingest; pay only the
               (smaller) per-representation load
  CAMERA     - frames arrive in memory from the sensor; pay transforms only

The CostProfile holds *measured* per-model/per-representation seconds
(core benchmark path: measured on this host; TPU-projected constants are
also provided for the roofline discussion). All times are seconds/image.

Pyramid pricing (DESIGN.md §3): a follow-up level whose resolution divides
an already-materialized level's resolution is produced from that level, not
from the raw base image — ``transform_from_s`` prices that *incremental*
t_transform. Profiles built by hand (without the modeled bandwidth fields)
degrade gracefully to the seed's from-base pricing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

from repro.core.transforms import Representation

SCENARIOS = ("INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA")

# ``DecomposedCost.rep_s`` key for the ARCHIVE scenario's full-size raw
# image load. It is not a pyramid level, but it shares exactly like one:
# a multi-predicate scan loads each raw image ONCE no matter how many
# cascades read representations derived from it. 0 can never collide
# with a real resolution.
FULL_LOAD = 0

# Deployment-environment constants used when costs are modeled instead of
# measured. Per-image fixed overheads reflect file open + JPEG decode for
# full images and packed-binary reads for pre-materialized representations
# (EXPERIMENTS.md §Paper-claims documents the calibration).
SSD_BW = 2.0e9
CAMERA_DMA_BW = 8.0e9
TRANSFORM_BW = 4.0e9             # host-side resize throughput
LOAD_FULL_OVERHEAD_S = 1.5e-3    # open + decode a full-size image
LOAD_REP_OVERHEAD_S = 30e-6      # read a pre-sized packed representation
TRANSFORM_OVERHEAD_S = 20e-6     # per-op dispatch/copy


@dataclass
class CostProfile:
    """Per-deployment measured/modeled costs.
    infer_s[model_id]        : seconds/image of pure inference
    transform_s[rep.name]    : seconds/image to produce rep from raw
    load_rep_s[rep.name]     : seconds/image to load rep from storage
    load_full_s              : seconds/image to load the full-size raw image

    The optional pyramid fields enable incremental t_transform pricing
    (``transform_from_s``); ``modeled`` fills them in, hand-built profiles
    may leave them None and keep the seed's from-base pricing.
    """
    infer_s: Mapping[str, float]
    transform_s: Mapping[str, float]
    load_rep_s: Mapping[str, float]
    load_full_s: float
    transform_bw: float | None = None        # bytes/s of the resize path
    transform_overhead_s: float = TRANSFORM_OVERHEAD_S
    byte_scale: float = 1.0                  # corpus -> paper-regime bytes
    base_hw: int | None = None

    @staticmethod
    def modeled(model_infer_s: Mapping[str, float],
                reps: list[Representation], base_hw: int,
                scale: float = 1.0) -> "CostProfile":
        """scale: byte-scale multiplier mapping reduced-resolution stand-in
        corpora onto the paper's 224px regime (scale = (224/base_hw)^2)."""
        full_bytes = base_hw * base_hw * 3 * scale
        return CostProfile(
            infer_s=dict(model_infer_s),
            transform_s={r.name: TRANSFORM_OVERHEAD_S
                         + (full_bytes + r.bytes * scale) / TRANSFORM_BW
                         for r in reps},
            load_rep_s={r.name: LOAD_REP_OVERHEAD_S
                        + r.bytes * scale / SSD_BW for r in reps},
            load_full_s=LOAD_FULL_OVERHEAD_S + full_bytes / SSD_BW,
            transform_bw=TRANSFORM_BW,
            transform_overhead_s=TRANSFORM_OVERHEAD_S,
            byte_scale=scale,
            base_hw=base_hw,
        )

    def transform_from_s(self, rep: Representation,
                         source_hw: int | None) -> float:
        """Incremental t_transform: produce ``rep`` from an already
        materialized RGB pyramid level at ``source_hw``. Falls back to the
        from-base price when the profile lacks bandwidth fields, when no
        source is given, or when the source cannot serve this resolution."""
        if (self.transform_bw is None or source_hw is None
                or source_hw % rep.resolution != 0
                or (self.base_hw is not None and source_hw >= self.base_hw)):
            return self.transform_s[rep.name]
        read = source_hw * source_hw * 3 * self.byte_scale
        return self.transform_overhead_s \
            + (read + rep.bytes * self.byte_scale) / self.transform_bw


def rep_cost_s(profile: CostProfile, rep: Representation,
               scenario: str, first_rep: bool,
               source_hw: int | None = None) -> float:
    """Data-handling cost of materializing ``rep`` for one image under
    ``scenario``. first_rep: True when this is the first representation the
    cascade touches (ARCHIVE pays the full-size load exactly once).
    source_hw: resolution of the nearest already-materialized RGB pyramid
    level, when the executor can derive ``rep`` from it (DESIGN.md §3)."""
    if scenario == "INFER_ONLY":
        return 0.0
    if scenario == "ARCHIVE":
        return (profile.load_full_s if first_rep else 0.0) \
            + profile.transform_from_s(rep, source_hw)
    if scenario == "ONGOING":
        return profile.load_rep_s[rep.name]
    if scenario == "CAMERA":
        return profile.transform_from_s(rep, source_hw)
    raise ValueError(scenario)


# ---------------------------------------------- decomposed §VI pricing -----
@dataclass
class DecomposedCost:
    """One cascade's expected §VI seconds/image, split into the two
    physically different spends (DESIGN.md §11):

    ``infer_s``  — expected pure-inference seconds/image (every level's
                   infer_s weighted by its reach probability);
    ``rep_s``    — expected representation-HANDLING seconds/image, keyed
                   by the pyramid level (RGB resolution) each charge
                   materializes, plus ``FULL_LOAD`` for ARCHIVE's raw
                   load. These are the charges a multi-predicate scan can
                   SHARE: the engine materializes one pyramid per chunk
                   covering the union of every cascade's levels, so a
                   level an earlier predicate already pays for is free to
                   later predicates.

    ``total_s`` reproduces the standalone §VI expected cost exactly
    (``== CascadeSpace.time_s[i]``, tests/test_joint_planner.py);
    ``marginal_s`` is the same cascade priced when ``materialized``
    levels already exist — the joint planner's unit of cost."""
    infer_s: float
    rep_s: dict = field(default_factory=dict)   # {resolution|FULL_LOAD: s}

    @property
    def levels(self) -> frozenset:
        """Every rep_s key this cascade touches (pyramid resolutions,
        plus FULL_LOAD under ARCHIVE)."""
        return frozenset(self.rep_s)

    @property
    def rep_total_s(self) -> float:
        return float(sum(self.rep_s.values()))

    @property
    def total_s(self) -> float:
        """Standalone expected seconds/image (the §VI cost the cascade
        evaluator prices and the independent planner ranks by)."""
        return self.infer_s + self.rep_total_s

    def marginal_rep_s(self, materialized) -> float:
        """Rep-handling cost excluding levels in ``materialized`` (levels
        an earlier predicate in the plan order already pays for). Never
        exceeds ``rep_total_s`` — the basis of the joint planner's
        never-worse-than-independent guarantee."""
        return float(sum(s for r, s in self.rep_s.items()
                         if r not in materialized))

    def marginal_s(self, materialized) -> float:
        return self.infer_s + self.marginal_rep_s(materialized)

    def scaled(self, eval_frac: float) -> "DecomposedCost":
        """This cascade priced when only ``eval_frac`` of the candidate
        rows still need evaluation — the rest are answered from a
        seeded virtual column (engine/ingest.CandidateIndex decided
        labels, DESIGN.md §14/§15). Every charge scales linearly and
        the level set is preserved, so shared-pyramid marginal pricing
        (``marginal_s``) composes with index-aware planning."""
        f = float(eval_frac)
        return DecomposedCost(self.infer_s * f,
                              {r: s * f for r, s in self.rep_s.items()})


def decompose_cascade_cost(levels, scores_eval, reps, infer_s,
                           profile: CostProfile, scenario: str,
                           pyramid: bool = True,
                           dense_levels: bool = False) -> DecomposedCost:
    """Decompose one cascade's expected cost over the eval split.

    ``levels``: [(model_idx, p_low|None, p_high|None)] (the
    cascade.spec_levels format); ``scores_eval``: (M, I) cached scores;
    ``reps``: per-model Representation. The walk is the vectorized twin
    of ``cascade.cascade_time_naive`` — every charge a level incurs is
    identical for all images reaching it, so summing per-level charges
    weighted by reach fractions reproduces the per-image walk exactly —
    but each rep-handling charge is attributed to the pyramid level
    (resolution) it materializes instead of being folded into one
    scalar. ARCHIVE's full-size raw load is split out under the
    ``FULL_LOAD`` key (it too is shared across predicates).

    ``dense_levels=True`` prices the ENGINE's execution instead of the
    paper's per-image walk: every level is charged at reach probability
    1. The scan paths deliberately run full-width levels (static
    shapes, batch-packing-independent labels — engine/scan.py
    CompiledCascade), so a flushed batch pays EVERY level of the
    cascade for every row; reach-weighted §VI costing systematically
    undercharges multi-level cascades there. The joint planner uses
    this mode by default (engine/planner.plan_query costing='engine')
    because the plan it emits is executed by exactly those paths.
    NOTE: this is WITHIN-cascade pricing (a flushed batch runs every
    level of its own cascade full-width); it is orthogonal to the
    CROSS-predicate rep-charge weighting (joint_scan_cost dense_reps),
    where the engines' lazy first-touch schedule means a later
    predicate's levels are only pooled for rows surviving to it."""
    import numpy as np

    s = np.asarray(scores_eval)
    n = s.shape[1]
    active = np.ones(n, bool)
    seen: list = []                     # Representations already priced
    mat: list[int] = []                 # materialized pyramid resolutions
    rep_charges: dict = {}
    infer_total = 0.0
    for m, lo, hi in levels:
        p = (1.0 if dense_levels
             else float(active.sum()) / n)   # P(reach this level)
        if p == 0.0:
            break
        rep = reps[m]
        if rep not in seen:
            src = None
            if pyramid and mat:
                usable = [r for r in mat if r % rep.resolution == 0]
                src = min(usable) if usable else None
            c = rep_cost_s(profile, rep, scenario, first_rep=not seen,
                           source_hw=src)
            if scenario == "ARCHIVE" and not seen:
                rep_charges[FULL_LOAD] = (rep_charges.get(FULL_LOAD, 0.0)
                                          + p * profile.load_full_s)
                c -= profile.load_full_s
            rep_charges[rep.resolution] = (
                rep_charges.get(rep.resolution, 0.0) + p * c)
            seen.append(rep)
            mat.append(rep.resolution)
        infer_total += p * float(infer_s[m])
        if lo is None:
            break
        o = s[m]
        active = active & ~((o <= lo) | (o >= hi))
    return DecomposedCost(infer_total, rep_charges)
