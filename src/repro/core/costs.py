"""Deployment-scenario-aware data handling costs (paper §III issue 4, §VI).

t_classify = t_load + t_transform + t_infer, with the representation costs
charged ONCE per distinct representation per image (§VII-A3). Scenarios:

  INFER_ONLY - inference only (the computer-vision-literature convention)
  ARCHIVE    - load the full-size image from SSD once + transform into each
               distinct representation the cascade needs
  ONGOING    - representations were materialized at ingest; pay only the
               (smaller) per-representation load
  CAMERA     - frames arrive in memory from the sensor; pay transforms only

The CostProfile holds *measured* per-model/per-representation seconds
(core benchmark path: measured on this host; TPU-projected constants are
also provided for the roofline discussion). All times are seconds/image.

Pyramid pricing (DESIGN.md §3): a follow-up level whose resolution divides
an already-materialized level's resolution is produced from that level, not
from the raw base image — ``transform_from_s`` prices that *incremental*
t_transform. Profiles built by hand (without the modeled bandwidth fields)
degrade gracefully to the seed's from-base pricing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

from repro.core.transforms import Representation

SCENARIOS = ("INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA")

# Deployment-environment constants used when costs are modeled instead of
# measured. Per-image fixed overheads reflect file open + JPEG decode for
# full images and packed-binary reads for pre-materialized representations
# (EXPERIMENTS.md §Paper-claims documents the calibration).
SSD_BW = 2.0e9
CAMERA_DMA_BW = 8.0e9
TRANSFORM_BW = 4.0e9             # host-side resize throughput
LOAD_FULL_OVERHEAD_S = 1.5e-3    # open + decode a full-size image
LOAD_REP_OVERHEAD_S = 30e-6      # read a pre-sized packed representation
TRANSFORM_OVERHEAD_S = 20e-6     # per-op dispatch/copy


@dataclass
class CostProfile:
    """Per-deployment measured/modeled costs.
    infer_s[model_id]        : seconds/image of pure inference
    transform_s[rep.name]    : seconds/image to produce rep from raw
    load_rep_s[rep.name]     : seconds/image to load rep from storage
    load_full_s              : seconds/image to load the full-size raw image

    The optional pyramid fields enable incremental t_transform pricing
    (``transform_from_s``); ``modeled`` fills them in, hand-built profiles
    may leave them None and keep the seed's from-base pricing.
    """
    infer_s: Mapping[str, float]
    transform_s: Mapping[str, float]
    load_rep_s: Mapping[str, float]
    load_full_s: float
    transform_bw: float | None = None        # bytes/s of the resize path
    transform_overhead_s: float = TRANSFORM_OVERHEAD_S
    byte_scale: float = 1.0                  # corpus -> paper-regime bytes
    base_hw: int | None = None

    @staticmethod
    def modeled(model_infer_s: Mapping[str, float],
                reps: list[Representation], base_hw: int,
                scale: float = 1.0) -> "CostProfile":
        """scale: byte-scale multiplier mapping reduced-resolution stand-in
        corpora onto the paper's 224px regime (scale = (224/base_hw)^2)."""
        full_bytes = base_hw * base_hw * 3 * scale
        return CostProfile(
            infer_s=dict(model_infer_s),
            transform_s={r.name: TRANSFORM_OVERHEAD_S
                         + (full_bytes + r.bytes * scale) / TRANSFORM_BW
                         for r in reps},
            load_rep_s={r.name: LOAD_REP_OVERHEAD_S
                        + r.bytes * scale / SSD_BW for r in reps},
            load_full_s=LOAD_FULL_OVERHEAD_S + full_bytes / SSD_BW,
            transform_bw=TRANSFORM_BW,
            transform_overhead_s=TRANSFORM_OVERHEAD_S,
            byte_scale=scale,
            base_hw=base_hw,
        )

    def transform_from_s(self, rep: Representation,
                         source_hw: int | None) -> float:
        """Incremental t_transform: produce ``rep`` from an already
        materialized RGB pyramid level at ``source_hw``. Falls back to the
        from-base price when the profile lacks bandwidth fields, when no
        source is given, or when the source cannot serve this resolution."""
        if (self.transform_bw is None or source_hw is None
                or source_hw % rep.resolution != 0
                or (self.base_hw is not None and source_hw >= self.base_hw)):
            return self.transform_s[rep.name]
        read = source_hw * source_hw * 3 * self.byte_scale
        return self.transform_overhead_s \
            + (read + rep.bytes * self.byte_scale) / self.transform_bw


def rep_cost_s(profile: CostProfile, rep: Representation,
               scenario: str, first_rep: bool,
               source_hw: int | None = None) -> float:
    """Data-handling cost of materializing ``rep`` for one image under
    ``scenario``. first_rep: True when this is the first representation the
    cascade touches (ARCHIVE pays the full-size load exactly once).
    source_hw: resolution of the nearest already-materialized RGB pyramid
    level, when the executor can derive ``rep`` from it (DESIGN.md §3)."""
    if scenario == "INFER_ONLY":
        return 0.0
    if scenario == "ARCHIVE":
        return (profile.load_full_s if first_rep else 0.0) \
            + profile.transform_from_s(rep, source_hw)
    if scenario == "ONGOING":
        return profile.load_rep_s[rep.name]
    if scenario == "CAMERA":
        return profile.transform_from_s(rep, source_hw)
    raise ValueError(scenario)
