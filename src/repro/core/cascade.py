"""Cascade construction + evaluation (paper §V-D/E).

The paper's key evaluation trick: inference runs ONCE per model over the
eval split; every cascade is then *simulated* from the cached score matrix.
We push this further than the paper's per-cascade loop: because decision
thresholds are per-model (independent of cascade context, §V-C), cascade
accuracy/cost decompose into per-model sums and pairwise inner products
over images — so evaluating ALL 1/2/3-level cascades is a handful of
(A x I) @ (I x B) matmuls (TPU/BLAS-native; DESIGN.md §3). The paper
evaluates 1.3M cascades in ~1 minute; this path does it in seconds
(benchmarks/bench_eval_speed.py) and is property-tested against a naive
per-image simulator (simulate_cascade).

Two evaluators share the same closed form:

  evaluate_cascades            dense: materializes the full (A2,M) and
                               (A,B) blocks in RAM (fine up to ~10M
                               cascades on a laptop).
  evaluate_cascades_streaming  bounded memory: the A axis is processed in
                               fixed-size chunks through a jitted JAX
                               kernel (kernels/matmul.py on TPU), each
                               chunk immediately folded into a streaming
                               Pareto-frontier / top-K reduction — the
                               full N-cascade arrays are never
                               materialized, scaling the search to tens of
                               millions of cascades (DESIGN.md §3).

Cascade semantics (Def. 7): image flows through levels; level l's output o
is accepted iff o <= p_low or o >= p_high (label = o >= p_high); the final
level's label is o >= 0.5 unconditionally.

Cost semantics (§VI + §VII-A3): expected seconds/image =
  sum_l P(reach l) * [infer_s(l) + rep-handling of level-l's representation
                      if not already materialized by an earlier level]
with rep handling priced by the deployment scenario (core/costs.py).
Pyramid pricing (default): a follow-up representation is transformed from
the nearest already-materialized pyramid level instead of the raw base
image — the incremental t_transform of core/transforms.plan_pyramid,
mirroring what core/executor.py actually executes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.costs import CostProfile, rep_cost_s
from repro.core.transforms import Representation

KIND_SINGLE, KIND_TWO, KIND_THREE = 0, 1, 2


@dataclass
class CascadeSpace:
    """Flat arrays over enumerated (dense) or surviving (streaming)
    cascades. ``evaluated`` counts the cascades scored to produce this
    space (== len() for the dense evaluator)."""
    acc: np.ndarray          # (N,)
    time_s: np.ndarray       # (N,) expected seconds/image
    kind: np.ndarray         # (N,) 0/1/2
    i1: np.ndarray           # (N,) level-1: configured idx (kinds 1,2) or model idx (kind 0)
    i2: np.ndarray           # (N,) level-2: model idx (kind 1) / configured idx (kind 2)
    n_targets: int
    trusted: int
    evaluated: int = 0

    @property
    def throughput(self) -> np.ndarray:
        return 1.0 / self.time_s

    def __len__(self):
        return len(self.acc)

    def describe(self, i: int, model_names: Sequence[str],
                 targets: Sequence[float]) -> str:
        k = self.kind[i]
        def cfg(a):
            return (f"{model_names[a // self.n_targets]}"
                    f"@p{targets[a % self.n_targets]}")
        if k == KIND_SINGLE:
            return model_names[self.i1[i]]
        if k == KIND_TWO:
            return f"{cfg(self.i1[i])} -> {model_names[self.i2[i]]}"
        return (f"{cfg(self.i1[i])} -> {cfg(self.i2[i])} -> "
                f"{model_names[self.trusted]}")


# ------------------------------------------------------------ cost model ---
def _cost_matrices(reps: list[Representation], infer_s, profile,
                   scenario: str, trusted: int, pyramid: bool):
    """first[m]  : level-1 cost of model m (rep-from-base + infer).
    follow[i,j]  : data cost of rep_j at the level after a level using
                   rep_i (materialized pyramid levels: {base, res_i}).
    tpair[i,j]   : data cost of the trusted rep at level 3 after levels
                   using rep_i then rep_j ({base, res_i, res_j})."""
    m = len(reps)
    res = np.array([r.resolution for r in reps])
    names = np.array([r.name for r in reps])
    same = names[:, None] == names[None, :]

    first = np.array([rep_cost_s(profile, reps[i], scenario, True)
                      + infer_s[i] for i in range(m)])

    uniq = sorted(set(int(r) for r in res))
    # cost_from[u][j]: rep_j produced from a materialized level at u
    cost_from = {u: np.array([rep_cost_s(profile, reps[j], scenario, False,
                                         source_hw=u if pyramid else None)
                              for j in range(m)]) for u in uniq}
    cost_base = np.array([rep_cost_s(profile, reps[j], scenario, False)
                          for j in range(m)])

    div = (res[:, None] % res[None, :]) == 0          # src i usable for j
    by_src = np.stack([cost_from[int(r)] for r in res])   # (m_src, m)
    follow = np.where(div, by_src, cost_base[None, :])
    follow[same] = 0.0

    rt = reps[trusted]
    big = np.iinfo(np.int64).max
    src_t = np.where((res % rt.resolution == 0) if pyramid
                     else np.zeros(m, bool), res, big)   # (m,) or sentinel
    pair_src = np.minimum(src_t[:, None], src_t[None, :])  # (m, m)
    t_by_src = {u: rep_cost_s(profile, rt, scenario, False, source_hw=u)
                for u in uniq}
    t_base = rep_cost_s(profile, rt, scenario, False)
    tpair = np.full((m, m), t_base)
    for u in uniq:
        tpair[pair_src == u] = t_by_src[u]
    tpair[same[:, trusted][:, None] | same[trusted, :][None, :]] = 0.0
    return first, follow, tpair


def _certainty_stats(scores, truth, p_low, p_high):
    """Per-configured-model certainty/correctness reductions shared by both
    evaluators. Returns dict of (A,I)/(A,)/(M,)-shaped arrays."""
    s = np.asarray(scores, np.float32)
    y = np.asarray(truth, bool)
    m_models, n_img = s.shape
    p_low = np.asarray(p_low)
    p_high = np.asarray(p_high)
    n_t = p_low.shape[1]
    shi = s[:, None, :] >= p_high[:, :, None]          # (M,T,I)
    slo = s[:, None, :] <= p_low[:, :, None]
    cert = (shi | slo)
    corr_cert = cert & (shi == y[None, None, :])
    a_dim = m_models * n_t
    c = cert.reshape(a_dim, n_img).astype(np.float32)           # (A,I)
    v = corr_cert.reshape(a_dim, n_img).astype(np.float32)      # (A,I)
    corr_final = ((s >= 0.5) == y[None, :]).astype(np.float32)  # (M,I)
    return {
        "c": c, "v": v, "cc_sum": v.sum(1), "p_cert": c.mean(1),
        "c_sum": c.sum(1), "corr_final": corr_final,
        "cf_sum": corr_final.sum(1), "n_img": n_img,
        "m_models": m_models, "n_t": n_t,
        "cfg_model": np.repeat(np.arange(m_models), n_t),
    }


# --------------------------------------------------------- dense evaluator -
def evaluate_cascades(scores_eval, truth, p_low, p_high,
                      reps: list[Representation], infer_s,
                      profile: CostProfile, scenario: str,
                      trusted: int, *, max_level: int = 3,
                      first_level_models=None,
                      pyramid: bool = True) -> CascadeSpace:
    """scores_eval (M, I); p_low/p_high (M, T); infer_s (M,).
    trusted: model index used as the forced final level of 3-level
    cascades (the paper's ResNet50 slot). pyramid: price follow-up
    transforms incrementally from materialized pyramid levels (see module
    docstring); False reproduces from-base pricing."""
    st = _certainty_stats(scores_eval, truth, p_low, p_high)
    m_models, n_img, n_t = st["m_models"], st["n_img"], st["n_t"]
    c, v, corr_final = st["c"], st["v"], st["corr_final"]
    cc_sum, p_cert, cf_sum = st["cc_sum"], st["p_cert"], st["cf_sum"]
    cfg_model = st["cfg_model"]
    infer_s = np.asarray(infer_s, np.float64)
    first_c, follow_c, tpair_c = _cost_matrices(
        reps, infer_s, profile, scenario, trusted, pyramid)

    first_models = (np.arange(m_models) if first_level_models is None
                    else np.asarray(first_level_models))

    out_acc, out_t, out_kind, out_i1, out_i2 = [], [], [], [], []

    # ---- 1-level: every base model alone
    out_acc.append(cf_sum / n_img)
    out_t.append(first_c.copy())
    out_kind.append(np.full(m_models, KIND_SINGLE))
    out_i1.append(np.arange(m_models))
    out_i2.append(np.full(m_models, -1))

    if max_level >= 2:
        # ---- 2-level: configured a -> final b (all models)
        a_idx = (first_models[:, None] * n_t
                 + np.arange(n_t)[None, :]).ravel()             # (A2,)
        c_a = c[a_idx]
        acc = (cc_sum[a_idx][:, None] + cf_sum[None, :]
               - c_a @ corr_final.T) / n_img                    # (A2,M)
        p_unc = 1.0 - p_cert[a_idx]
        rep_extra = follow_c[cfg_model[a_idx]]                  # (A2,M)
        t = (first_c[cfg_model[a_idx]][:, None]
             + p_unc[:, None] * (infer_s[None, :] + rep_extra))
        a2, mm = acc.shape
        out_acc.append(acc.ravel())
        out_t.append(t.ravel())
        out_kind.append(np.full(a2 * mm, KIND_TWO))
        out_i1.append(np.repeat(a_idx, mm))
        out_i2.append(np.tile(np.arange(m_models), a2))

    if max_level >= 3:
        # ---- 3-level: configured a -> configured b -> trusted
        a_idx = (first_models[:, None] * n_t
                 + np.arange(n_t)[None, :]).ravel()
        b_idx = np.arange(m_models * n_t)
        c_a, c_b = c[a_idx], c
        corr_t = corr_final[trusted]
        ct_sum = corr_t.sum()
        term2 = cc_sum[None, :] - c_a @ v.T                     # (A,B)
        cab = c_a @ c_b.T
        cab_t = (c_a * corr_t[None, :]) @ c_b.T
        sum_ca_t = c_a @ corr_t
        sum_cb_t = c_b @ corr_t
        term3 = (ct_sum - sum_ca_t[:, None] - sum_cb_t[None, :] + cab_t)
        acc = (cc_sum[a_idx][:, None] + term2 + term3) / n_img
        p_unc_a = 1.0 - p_cert[a_idx]
        p_unc_ab = (n_img - c_a.sum(1)[:, None] - c_b.sum(1)[None, :]
                    + cab) / n_img
        mb = cfg_model
        ma = cfg_model[a_idx]
        rep_b_extra = follow_c[ma][:, mb]
        rep_t_extra = tpair_c[ma][:, mb]
        t = (first_c[ma][:, None]
             + p_unc_a[:, None] * (infer_s[mb][None, :] + rep_b_extra)
             + p_unc_ab * (infer_s[trusted] + rep_t_extra))
        a3, bdim = acc.shape
        out_acc.append(acc.ravel())
        out_t.append(t.ravel())
        out_kind.append(np.full(a3 * bdim, KIND_THREE))
        out_i1.append(np.repeat(a_idx, bdim))
        out_i2.append(np.tile(b_idx, a3))

    acc = np.concatenate(out_acc)
    return CascadeSpace(
        acc=acc, time_s=np.concatenate(out_t),
        kind=np.concatenate(out_kind).astype(np.int8),
        i1=np.concatenate(out_i1).astype(np.int32),
        i2=np.concatenate(out_i2).astype(np.int32),
        n_targets=n_t, trusted=trusted, evaluated=len(acc))


# ----------------------------------------------------- streaming evaluator -
def _frontier_mask(acc, time_s):
    """Vectorized (acc max, time min) skyline sweep — O(n log n), no
    python-per-point loop. May keep boundary duplicates; the final result
    is canonicalized through pareto.pareto_indices by the caller."""
    acc = np.asarray(acc, np.float64)
    thr = 1.0 / np.asarray(time_s, np.float64)
    order = np.lexsort((-thr, -acc))
    t_sorted = thr[order]
    keep_sorted = np.empty(len(order), bool)
    if len(order):
        keep_sorted[0] = True
        keep_sorted[1:] = t_sorted[1:] > np.maximum.accumulate(t_sorted)[:-1]
    mask = np.zeros(len(acc), bool)
    mask[order[keep_sorted]] = True
    return mask


class _StreamReducer:
    """Folds candidate blocks into a bounded survivor set: the running
    Pareto frontier, or a top-K (by accuracy, faster-first tie-break).
    Peak state is O(frontier + K), independent of cascades seen.

    Pareto fold cost per block is O(n log F): a vectorized dominance test
    against the current frontier (searchsorted + suffix-max) discards the
    overwhelming majority of candidates WITHOUT sorting the block; only
    the (few) non-dominated survivors pay the exact skyline sweep."""

    FIELDS = ("acc", "time_s", "kind", "i1", "i2")

    def __init__(self, keep: str = "pareto", top_k: int | None = None):
        assert keep in ("pareto", "topk")
        if keep == "topk" and not top_k:
            raise ValueError("keep='topk' requires top_k")
        self.keep = keep
        self.top_k = top_k
        self.buf = {f: np.empty(0) for f in self.FIELDS}
        self.seen = 0
        # frontier dominance index: acc ascending + suffix max throughput
        self._acc_sorted = np.empty(0)
        self._thr_suffix_max = np.empty(0)

    def _reindex(self):
        order = np.argsort(self.buf["acc"], kind="stable")
        self._acc_sorted = self.buf["acc"][order]
        thr = 1.0 / self.buf["time_s"][order]
        self._thr_suffix_max = np.maximum.accumulate(thr[::-1])[::-1]

    def _undominated(self, acc, thr):
        """True for candidates no current frontier point dominates (exact
        duplicates of frontier points count as dominated)."""
        if not len(self._acc_sorted):
            return np.ones(len(acc), bool)
        idx = np.searchsorted(self._acc_sorted, acc, side="left")
        best = np.full(len(acc), -np.inf)
        inb = idx < len(self._acc_sorted)
        best[inb] = self._thr_suffix_max[idx[inb]]
        return thr > best

    def push(self, acc, time_s, kind, i1, i2):
        acc = np.asarray(acc).ravel()
        self.seen += len(acc)
        time_s = np.asarray(time_s).ravel()
        if self.keep == "pareto":
            thr = 1.0 / time_s
            cand = np.nonzero(self._undominated(acc, thr))[0]
            if not len(cand):
                return
            block = {"acc": acc[cand], "time_s": time_s[cand],
                     "kind": np.broadcast_to(kind, acc.shape)[cand],
                     "i1": np.asarray(i1).ravel()[cand],
                     "i2": np.asarray(i2).ravel()[cand]}
            merged = {f: np.concatenate([self.buf[f], block[f]])
                      for f in self.FIELDS}
            mask = _frontier_mask(merged["acc"], merged["time_s"])
            self.buf = {f: merged[f][mask] for f in self.FIELDS}
            self._reindex()
        else:
            block = {"acc": acc, "time_s": time_s,
                     "kind": np.broadcast_to(kind, acc.shape).ravel(),
                     "i1": np.asarray(i1).ravel(),
                     "i2": np.asarray(i2).ravel()}
            k = self.top_k
            if len(acc) > k:
                # intra-block prefilter: keep everything at or above the
                # k-th largest accuracy (>= keeps boundary TIES, so the
                # faster-first tie-break below still sees all of them)
                kth = np.partition(block["acc"], len(acc) - k)[len(acc) - k]
                mask = block["acc"] >= kth
                block = {f: block[f][mask] for f in self.FIELDS}
            merged = {f: np.concatenate([self.buf[f], block[f]])
                      for f in self.FIELDS}
            order = np.lexsort((merged["time_s"], -merged["acc"]))[:k]
            self.buf = {f: merged[f][order] for f in self.FIELDS}

    def result(self, n_targets: int, trusted: int) -> CascadeSpace:
        from repro.core.pareto import pareto_indices
        buf = self.buf
        if self.keep == "pareto" and len(buf["acc"]):
            idx = np.sort(pareto_indices(buf["acc"], 1.0 / buf["time_s"]))
            buf = {f: buf[f][idx] for f in self.FIELDS}
        return CascadeSpace(
            acc=np.asarray(buf["acc"], np.float64),
            time_s=np.asarray(buf["time_s"], np.float64),
            kind=np.asarray(buf["kind"], np.int8),
            i1=np.asarray(buf["i1"], np.int32),
            i2=np.asarray(buf["i2"], np.int32),
            n_targets=n_targets, trusted=trusted, evaluated=self.seen)


def evaluate_cascades_streaming(scores_eval, truth, p_low, p_high,
                                reps: list[Representation], infer_s,
                                profile: CostProfile, scenario: str,
                                trusted: int, *, max_level: int = 3,
                                first_level_models=None,
                                pyramid: bool = True,
                                chunk: int = 128,
                                keep: str = "pareto",
                                top_k: int | None = None,
                                use_pallas_matmul: bool | None = None
                                ) -> CascadeSpace:
    """Bounded-memory evaluation of the same cascade space as
    ``evaluate_cascades``: first-level configurations are processed in
    ``chunk``-sized slices through one jitted JAX program (the (chunk,M)
    2-level and (chunk,B) 3-level blocks), and every block is folded into
    a streaming Pareto/top-K reduction before the next slice is computed.
    Peak memory is O(chunk * B + survivors) instead of O(A * B).

    use_pallas_matmul: route the inner products through the blocked MXU
    kernel (kernels/matmul.py); default: only on TPU backends (interpret
    mode would dominate runtime on CPU)."""
    import jax
    import jax.numpy as jnp

    st = _certainty_stats(scores_eval, truth, p_low, p_high)
    m_models, n_img, n_t = st["m_models"], st["n_img"], st["n_t"]
    cfg_model = st["cfg_model"]
    infer64 = np.asarray(infer_s, np.float64)
    first_c, follow_c, tpair_c = _cost_matrices(
        reps, infer64, profile, scenario, trusted, pyramid)

    red = _StreamReducer(keep=keep, top_k=top_k)

    # ---- 1-level block (tiny; no chunking needed)
    red.push(st["cf_sum"] / n_img, first_c, KIND_SINGLE,
             np.arange(m_models), np.full(m_models, -1))
    if max_level < 2:
        return red.result(n_t, trusted)

    if use_pallas_matmul is None:
        use_pallas_matmul = jax.default_backend() == "tpu"
    if use_pallas_matmul:
        from repro.kernels.matmul import matmul as _pallas_mm
        def mm(a, b):
            return _pallas_mm(a, b, out_dtype=jnp.float32)
    else:
        mm = jnp.dot

    # device-resident constants (A,I)/(M,I): the only full-width state
    c_d = jnp.asarray(st["c"])
    v_t = jnp.asarray(st["v"].T)
    c_t = jnp.asarray(st["c"].T)
    cf_t = jnp.asarray(st["corr_final"].T)
    corr_t = jnp.asarray(st["corr_final"][trusted])
    ct_sum = float(st["corr_final"][trusted].sum())
    cf_sum_d = jnp.asarray(st["cf_sum"])
    cc_sum_d = jnp.asarray(st["cc_sum"])
    c_sum_d = jnp.asarray(st["c_sum"])
    sum_cb_t = jnp.asarray(st["c"] @ st["corr_final"][trusted])
    infer_m = jnp.asarray(infer64, jnp.float32)
    infer_b = jnp.asarray(infer64[cfg_model], jnp.float32)
    infer_trusted = float(infer64[trusted])
    inv_n = 1.0 / n_img

    @jax.jit
    def _eval_chunk(ca, cc_a, pc_a, first_a, f2, f3, tp):
        # 2-level (chunk, M)
        acc2 = (cc_a[:, None] + cf_sum_d[None, :] - mm(ca, cf_t)) * inv_n
        t2 = first_a[:, None] + (1.0 - pc_a)[:, None] * (infer_m[None, :]
                                                         + f2)
        if max_level < 3:
            return acc2, t2, None, None
        # 3-level (chunk, B)
        term2 = cc_sum_d[None, :] - mm(ca, v_t)
        cab = mm(ca, c_t)
        cab_t = mm(ca * corr_t[None, :], c_t)
        sum_ca_t = ca @ corr_t
        term3 = ct_sum - sum_ca_t[:, None] - sum_cb_t[None, :] + cab_t
        acc3 = (cc_a[:, None] + term2 + term3) * inv_n
        p_unc_ab = (n_img - ca.sum(1)[:, None] - c_sum_d[None, :]
                    + cab) * inv_n
        t3 = (first_a[:, None]
              + (1.0 - pc_a)[:, None] * (infer_b[None, :] + f3)
              + p_unc_ab * (infer_trusted + tp))
        return acc2, t2, acc3, t3

    first_models = (np.arange(m_models) if first_level_models is None
                    else np.asarray(first_level_models))
    a_idx = (first_models[:, None] * n_t
             + np.arange(n_t)[None, :]).ravel()
    b_idx = np.arange(m_models * n_t)
    chunk = max(1, min(chunk, len(a_idx)))

    # one f32 copy of the per-model cost gathers; chunks slice rows
    first32 = first_c.astype(np.float32)
    follow32 = follow_c.astype(np.float32)               # (M, M)
    follow_b32 = follow_c[:, cfg_model].astype(np.float32)   # (M, B)
    tpair_b32 = tpair_c[:, cfg_model].astype(np.float32)     # (M, B)
    zero_chunk = np.zeros((chunk, 1), np.float32)

    for start in range(0, len(a_idx), chunk):
        idx = a_idx[start:start + chunk]
        nvalid = len(idx)
        if nvalid < chunk:               # pad: keep one compiled shape
            idx = np.concatenate([idx, np.repeat(idx[-1:],
                                                 chunk - nvalid)])
        ma = cfg_model[idx]
        f3 = follow_b32[ma] if max_level >= 3 else zero_chunk
        tp = tpair_b32[ma] if max_level >= 3 else zero_chunk
        acc2, t2, acc3, t3 = _eval_chunk(
            c_d[idx], jnp.asarray(st["cc_sum"][idx]),
            jnp.asarray(st["p_cert"][idx]),
            jnp.asarray(first32[ma]), jnp.asarray(follow32[ma]),
            jnp.asarray(f3), jnp.asarray(tp))
        acc2 = np.asarray(acc2)[:nvalid]
        t2 = np.asarray(t2)[:nvalid]
        idx = idx[:nvalid]
        red.push(acc2, t2, KIND_TWO,
                 np.repeat(idx, m_models),
                 np.tile(np.arange(m_models), nvalid))
        if max_level >= 3:
            acc3 = np.asarray(acc3)[:nvalid]
            t3 = np.asarray(t3)[:nvalid]
            red.push(acc3, t3, KIND_THREE,
                     np.repeat(idx, len(b_idx)),
                     np.tile(b_idx, nvalid))
    return red.result(n_t, trusted)


# ------------------------------------------------------- naive reference ---
def simulate_cascade(levels, scores_eval, truth):
    """Per-image reference simulator. levels: list of
    (model_idx, p_low|None, p_high|None); None thresholds = final level.
    Returns (accuracy, level_reach_fractions)."""
    s = np.asarray(scores_eval)
    y = np.asarray(truth, bool)
    n = s.shape[1]
    correct = 0
    reach = np.zeros(len(levels))
    for i in range(n):
        for li, (m, lo, hi) in enumerate(levels):
            reach[li] += 1
            o = s[m, i]
            final = lo is None
            if final or o <= lo or o >= hi:
                pred = o >= (0.5 if final else hi)
                correct += int(pred == y[i])
                break
    return correct / n, reach / n


def cascade_time_naive(levels, scores_eval, reps, infer_s, profile,
                       scenario, pyramid: bool = True):
    """Expected per-image cost by explicit per-image walk (reference).
    pyramid: follow-up representations are transformed from the smallest
    already-materialized pyramid level whose resolution they divide
    (matching evaluate_cascades and the executor's derivation policy)."""
    s = np.asarray(scores_eval)
    n = s.shape[1]
    total = 0.0
    for i in range(n):
        seen_reps = []
        mat_res = []                      # materialized pyramid levels
        for li, (m, lo, hi) in enumerate(levels):
            if reps[m] not in seen_reps:
                src = None
                if pyramid and mat_res:
                    usable = [r for r in mat_res
                              if r % reps[m].resolution == 0]
                    src = min(usable) if usable else None
                total += rep_cost_s(profile, reps[m], scenario,
                                    first_rep=not seen_reps,
                                    source_hw=src)
                seen_reps.append(reps[m])
                mat_res.append(reps[m].resolution)
            total += infer_s[m]
            o = s[m, i]
            if lo is None or o <= lo or o >= hi:
                break
    return total / n


def spec_levels(space: CascadeSpace, i: int, p_low, p_high):
    """Decode cascade i into the ``levels`` format of simulate_cascade."""
    k, a, b = space.kind[i], space.i1[i], space.i2[i]
    nt = space.n_targets
    if k == KIND_SINGLE:
        return [(int(a), None, None)]
    if k == KIND_TWO:
        m1, t1 = divmod(int(a), nt)
        return [(m1, p_low[m1, t1], p_high[m1, t1]), (int(b), None, None)]
    m1, t1 = divmod(int(a), nt)
    m2, t2 = divmod(int(b), nt)
    return [(m1, p_low[m1, t1], p_high[m1, t1]),
            (m2, p_low[m2, t2], p_high[m2, t2]),
            (space.trusted, None, None)]
