"""Cascade construction + evaluation (paper §V-D/E).

The paper's key evaluation trick: inference runs ONCE per model over the
eval split; every cascade is then *simulated* from the cached score matrix.
We push this further than the paper's per-cascade loop: because decision
thresholds are per-model (independent of cascade context, §V-C), cascade
accuracy/cost decompose into per-model sums and pairwise inner products
over images — so evaluating ALL 1/2/3-level cascades is a handful of
(A x I) @ (I x B) matmuls (TPU/BLAS-native; DESIGN.md §3). The paper
evaluates 1.3M cascades in ~1 minute; this path does it in seconds
(benchmarks/bench_eval_speed.py) and is property-tested against a naive
per-image simulator (simulate_cascade).

Cascade semantics (Def. 7): image flows through levels; level l's output o
is accepted iff o <= p_low or o >= p_high (label = o >= p_high); the final
level's label is o >= 0.5 unconditionally.

Cost semantics (§VI + §VII-A3): expected seconds/image =
  sum_l P(reach l) * [infer_s(l) + rep-handling of level-l's representation
                      if not already materialized by an earlier level]
with rep handling priced by the deployment scenario (core/costs.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.costs import CostProfile, rep_cost_s
from repro.core.transforms import Representation

KIND_SINGLE, KIND_TWO, KIND_THREE = 0, 1, 2


@dataclass
class CascadeSpace:
    """Flat arrays over all enumerated cascades."""
    acc: np.ndarray          # (N,)
    time_s: np.ndarray       # (N,) expected seconds/image
    kind: np.ndarray         # (N,) 0/1/2
    i1: np.ndarray           # (N,) level-1: configured idx (kinds 1,2) or model idx (kind 0)
    i2: np.ndarray           # (N,) level-2: model idx (kind 1) / configured idx (kind 2)
    n_targets: int
    trusted: int

    @property
    def throughput(self) -> np.ndarray:
        return 1.0 / self.time_s

    def __len__(self):
        return len(self.acc)

    def describe(self, i: int, model_names: Sequence[str],
                 targets: Sequence[float]) -> str:
        k = self.kind[i]
        def cfg(a):
            return (f"{model_names[a // self.n_targets]}"
                    f"@p{targets[a % self.n_targets]}")
        if k == KIND_SINGLE:
            return model_names[self.i1[i]]
        if k == KIND_TWO:
            return f"{cfg(self.i1[i])} -> {model_names[self.i2[i]]}"
        return (f"{cfg(self.i1[i])} -> {cfg(self.i2[i])} -> "
                f"{model_names[self.trusted]}")


def _level_cost_matrix(reps: list[Representation], infer_s, profile,
                       scenario: str):
    """first_cost[m]: level-1 cost of model m (rep + infer).
    follow_cost[m]: rep+infer of m when it appears at level>=2 and its rep
    is NOT yet materialized. same_rep[m1, m2]: rep identity mask."""
    m = len(reps)
    first = np.array([rep_cost_s(profile, reps[i], scenario, True)
                      + infer_s[i] for i in range(m)])
    follow_rep = np.array([rep_cost_s(profile, reps[i], scenario, False)
                           for i in range(m)])
    same = np.array([[reps[i] == reps[j] for j in range(m)]
                     for i in range(m)])
    return first, follow_rep, same


def evaluate_cascades(scores_eval, truth, p_low, p_high,
                      reps: list[Representation], infer_s,
                      profile: CostProfile, scenario: str,
                      trusted: int, *, max_level: int = 3,
                      first_level_models=None) -> CascadeSpace:
    """scores_eval (M, I); p_low/p_high (M, T); infer_s (M,).
    trusted: model index used as the forced final level of 3-level
    cascades (the paper's ResNet50 slot)."""
    s = np.asarray(scores_eval, np.float32)
    y = np.asarray(truth, bool)
    m_models, n_img = s.shape
    p_low = np.asarray(p_low)
    p_high = np.asarray(p_high)
    n_t = p_low.shape[1]
    infer_s = np.asarray(infer_s, np.float64)
    first_c, follow_rep_c, same_rep = _level_cost_matrix(
        reps, infer_s, profile, scenario)

    # per-configured-model certainty/correctness over images
    shi = s[:, None, :] >= p_high[:, :, None]          # (M,T,I)
    slo = s[:, None, :] <= p_low[:, :, None]
    cert = (shi | slo)
    corr_cert = cert & (shi == y[None, None, :])
    a_dim = m_models * n_t
    c = cert.reshape(a_dim, n_img).astype(np.float32)           # (A,I)
    v = corr_cert.reshape(a_dim, n_img).astype(np.float32)      # (A,I)
    cc_sum = v.sum(1)                                           # (A,)
    p_cert = c.mean(1)
    corr_final = ((s >= 0.5) == y[None, :]).astype(np.float32)  # (M,I)
    cf_sum = corr_final.sum(1)

    cfg_model = np.repeat(np.arange(m_models), n_t)             # (A,)
    first_models = (np.arange(m_models) if first_level_models is None
                    else np.asarray(first_level_models))

    out_acc, out_t, out_kind, out_i1, out_i2 = [], [], [], [], []

    # ---- 1-level: every base model alone
    out_acc.append(cf_sum / n_img)
    out_t.append(first_c.copy())
    out_kind.append(np.full(m_models, KIND_SINGLE))
    out_i1.append(np.arange(m_models))
    out_i2.append(np.full(m_models, -1))

    if max_level >= 2:
        # ---- 2-level: configured a -> final b (all models)
        a_idx = (first_models[:, None] * n_t
                 + np.arange(n_t)[None, :]).ravel()             # (A2,)
        c_a = c[a_idx]
        acc = (cc_sum[a_idx][:, None] + cf_sum[None, :]
               - c_a @ corr_final.T) / n_img                    # (A2,M)
        p_unc = 1.0 - p_cert[a_idx]
        rep_extra = np.where(same_rep[cfg_model[a_idx]], 0.0,
                             follow_rep_c[None, :])
        t = (first_c[cfg_model[a_idx]][:, None]
             + p_unc[:, None] * (infer_s[None, :] + rep_extra))
        a2, mm = acc.shape
        out_acc.append(acc.ravel())
        out_t.append(t.ravel())
        out_kind.append(np.full(a2 * mm, KIND_TWO))
        out_i1.append(np.repeat(a_idx, mm))
        out_i2.append(np.tile(np.arange(m_models), a2))

    if max_level >= 3:
        # ---- 3-level: configured a -> configured b -> trusted
        a_idx = (first_models[:, None] * n_t
                 + np.arange(n_t)[None, :]).ravel()
        b_idx = np.arange(a_dim)
        c_a, c_b = c[a_idx], c
        corr_t = corr_final[trusted]
        ct_sum = corr_t.sum()
        term2 = cc_sum[None, :] - c_a @ v.T                     # (A,B)
        cab = c_a @ c_b.T
        cab_t = (c_a * corr_t[None, :]) @ c_b.T
        sum_ca_t = c_a @ corr_t
        sum_cb_t = c_b @ corr_t
        term3 = (ct_sum - sum_ca_t[:, None] - sum_cb_t[None, :] + cab_t)
        acc = (cc_sum[a_idx][:, None] + term2 + term3) / n_img
        p_unc_a = 1.0 - p_cert[a_idx]
        p_unc_ab = (n_img - c_a.sum(1)[:, None] - c_b.sum(1)[None, :]
                    + cab) / n_img
        mb = cfg_model
        rep_b_extra = np.where(same_rep[cfg_model[a_idx]][:, mb], 0.0,
                               follow_rep_c[mb][None, :])
        rep_t_extra = np.where(
            same_rep[cfg_model[a_idx], trusted][:, None]
            | same_rep[mb, trusted][None, :], 0.0,
            rep_cost_s(profile, reps[trusted], scenario, False))
        t = (first_c[cfg_model[a_idx]][:, None]
             + p_unc_a[:, None] * (infer_s[mb][None, :] + rep_b_extra)
             + p_unc_ab * (infer_s[trusted] + rep_t_extra))
        a3, bdim = acc.shape
        out_acc.append(acc.ravel())
        out_t.append(t.ravel())
        out_kind.append(np.full(a3 * bdim, KIND_THREE))
        out_i1.append(np.repeat(a_idx, bdim))
        out_i2.append(np.tile(b_idx, a3))

    return CascadeSpace(
        acc=np.concatenate(out_acc), time_s=np.concatenate(out_t),
        kind=np.concatenate(out_kind).astype(np.int8),
        i1=np.concatenate(out_i1).astype(np.int32),
        i2=np.concatenate(out_i2).astype(np.int32),
        n_targets=n_t, trusted=trusted)


# ------------------------------------------------------- naive reference ---
def simulate_cascade(levels, scores_eval, truth):
    """Per-image reference simulator. levels: list of
    (model_idx, p_low|None, p_high|None); None thresholds = final level.
    Returns (accuracy, level_reach_fractions)."""
    s = np.asarray(scores_eval)
    y = np.asarray(truth, bool)
    n = s.shape[1]
    correct = 0
    reach = np.zeros(len(levels))
    for i in range(n):
        for li, (m, lo, hi) in enumerate(levels):
            reach[li] += 1
            o = s[m, i]
            final = lo is None
            if final or o <= lo or o >= hi:
                pred = o >= (0.5 if final else hi)
                correct += int(pred == y[i])
                break
    return correct / n, reach / n


def cascade_time_naive(levels, scores_eval, reps, infer_s, profile,
                       scenario):
    """Expected per-image cost by explicit per-image walk (reference)."""
    s = np.asarray(scores_eval)
    n = s.shape[1]
    total = 0.0
    for i in range(n):
        seen_reps = []
        for li, (m, lo, hi) in enumerate(levels):
            if reps[m] not in seen_reps:
                total += rep_cost_s(profile, reps[m], scenario,
                                    first_rep=not seen_reps)
                seen_reps.append(reps[m])
            total += infer_s[m]
            o = s[m, i]
            if lo is None or o <= lo or o >= hi:
                break
    return total / n


def spec_levels(space: CascadeSpace, i: int, p_low, p_high):
    """Decode cascade i into the ``levels`` format of simulate_cascade."""
    k, a, b = space.kind[i], space.i1[i], space.i2[i]
    nt = space.n_targets
    if k == KIND_SINGLE:
        return [(int(a), None, None)]
    if k == KIND_TWO:
        m1, t1 = divmod(int(a), nt)
        return [(m1, p_low[m1, t1], p_high[m1, t1]), (int(b), None, None)]
    m1, t1 = divmod(int(a), nt)
    m2, t2 = divmod(int(b), nt)
    return [(m1, p_low[m1, t1], p_high[m1, t1]),
            (m2, p_low[m2, t2], p_high[m2, t2]),
            (space.trusted, None, None)]
