"""ALC — area to the left of the (throughput vs accuracy) step curve
(paper §VII-A4). Dividing ALC by the accuracy range gives the average
frontier throughput; the ratio of two ALCs over the SAME range is the
speedup of one cascade set over another."""
from __future__ import annotations

import numpy as np

from repro.core.pareto import pareto_indices


def alc(acc, thr, lo: float, hi: float) -> float:
    """Step-interpolated area of max-throughput-at-accuracy>=a over
    [lo, hi]. Points form a step function: at accuracy a the attainable
    throughput is max{thr_i : acc_i >= a}; cascades below lo are ignored."""
    acc = np.asarray(acc, np.float64)
    thr = np.asarray(thr, np.float64)
    if len(acc) == 0 or hi <= lo:
        return 0.0
    idx = pareto_indices(acc, thr)          # acc desc, thr asc
    a_desc = acc[idx]
    t_desc = thr[idx]
    area = 0.0
    prev = lo
    # walk accuracy ascending: throughput is a non-increasing step in acc
    for a, t in zip(a_desc[::-1], t_desc[::-1]):
        if a <= prev:
            continue
        seg_hi = min(a, hi)
        if seg_hi > prev:
            area += (seg_hi - prev) * t
            prev = seg_hi
        if prev >= hi:
            break
    return area


def average_throughput(acc, thr, lo: float, hi: float) -> float:
    return alc(acc, thr, lo, hi) / (hi - lo) if hi > lo else 0.0


def speedup(acc_a, thr_a, acc_b, thr_b, lo=None, hi=None) -> float:
    """ALC(A)/ALC(B) over the smaller shared accuracy range
    (paper: 'choose the smallest said range')."""
    lo = max(np.min(acc_a), np.min(acc_b)) if lo is None else lo
    hi = min(np.max(acc_a), np.max(acc_b)) if hi is None else hi
    denom = alc(acc_b, thr_b, lo, hi)
    return alc(acc_a, thr_a, lo, hi) / denom if denom else float("inf")


def best_matching(acc, thr, target_acc: float):
    """Paper §VII-A4: vs a single classifier, pick the optimal cascade whose
    accuracy is higher than and closest to the target. Returns index or
    None."""
    acc = np.asarray(acc)
    ok = np.where(acc >= target_acc)[0]
    if len(ok) == 0:
        return None
    thr = np.asarray(thr)
    # among qualifying, frontier point with max throughput
    return int(ok[np.argmax(thr[ok])])
