"""The physical input-representation space F (paper §IV Def. 6, §V-B).

A Representation = (resolution, color) names one physical form of an image.
``apply_transform`` produces it from the raw full-resolution RGB image.
Downscaling uses area averaging (box filter) — exactly expressible as a
reshape-mean, which lowers to TPU-friendly reductions; the fused Pallas
kernel (kernels/image_transform.py) implements resize+channel+normalize in
one HBM->VMEM pass and is validated against this module.

Representations are the unit of data-handling cost (§VI): a cascade that
uses the same representation at two levels pays its load/transform cost
ONCE (core/costs.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import jax.numpy as jnp
import numpy as np

COLOR_REPS = ("rgb", "r", "g", "b", "gray")
_GRAY = np.array([0.299, 0.587, 0.114], np.float32)


@dataclass(frozen=True, order=True)
class Representation:
    resolution: int
    color: str  # COLOR_REPS

    @property
    def channels(self) -> int:
        return 3 if self.color == "rgb" else 1

    @property
    def values(self) -> int:
        """Input values per image = resolution^2 * channels (paper §VII-D)."""
        return self.resolution * self.resolution * self.channels

    @property
    def bytes(self) -> int:
        return self.values  # uint8 storage

    @property
    def name(self) -> str:
        return f"{self.resolution}x{self.resolution}_{self.color}"


def resize_area(img, out_hw: int):
    """Box-filter downscale (B,H,W,C) -> (B,out,out,C). H must be a
    multiple of out_hw (the paper's resolutions nest under our base)."""
    b, h, w, c = img.shape
    if h == out_hw:
        return img
    assert h % out_hw == 0 and w % out_hw == 0, (h, w, out_hw)
    f = h // out_hw
    img = img.reshape(b, out_hw, f, out_hw, f, c)
    return img.mean(axis=(2, 4))


def color_transform(img, color: str):
    """(B,H,W,3) -> (B,H,W,C') per the color representation."""
    if color == "rgb":
        return img
    if color == "gray":
        return (img * jnp.asarray(_GRAY)).sum(-1, keepdims=True)
    idx = {"r": 0, "g": 1, "b": 2}[color]
    return img[..., idx:idx + 1]


def apply_transform(img, rep: Representation):
    """Raw RGB float image in [0,1], (B,H,W,3) -> representation tensor."""
    out = resize_area(img, rep.resolution)
    return color_transform(out, rep.color)


def representation_space(resolutions: Iterable[int],
                         colors: Iterable[str] = COLOR_REPS
                         ) -> list[Representation]:
    return [Representation(r, c) for r in resolutions for c in colors]


# analytic per-image transform FLOPs/bytes (feeds core/costs.py)
def transform_cost(rep: Representation, base_hw: int) -> dict:
    read = base_hw * base_hw * 3          # bytes in (uint8)
    flops = base_hw * base_hw * 3         # box-filter adds
    if rep.color == "gray":
        flops += rep.resolution ** 2 * 3
    write = rep.bytes
    return {"flops": float(flops), "bytes": float(read + write)}
