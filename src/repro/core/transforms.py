"""The physical input-representation space F (paper §IV Def. 6, §V-B).

A Representation = (resolution, color) names one physical form of an image.
``apply_transform`` produces it from the raw full-resolution RGB image.
Downscaling uses area averaging (box filter) — exactly expressible as a
reshape-mean, which lowers to TPU-friendly reductions; the fused Pallas
kernel (kernels/image_transform.py) implements resize+channel+normalize in
one HBM->VMEM pass and is validated against this module.

Representations are the unit of data-handling cost (§VI): a cascade that
uses the same representation at two levels pays its load/transform cost
ONCE (core/costs.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import jax.numpy as jnp
import numpy as np

COLOR_REPS = ("rgb", "r", "g", "b", "gray")
_GRAY = np.array([0.299, 0.587, 0.114], np.float32)


@dataclass(frozen=True, order=True)
class Representation:
    resolution: int
    color: str  # COLOR_REPS

    @property
    def channels(self) -> int:
        return 3 if self.color == "rgb" else 1

    @property
    def values(self) -> int:
        """Input values per image = resolution^2 * channels (paper §VII-D)."""
        return self.resolution * self.resolution * self.channels

    @property
    def bytes(self) -> int:
        return self.values  # uint8 storage

    @property
    def name(self) -> str:
        return f"{self.resolution}x{self.resolution}_{self.color}"


def resize_area(img, out_hw: int):
    """Box-filter downscale (B,H,W,C) -> (B,out,out,C). H must be a
    multiple of out_hw (the paper's resolutions nest under our base)."""
    b, h, w, c = img.shape
    if h == out_hw:
        return img
    assert h % out_hw == 0 and w % out_hw == 0, (h, w, out_hw)
    f = h // out_hw
    img = img.reshape(b, out_hw, f, out_hw, f, c)
    return img.mean(axis=(2, 4))


def color_transform(img, color: str):
    """(B,H,W,3) -> (B,H,W,C') per the color representation."""
    if color == "rgb":
        return img
    if color == "gray":
        return (img * jnp.asarray(_GRAY)).sum(-1, keepdims=True)
    idx = {"r": 0, "g": 1, "b": 2}[color]
    return img[..., idx:idx + 1]


def apply_transform(img, rep: Representation):
    """Raw RGB float image in [0,1], (B,H,W,3) -> representation tensor."""
    out = resize_area(img, rep.resolution)
    return color_transform(out, rep.color)


def representation_space(resolutions: Iterable[int],
                         colors: Iterable[str] = COLOR_REPS
                         ) -> list[Representation]:
    return [Representation(r, c) for r in resolutions for c in colors]


# ------------------------------------------------- representation pyramid --
# Box filters nest: area-averaging base->r1->r2 equals base->r2 whenever the
# factors divide (the paper's resolution ladders all do).  Materializing the
# whole A x F grid's representations therefore never needs to touch the raw
# base image more than once — each resolution is derived from the nearest
# (smallest) already-materialized resolution, and every color representation
# of a resolution shares that one pooled RGB tensor.

@dataclass(frozen=True)
class PyramidStep:
    """Produce the ``resolution`` RGB level from the ``source`` level."""
    resolution: int
    source: int


def plan_pyramid(resolutions: Iterable[int], base_hw: int
                 ) -> list[PyramidStep]:
    """Progressive downscale plan over distinct resolutions <= base_hw.
    Each level is derived from the smallest already-materialized resolution
    it divides (base_hw is always materialized). Raises if some resolution
    cannot nest under base_hw at all."""
    steps: list[PyramidStep] = []
    avail = [base_hw]
    for r in sorted({int(r) for r in resolutions}, reverse=True):
        if r == base_hw:
            continue
        src = min((a for a in avail if a > r and a % r == 0),
                  default=None)
        if src is None:
            raise ValueError(f"resolution {r} does not nest under "
                             f"{sorted(avail)}")
        steps.append(PyramidStep(r, src))
        avail.append(r)
    return steps


def materialize_pyramid(img, resolutions: Iterable[int]):
    """One progressive pass: raw RGB (B,H,H,3) -> {resolution: RGB tensor}.
    Bit-identical to ``resize_area(img, r)`` from base when pixel values
    are exactly representable dyadics (raw uint8 counts or k/256 floats:
    sums stay exact in f32 and the nested factors are powers of two in
    every grid this repo uses); within 1 ulp otherwise."""
    base = img.shape[1]
    levels = {base: img}
    for step in plan_pyramid(resolutions, base):
        levels[step.resolution] = resize_area(levels[step.source],
                                              step.resolution)
    return levels


def materialize_representations(img, reps: Iterable[Representation]):
    """All representations a cascade (or the full A x F grid) needs, in one
    progressive pass: {Representation: tensor}. Color projections reuse the
    shared pooled RGB level of their resolution."""
    reps = list(reps)
    levels = materialize_pyramid(img, (r.resolution for r in reps))
    return {rep: color_transform(levels[rep.resolution], rep.color)
            for rep in set(reps)}


# analytic per-image transform FLOPs/bytes (feeds core/costs.py).
# source_hw prices the *incremental* pyramid transform: reading an already
# materialized source level instead of the full-size base image.
def transform_cost(rep: Representation, base_hw: int,
                   source_hw: int | None = None) -> dict:
    src = base_hw if source_hw is None else source_hw
    read = src * src * 3                  # bytes in (uint8)
    flops = src * src * 3                 # box-filter adds
    if rep.color == "gray":
        flops += rep.resolution ** 2 * 3
    write = rep.bytes
    return {"flops": float(flops), "bytes": float(read + write)}


def pyramid_bytes_moved(reps: Iterable[Representation], base_hw: int
                        ) -> float:
    """Total analytic bytes for materializing all reps progressively
    (vs. ``sum(transform_cost(r, base_hw)['bytes'])`` for the naive
    one-rep-at-a-time path)."""
    reps = list(reps)
    total = 0.0
    for step in plan_pyramid((r.resolution for r in reps), base_hw):
        total += step.source ** 2 * 3 + step.resolution ** 2 * 3
    for rep in set(reps):
        if rep.color == "rgb":
            continue                      # shares the pooled RGB level
        total += rep.resolution ** 2 * 3 + rep.bytes
    return total
