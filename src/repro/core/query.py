"""Mini relational layer over an image corpus (paper §IV).

A content-based query = metadata predicates (evaluated directly on stored
tuples) AND binary contains-object predicates (evaluated by a selected
cascade). The cascade's output materializes the predicate's virtual column
(paper: 'the output of a classifier model can be thought of as a virtual
column'), which is cached corpus-side so repeated queries are free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np


@dataclass
class Corpus:
    images: np.ndarray                       # (N, H, W, 3) float32 [0,1]
    metadata: Mapping[str, np.ndarray]       # column -> (N,)
    virtual_columns: dict = field(default_factory=dict)

    def __len__(self):
        return len(self.images)


@dataclass
class BinaryPredicate:
    """contains_object(<concept>) implemented by an executor closure
    mapping an image batch -> int labels (the selected cascade)."""
    concept: str
    executor: Callable[[np.ndarray], np.ndarray]


def evaluate_predicate(corpus: Corpus, pred: BinaryPredicate,
                       batch_size: int = 64) -> np.ndarray:
    """Populate (and cache) the predicate's virtual column."""
    if pred.concept in corpus.virtual_columns:
        return corpus.virtual_columns[pred.concept]
    n = len(corpus)
    out = np.zeros((n,), np.int32)
    for lo in range(0, n, batch_size):
        hi = min(lo + batch_size, n)
        chunk = corpus.images[lo:hi]
        if len(chunk) < batch_size:          # static-shape pad (TPU)
            pad = np.repeat(chunk[-1:], batch_size - len(chunk), axis=0)
            labels = np.asarray(pred.executor(
                np.concatenate([chunk, pad])))[:len(chunk)]
        else:
            labels = np.asarray(pred.executor(chunk))
        out[lo:hi] = labels
    corpus.virtual_columns[pred.concept] = out
    return out


def run_query(corpus: Corpus, *,
              metadata_eq: Mapping[str, object] | None = None,
              binary_preds: Sequence[BinaryPredicate] = ()) -> np.ndarray:
    """SELECT image_id WHERE meta = ... AND contains(a) AND contains(b).
    Metadata predicates are applied FIRST (cheap), binary predicates only
    on the surviving rows' virtual columns."""
    mask = np.ones(len(corpus), bool)
    for col, val in (metadata_eq or {}).items():
        mask &= np.asarray(corpus.metadata[col]) == val
    for pred in binary_preds:
        col = evaluate_predicate(corpus, pred)
        mask &= col.astype(bool)
    return np.where(mask)[0]
