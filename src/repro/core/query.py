"""Mini relational layer over an image corpus (paper §IV).

A content-based query = metadata predicates (evaluated directly on stored
tuples) AND binary contains-object predicates (evaluated by a selected
cascade). The cascade's output materializes the predicate's virtual
column (paper: 'the output of a classifier model can be thought of as a
virtual column'), cached corpus-side PARTIALLY: only the rows a query
actually had to evaluate are stored (int8, -1 = unknown), and later
queries pay only for rows no earlier query decided.

Predicate ordering here is fixed (metadata first, then the binary
predicates in the given order) and each binary predicate runs ONLY on
rows surviving everything before it. The planned path — cascade
selection per predicate, selectivity x cost ordering, shared-pyramid
chunk scan — is repro.engine (DESIGN.md §4); this module remains the
simple executor-closure reference the engine is tested against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np


@dataclass
class Corpus:
    images: np.ndarray                       # (N, H, W, 3) float32 [0,1]
    metadata: Mapping[str, np.ndarray]       # column -> (N,)
    virtual_columns: dict = field(default_factory=dict)

    def __len__(self):
        return len(self.images)


@dataclass
class BinaryPredicate:
    """contains_object(<concept>) implemented by an executor closure
    mapping an image batch -> int labels (the selected cascade)."""
    concept: str
    executor: Callable[[np.ndarray], np.ndarray]


def evaluate_predicate(corpus: Corpus, pred: BinaryPredicate,
                       batch_size: int = 64,
                       mask: np.ndarray | None = None) -> np.ndarray:
    """Populate the predicate's PARTIAL virtual column for the rows in
    ``mask`` (all rows when None) that are still unknown; rows other
    queries already decided are never re-run. Returns the full column
    (int8; -1 = never evaluated)."""
    n = len(corpus)
    col = corpus.virtual_columns.get(pred.concept)
    if col is None:
        col = np.full(n, -1, np.int8)
        corpus.virtual_columns[pred.concept] = col
    need = col == -1
    if mask is not None:
        need = need & np.asarray(mask, bool)
    ids = np.where(need)[0]
    for lo in range(0, len(ids), batch_size):
        sub = ids[lo:lo + batch_size]
        chunk = corpus.images[sub]
        if len(sub) < batch_size:            # static-shape pad (TPU)
            pad = np.repeat(chunk[-1:], batch_size - len(chunk), axis=0)
            labels = np.asarray(pred.executor(
                np.concatenate([chunk, pad])))[:len(sub)]
        else:
            labels = np.asarray(pred.executor(chunk))
        col[sub] = labels.astype(np.int8)
    return col


def run_query(corpus: Corpus, *,
              metadata_eq: Mapping[str, object] | None = None,
              binary_preds: Sequence[BinaryPredicate] = (),
              batch_size: int = 64) -> np.ndarray:
    """SELECT image_id WHERE meta = ... AND contains(a) AND contains(b).
    Metadata predicates are applied FIRST (cheap); each binary predicate
    is evaluated ONLY on the rows surviving the metadata filter and every
    earlier binary predicate — never on rows already eliminated."""
    mask = np.ones(len(corpus), bool)
    for col, val in (metadata_eq or {}).items():
        mask &= np.asarray(corpus.metadata[col]) == val
    for pred in binary_preds:
        if not mask.any():
            break
        col = evaluate_predicate(corpus, pred, batch_size, mask=mask)
        mask &= col == 1
    return np.where(mask)[0]
