"""Gradient compression for the data-parallel reduction (large-scale
distributed-optimization trick; DESIGN.md §6).

Two error-feedback compressors, composable in front of the optimizer:

* top-k sparsification with error feedback (Stich et al.): only the k
  largest-magnitude entries of (grad + residual) are transmitted; the
  untransmitted remainder becomes the next step's residual, so the scheme
  is contractive and unbiased-in-the-limit.
* int8 quantization with per-tensor scale + error feedback.

On a real fleet these run per-shard before the reduce; here the compress->
decompress round trip is applied in-graph so training quality effects and
compression ratios are measurable (tests/test_compression.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressor(NamedTuple):
    init: callable      # params -> residual state
    apply: callable     # (grads, state) -> (decompressed, state, stats)


def topk_compressor(k_frac: float = 0.01) -> Compressor:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.ravel()
        n = flat.shape[0]
        k = max(1, int(n * k_frac))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        sent = jnp.zeros_like(flat).at[idx].set(flat[idx])
        resid = flat - sent
        return sent.reshape(gf.shape), resid.reshape(gf.shape)

    def apply(grads, state):
        out = jax.tree.map(one, grads, state)
        dec = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        stats = {"ratio": k_frac}
        return dec, res, stats

    return Compressor(init, apply)


def int8_compressor() -> Compressor:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        dec = q.astype(jnp.float32) * scale
        return dec, gf - dec

    def apply(grads, state):
        out = jax.tree.map(one, grads, state)
        dec = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return dec, res, {"ratio": 0.25}

    return Compressor(init, apply)
