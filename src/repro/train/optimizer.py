"""Optimizers + LR schedules (optax is not available offline — own impl).

State trees mirror the params tree leaf-for-leaf, so the sharding policy's
name-suffix rules apply to optimizer state unchanged (ZeRO-style: m/v shard
exactly like their params).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def _cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
          grad_clip: float | None = 1.0) -> Optimizer:
    """lr: float or schedule fn(step)->float. m/v kept in float32."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        gnorm = None
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        step_lr = lr_fn(count)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_lr * step).astype(p.dtype), \
                m2, v2

        flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_state = {"m": new_m, "v": new_v, "count": count}
        return new_params, new_state, {"grad_norm": gnorm,
                                       "lr": jnp.float32(step_lr)}

    return Optimizer(init, update)


def sgd(lr, momentum=0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        step_lr = lr_fn(count)

        def upd(p, g, mu):
            mu2 = momentum * mu + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_lr * mu2).astype(p.dtype), mu2

        flat = jax.tree.map(upd, params, grads, state["mu"])
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu, "count": count}, {}

    return Optimizer(init, update)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return fn
