"""Elastic checkpointing (fault tolerance substrate; DESIGN.md §8).

Layout: <dir>/step_<n>/manifest.json + one .npy per pytree leaf.
The manifest records the flattened treedef paths, dtypes, shapes, step,
and the mesh shape at save time. Restore rebuilds the tree and
``jax.device_put``s every leaf against shardings derived from the
CURRENT mesh via the sharding policy — so a checkpoint taken on one mesh
restores onto a different mesh (elastic scale up/down), which is the
property tests exercise.

Writes are atomic (tmp dir + rename) so a failure mid-save never corrupts
the latest complete checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

# numpy round-trips ml_dtypes (bfloat16 etc.) as void; store a uint view
# and re-view on load using the manifest's dtype string.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir, step: int, tree, *, mesh=None, keep: int = 3):
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "mesh_shape":
                list(mesh.devices.shape) if mesh is not None else None}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype in _VIEW_AS:
            arr = arr.view(_VIEW_AS[dtype])
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "dtype": dtype,
             "shape": list(arr.shape)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted((int(p.name.split("_")[1]), p)
                   for p in ckpt_dir.glob("step_*"))
    for _, p in steps[:-keep]:
        shutil.rmtree(p)


class AsyncSaver:
    """Overlap checkpoint IO with training: device_get happens on the
    caller (cheap, avoids racing donated buffers), serialization + fsync
    run on a background thread. ``wait()`` joins the in-flight save;
    a new save waits for the previous one (at most one in flight)."""

    def __init__(self):
        self._thread = None

    def save(self, ckpt_dir, step: int, tree, *, mesh=None, keep: int = 3):
        import threading
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._thread = threading.Thread(
            target=save, args=(ckpt_dir, step, host_tree),
            kwargs=dict(mesh=mesh, keep=keep), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, tree_like, *, mesh=None,
            sharding_fn=None):
    """tree_like: a pytree (arrays or ShapeDtypeStructs) giving the target
    structure. sharding_fn(tree_like, mesh) -> shardings tree; defaults to
    the repo sharding policy. Leaves are device_put against the CURRENT
    mesh — elastic restore."""
    path = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat) == len(manifest["leaves"]), \
        f"leaf count mismatch: {len(flat)} vs {len(manifest['leaves'])}"
    shardings = None
    if mesh is not None:
        if sharding_fn is None:
            from repro.sharding.policy import param_shardings
            sharding_fn = param_shardings
        shardings = jax.tree_util.tree_flatten(
            sharding_fn(jax.tree_util.tree_unflatten(treedef, flat),
                        mesh))[0]
    out = []
    for i, meta in enumerate(manifest["leaves"]):
        arr = np.load(path / meta["file"])
        if meta["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        if shardings is not None:
            out.append(jax.device_put(arr, shardings[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
