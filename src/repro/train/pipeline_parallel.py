"""Pipeline parallelism over the 'pod' axis (DESIGN.md §6).

GPipe-style fill/drain schedule written with shard_map +
lax.ppermute: each pod stage holds half the layer stack; microbatch
activations flow stage->stage over ICI while both stages stay busy in the
steady state. This module proves PP viability on the multi-pod mesh (the
default multi-pod config composes 'pod' into data parallelism instead).

The schedule below runs forward-only pipelining for serving/eval or as a
building block; training composes it with jax.grad per microbatch chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, params_by_stage, x_micro, *, mesh,
                     axis: str = "pod"):
    """stage_fn(stage_params, h) -> h; params_by_stage: pytree whose
    leaves have a leading [n_stages] dim sharded over ``axis``;
    x_micro: (n_micro, mb, ...) microbatched inputs (replicated).
    Returns (n_micro, mb, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def spmd(stage_params, xs):
        stage = jax.lax.axis_index(axis)
        sp = jax.tree.map(lambda a: a[0], stage_params)  # local stage slice
        total = n_micro + n_stages - 1
        h = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            h_in, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            h_cur = jnp.where(stage == 0,
                              xs[mb_idx].astype(h_in.dtype), h_in)
            h_out = stage_fn(sp, h_cur)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = jnp.logical_and(stage == n_stages - 1,
                                   t >= n_stages - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[out_idx].set(h_out.astype(o.dtype)),
                lambda o: o, outs)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            h_next = jax.lax.ppermute(h_out, axis, perm)
            return (h_next, outs)

        _, outs = jax.lax.fori_loop(0, total, tick, (h, outs))
        # only the last stage holds real outputs; share them
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    other = tuple(a for a in mesh.axis_names if a != axis)
    in_specs = (jax.tree.map(lambda _: P(axis), params_by_stage),
                P())
    return _shard_map(spmd, mesh, in_specs, P())(params_by_stage, x_micro)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across JAX versions: jax.shard_map(check_vma=...) on new
    releases, jax.experimental.shard_map.shard_map(check_rep=...) on the
    installed one (replica checking off in both — `outs` is deliberately
    stage-varying until the final psum)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
