"""Fault-tolerant training runtime (DESIGN.md §6/§8).

The loop treats the jitted step as a pure function of (params, opt_state,
batch), which makes recovery trivial: on ANY step failure we restore the
last complete checkpoint and replay from its step. Features:

* periodic atomic checkpoints (train/checkpoint.py), elastic on restore;
* retry-with-restore on step failure (bounded retries, exponential
  backoff hook for real fleets);
* failure injection (``inject_failure_at``) for tests/drills;
* straggler detection: per-step wall-time EMA + z-score; flagged steps are
  logged and counted — on a real fleet this signal feeds the scheduler to
  re-shard around slow hosts, here the detector logic itself is the
  deliverable (unit-tested);
* pluggable gradient-compression (wired inside the step builder).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt


@dataclass
class StragglerDetector:
    alpha: float = 0.2
    z_thresh: float = 3.0
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EMA
            self.mean = dt if self.n == 1 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        std = max(np.sqrt(self.var), 1e-9)
        z = (dt - self.mean) / std
        slow = z > self.z_thresh
        if slow:
            self.flagged.append((step, dt, float(z)))
        else:  # don't let stragglers poison the baseline
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var \
                + self.alpha * (dt - self.mean) ** 2
        return slow


@dataclass
class RuntimeConfig:
    ckpt_dir: str
    ckpt_every: int = 20
    max_retries: int = 3
    keep: int = 3
    async_save: bool = False   # overlap checkpoint IO with training


class TrainRuntime:
    def __init__(self, step_fn: Callable, cfg: RuntimeConfig, *,
                 mesh=None, log: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.cfg = cfg
        self.mesh = mesh
        self.log = log
        self.straggler = StragglerDetector()
        self.inject_failure_at: set[int] = set()
        self._injected: set[int] = set()
        self.recoveries = 0
        self._saver = ckpt.AsyncSaver() if cfg.async_save else None

    def _save(self, step, state):
        if self._saver is not None:
            self._saver.save(self.cfg.ckpt_dir, step, state,
                             mesh=self.mesh, keep=self.cfg.keep)
        else:
            ckpt.save(self.cfg.ckpt_dir, step, state, mesh=self.mesh,
                      keep=self.cfg.keep)

    def _maybe_fail(self, step: int):
        if step in self.inject_failure_at and step not in self._injected:
            self._injected.add(step)
            raise RuntimeError(f"injected failure at step {step}")

    def run(self, params, opt_state, batches: Callable[[int], dict],
            *, start_step: int = 0, num_steps: int = 100):
        """batches(step) -> batch dict. Returns (params, opt_state,
        history)."""
        state = (params, opt_state)
        step = start_step
        # resume from the newest checkpoint if one exists
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is not None and last > step:
            state = ckpt.restore(self.cfg.ckpt_dir, last, state,
                                 mesh=self.mesh)
            step = last
            self.log(f"resumed from checkpoint step {last}")
        history = []
        retries = 0
        while step < num_steps:
            try:
                self._maybe_fail(step)
                t0 = time.perf_counter()
                p, o, metrics = self.step_fn(state[0], state[1],
                                             batches(step))
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                slow = self.straggler.observe(step, dt)
                if slow:
                    self.log(f"straggler: step {step} took {dt:.3f}s")
                state = (p, o)
                history.append({"step": step, "dt": dt,
                                **{k: float(v) for k, v in
                                   metrics.items() if v is not None}})
                step += 1
                retries = 0
                if step % self.cfg.ckpt_every == 0:
                    self._save(step, state)
            except Exception as e:  # noqa: BLE001 — recovery is the point
                retries += 1
                self.recoveries += 1
                self.log(f"step {step} failed ({e}); "
                         f"recovery {retries}/{self.cfg.max_retries}")
                if retries > self.cfg.max_retries:
                    raise
                if self._saver is not None:
                    self._saver.wait()   # don't restore past an in-flight save
                last = ckpt.latest_step(self.cfg.ckpt_dir)
                if last is not None:
                    state = ckpt.restore(self.cfg.ckpt_dir, last, state,
                                         mesh=self.mesh)
                    step = last
        if self._saver is not None:
            self._saver.wait()
        ckpt.save(self.cfg.ckpt_dir, step, state, mesh=self.mesh,
                  keep=self.cfg.keep)
        return state[0], state[1], history
