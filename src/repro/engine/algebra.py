"""Relational query algebra over the scan engines (DESIGN.md §15).

The planner (engine/planner.py) optimizes CONJUNCTIVE predicate chains —
Tahoma's query model. This module generalizes the query surface to full
boolean expression trees plus cross-corpus temporal joins, as a layer
ABOVE the existing engines rather than a new executor:

* **Logical nodes** — ``Pred`` / ``And`` / ``Or`` / ``Not`` compose
  arbitrarily; ``Join(left, right, delta_t)`` (root only) asks for frame
  pairs from two corpora within ``delta_t`` of each other that each
  satisfy their side's tree ("cam A and cam B both see X within Δt").

* **Normalization** — ``normalize`` rewrites to negation normal form:
  double negations cancel, De Morgan pushes every ``Not`` down to a
  leaf, same-op children flatten. A negated LEAF is executable: the
  scan records the cascade's label for every candidate row into the
  engine's ``VirtualColumnStore`` int8 column, and the decided-**0**
  rows of that column are exactly ¬Pred — so NOT costs one ordinary
  cascade evaluation, shares its virtual column with the positive
  predicate, and stays bit-exact.

* **Cost-based rewriting** — every plan node carries an estimated
  selectivity (P(true), independence across leaves) and an expected
  cost per candidate row derived from the same ``DecomposedCost`` /
  ``estimate_selectivity`` machinery the conjunctive planner uses.
  Child ordering short-circuits: AND children by the classical rank
  cost/(1−sel) ascending; OR children by the INVERTED rank cost/sel
  ascending — an OR branch stops on the first TRUE, so the most
  selective (rarely-true) branch belongs LAST (by De Morgan an OR chain
  is an AND chain over complements: rank c/(1−(1−s)) = c/s; proof
  sketch in DESIGN.md §15.2). Small fan-outs are ordered exhaustively
  against the exact chain-cost function, which also prices
  shared-pyramid savings inside runs of positive leaves. Joins choose
  the cheap side first and push the temporal window down as a
  prefilter on the expensive side (§15.3).

* **Execution** — ``execute_tree`` lowers each maximal run of positive
  leaves under an AND onto ONE ``ScanEngine``/``ShardedScanEngine``
  ``execute`` call (shared pyramid, lazy materialization, virtual
  columns — all reused), and combines branch survivor sets with numpy
  mask algebra: AND threads survivors left-to-right, OR evaluates each
  branch only on rows no earlier branch accepted. Per-row label
  independence makes every evaluation order return bit-identical row
  sets (differential-tested against ``naive_tree_rows``, the per-row
  oracle, in tests/test_algebra.py). ``execute_join`` evaluates the
  planned build side, prefilters the probe side to rows within
  ``delta_t`` of a surviving build timestamp (semantics-preserving:
  rows outside every window can never join), then verifies pairs with
  a temporal hash join on binned timestamps.

``TreePlan.explain`` / ``JoinPlan.explain`` render the annotated
relational-algebra tree — per-node estimated cost, selectivity and
cardinality, and (after execution) actual row counts next to the
estimates.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.costs import DecomposedCost
from repro.core.selector import select
from repro.engine.scan import CompiledCascade, naive_scan


# ------------------------------------------------------ logical nodes ----
@dataclass(frozen=True)
class Pred:
    """contains_object(<concept>) leaf with the user's constraint."""
    concept: str
    min_accuracy: float | None = None
    min_throughput: float | None = None


@dataclass(frozen=True)
class Not:
    child: object


class _NaryOp:
    __slots__ = ("children",)

    def __init__(self, *children):
        if not children:
            raise ValueError(f"{type(self).__name__} needs >= 1 child")
        self.children = tuple(children)

    def __repr__(self):
        inner = ", ".join(map(repr, self.children))
        return f"{type(self).__name__}({inner})"

    def __eq__(self, other):
        return type(other) is type(self) and other.children == self.children

    def __hash__(self):
        return hash((type(self).__name__, self.children))


class And(_NaryOp):
    """Variadic conjunction."""


class Or(_NaryOp):
    """Variadic disjunction."""


@dataclass(frozen=True)
class Join:
    """Cross-corpus temporal join (ROOT node only): pairs (a, b) with a
    from the left corpus satisfying ``left``, b from the right corpus
    satisfying ``right``, and |t_a − t_b| <= delta_t on the named
    metadata timestamp columns."""
    left: object
    right: object
    delta_t: float
    left_time: str = "t"
    right_time: str = "t"


# ------------------------------------------------------- normalization ---
def normalize(tree):
    """Negation normal form: double negations cancel, De Morgan pushes
    NOT to the leaves, nested same-op children flatten, single-child
    And/Or collapse. Pure boolean-algebra rewrites — row-set preserving
    (property-tested in tests/test_algebra.py). Idempotent."""
    if isinstance(tree, Pred):
        return tree
    if isinstance(tree, Not):
        inner = tree.child
        if isinstance(inner, Not):                      # ¬¬x = x
            return normalize(inner.child)
        if isinstance(inner, And):                      # ¬(a∧b) = ¬a∨¬b
            return normalize(Or(*[Not(c) for c in inner.children]))
        if isinstance(inner, Or):                       # ¬(a∨b) = ¬a∧¬b
            return normalize(And(*[Not(c) for c in inner.children]))
        if isinstance(inner, Pred):
            return tree
        raise TypeError(f"cannot negate {inner!r}")
    if isinstance(tree, (And, Or)):
        cls = type(tree)
        flat = []
        for c in tree.children:
            c = normalize(c)
            if type(c) is cls:
                flat.extend(c.children)
            else:
                flat.append(c)
        return flat[0] if len(flat) == 1 else cls(*flat)
    if isinstance(tree, Join):
        raise TypeError("Join may only appear at the root of a query "
                        "tree (plan_expression handles it there)")
    raise TypeError(f"not an expression node: {tree!r}")


# ----------------------------------------------------------- plan tree ---
@dataclass
class PlanNode:
    """One annotated relational-algebra node. ``est_*`` are planner
    estimates (per candidate row); ``rows_in``/``rows_out``/``seconds``
    are actuals filled in by ``execute_tree``."""
    op: str                                  # 'pred' | 'and' | 'or'
    children: list = field(default_factory=list)
    # pred leaves
    cascade: CompiledCascade | None = None
    negated: bool = False
    selection: object | None = None
    description: str = ""
    decomposed: DecomposedCost | None = None
    index_cached: float = 0.0    # fraction answered from seeded columns
    # annotations
    est_sel: float = 1.0
    est_cost: float = 0.0        # expected seconds per candidate row
    # actuals
    rows_in: int | None = None
    rows_out: int | None = None
    seconds: float | None = None

    def clear_actuals(self) -> None:
        self.rows_in = self.rows_out = self.seconds = None
        for c in self.children:
            c.clear_actuals()

    def leaves(self) -> list["PlanNode"]:
        if self.op == "pred":
            return [self]
        return [l for c in self.children for l in c.leaves()]


@dataclass
class TreePlan:
    """Physical plan for one boolean expression tree over ONE corpus.
    The tree-algebra sibling of planner.PhysicalPlan; ``explain()`` is
    the tree renderer the conjunctive plan's EXPLAIN grew into."""
    scenario: str
    metadata_eq: dict
    root: PlanNode
    meta_selectivity: float | None = None
    index: object | None = None     # engine/ingest.CandidateIndex
    optimized: bool = True

    @property
    def cascades(self) -> list:
        """Distinct cascades, in leaf order."""
        seen, out = set(), []
        for leaf in self.root.leaves():
            if leaf.cascade.key not in seen:
                seen.add(leaf.cascade.key)
                out.append(leaf.cascade)
        return out

    def cascade_map(self) -> dict:
        """concept -> cascade, for the naive per-row oracle. Refuses
        trees that bind one concept to two different cascades (the
        oracle's mask cache is keyed by concept)."""
        out = {}
        for leaf in self.root.leaves():
            prev = out.setdefault(leaf.cascade.concept, leaf.cascade)
            if prev.key != leaf.cascade.key:
                raise ValueError(
                    f"concept {leaf.cascade.concept!r} planned with two "
                    "different cascades; per-concept oracle undefined")
        return out

    def clear_actuals(self) -> None:
        self.root.clear_actuals()

    def estimated_cost_per_row(self) -> float:
        return self.root.est_cost

    def explain(self, n_rows: int | None = None) -> str:
        lines = [f"ALGEBRA PLAN  scenario={self.scenario}"
                 f"  metadata_eq={self.metadata_eq or {}}"
                 + ("" if self.optimized else "  [UNOPTIMIZED]")]
        if self.meta_selectivity is not None:
            lines.append(f"  metadata selectivity ~{self.meta_selectivity:.2f}")
        if self.index is not None:
            lines.append("  index: seeds engine store with exact "
                         "decided labels (prefilter unsound under "
                         "OR/NOT — seeding only)")
        est_in = float(n_rows) if n_rows is not None else (
            float(self.root.rows_in) if self.root.rows_in is not None
            else None)
        _render_node(self.root, lines, "", "", est_in)
        return "\n".join(lines)


def _node_label(node: PlanNode) -> str:
    if node.op == "pred":
        neg = "NOT " if node.negated else ""
        return f"{neg}contains({node.cascade.concept})"
    return node.op.upper()


def _render_node(node: PlanNode, lines: list, pad: str, branch: str,
                 est_in: float | None) -> None:
    card = ""
    if est_in is not None:
        card = f"  rows~{est_in:.0f}->{est_in * node.est_sel:.0f}"
    act = ""
    if node.rows_in is not None:
        act = f"  actual {node.rows_in}->{node.rows_out}"
    detail = (f"  [sel={node.est_sel:.2f}"
              f" cost/row={node.est_cost * 1e6:.1f}us{card}{act}]")
    extra = ""
    if node.op == "pred" and node.description:
        extra = f"  {node.description}"
        if node.index_cached:
            extra += f"  (index answers {node.index_cached:.0%})"
    lines.append(f"{pad}{branch}{_node_label(node)}{detail}{extra}")
    child_pad = pad + ("" if not branch else
                       ("   " if branch.startswith("└") else "│  "))
    # estimated input cardinality per child under short-circuit order
    frac = 1.0
    for i, c in enumerate(node.children):
        child_in = None if est_in is None else est_in * frac
        glyph = "└─ " if i == len(node.children) - 1 else "├─ "
        _render_node(c, lines, child_pad, glyph, child_in)
        frac *= c.est_sel if node.op == "and" else (1.0 - c.est_sel)


@dataclass
class JoinPlan:
    """Root-level cross-corpus temporal join plan: two TreePlans, the
    window, and the cost-chosen build side (evaluated first, its
    surviving timestamps prefilter the probe side)."""
    left: TreePlan
    right: TreePlan
    delta_t: float
    time_cols: tuple                 # (left_col, right_col)
    build_side: int                  # 0 = left evaluated first
    est_pairs: float = 0.0
    est_cost_s: float = 0.0          # expected total seconds, both sides
    window_kept: int | None = None   # probe candidates after pushdown
    actual_pairs: int | None = None

    def explain(self, n_rows: tuple | None = None) -> str:
        build = "left" if self.build_side == 0 else "right"
        act = ("" if self.actual_pairs is None
               else f"  actual pairs={self.actual_pairs}")
        kept = ("" if self.window_kept is None
                else f"  probe window kept={self.window_kept}")
        lines = [
            f"JOIN  |t_left - t_right| <= {self.delta_t:g}"
            f"  on ({self.time_cols[0]}, {self.time_cols[1]})",
            f"  build side={build} (cheap side first)"
            f"  est pairs~{self.est_pairs:.0f}"
            f"  est cost~{self.est_cost_s * 1e3:.1f}ms{kept}{act}",
        ]
        nl, nr = (None, None) if n_rows is None else n_rows
        lines.append("├─ LEFT")
        lines.extend("│  " + ln for ln in
                     self.left.explain(nl).splitlines())
        lines.append("└─ RIGHT")
        lines.extend("   " + ln for ln in
                     self.right.explain(nr).splitlines())
        return "\n".join(lines)


# -------------------------------------------------------- plan builder ---
def _meta_sel(metadata_eq, metadata) -> float | None:
    if not metadata_eq or metadata is None:
        return None
    mask = np.ones(len(next(iter(metadata.values()))), bool)
    for col, val in metadata_eq.items():
        mask &= np.asarray(metadata[col]) == val
    return float(mask.mean())


def _plan_leaf(systems: Mapping, pred: Pred, negated: bool, *,
               scenario: str, max_level: int, index) -> PlanNode:
    system = systems[pred.concept]
    space = system.cascade_space(scenario, max_level=max_level)
    sel = select(space, min_accuracy=pred.min_accuracy,
                 min_throughput=pred.min_throughput)
    casc = system.compiled_cascade(space, sel.index, concept=pred.concept)
    dec = system.decomposed_cost(space, sel.index, scenario,
                                 dense_levels=True)
    frac, cost, cached = casc.selectivity, dec.total_s, 0.0
    if index is not None:
        eval_frac, frac = index.planning_stats(casc.key, frac,
                                               prefilter=False)
        cached = 1.0 - eval_frac
        cost *= eval_frac
    return PlanNode(
        "pred", cascade=casc, negated=negated, selection=sel,
        description=space.describe(sel.index, system.bank.names,
                                   system.targets),
        decomposed=dec, index_cached=cached,
        est_sel=(1.0 - frac) if negated else frac, est_cost=cost)


def _chain_cost(op: str, ordered: Sequence[PlanNode]) -> float:
    """Expected seconds per candidate row of evaluating ``ordered``
    children with short-circuiting. AND stops at the first FALSE (later
    children pay only on survivors, Π sel); OR stops at the first TRUE
    (later children pay only on rejects, Π (1−sel)). Runs of positive
    leaves under an AND execute as one engine call sharing a pyramid,
    so their representation charges are priced marginally
    (DecomposedCost.marginal_s); any other child is its own engine call
    and the materialized-level set resets."""
    total, p = 0.0, 1.0
    mat: set = set()
    for node in ordered:
        in_run = (op == "and" and node.op == "pred" and not node.negated
                  and node.decomposed is not None)
        if in_run:
            c = node.decomposed.marginal_s(mat) * (1.0 - node.index_cached)
            mat = mat | node.decomposed.levels
        else:
            c, mat = node.est_cost, set()
        total += p * c
        p *= node.est_sel if op == "and" else (1.0 - node.est_sel)
    return total


_EXHAUSTIVE_LIMIT = 6


def order_children(op: str, kids: list) -> list:
    """Cost-based short-circuit ordering of one node's children. Small
    fan-outs are ordered exhaustively against ``_chain_cost`` (which
    also sees shared-pyramid savings inside positive-leaf runs); larger
    ones greedily by rank — AND: cost/(1−sel) ascending (the classical
    conjunctive rank), OR: cost/sel ascending (the INVERTED rank: a
    branch short-circuits on TRUE, so the most selective branch goes
    last — DESIGN.md §15.2)."""
    if len(kids) <= _EXHAUSTIVE_LIMIT:
        best = min(itertools.permutations(range(len(kids))),
                   key=lambda p: (_chain_cost(op, [kids[i] for i in p]), p))
        return [kids[i] for i in best]

    def rank(node):
        miss = (1.0 - node.est_sel) if op == "and" else node.est_sel
        r = node.est_cost / miss if miss > 0 else float("inf")
        return (r, node.est_cost)
    return sorted(kids, key=rank)


def _plan_node(systems, tree, *, scenario, max_level, index,
               optimize) -> PlanNode:
    if isinstance(tree, Pred):
        return _plan_leaf(systems, tree, False, scenario=scenario,
                          max_level=max_level, index=index)
    if isinstance(tree, Not):        # NNF: child is a Pred
        return _plan_leaf(systems, tree.child, True, scenario=scenario,
                          max_level=max_level, index=index)
    op = "and" if isinstance(tree, And) else "or"
    kids = [_plan_node(systems, c, scenario=scenario, max_level=max_level,
                       index=index, optimize=optimize)
            for c in tree.children]
    if optimize:
        kids = order_children(op, kids)
    sels = [k.est_sel for k in kids]
    prod = float(np.prod(sels)) if op == "and" \
        else float(np.prod([1.0 - s for s in sels]))
    return PlanNode(op, children=kids,
                    est_sel=prod if op == "and" else 1.0 - prod,
                    est_cost=_chain_cost(op, kids))


def plan_expression(systems, tree, *, scenario: str = "CAMERA",
                    max_level: int = 3, metadata=None, metadata_eq=None,
                    index=None, optimize: bool = True):
    """Compile a boolean expression tree (or a root ``Join``) into an
    annotated, cost-ordered physical plan. ``systems``: concept ->
    TahomaSystem (shared by both join sides). For a ``Join`` root,
    ``metadata``/``metadata_eq`` are (left, right) pairs and the
    metadata must hold the join's timestamp columns; the cheap side
    (estimated per-row cost × candidate rows) becomes the build side.
    ``index`` (engine/ingest.CandidateIndex) conditions leaf cost and
    selectivity on its decided columns and makes ``execute_tree`` seed
    the engine store — exact labels only, no row pruning (pruning
    decided-0 rows is unsound under OR/NOT). ``optimize=False`` keeps
    the user's child order and makes ``execute_tree`` evaluate every
    child on its node's full input (the benchmark baseline)."""
    if isinstance(tree, Join):
        metas = metadata or (None, None)     # {} (the QuerySpec
        eqs = metadata_eq or (None, None)     # default) means absent
        left = plan_expression(systems, tree.left, scenario=scenario,
                               max_level=max_level, metadata=metas[0],
                               metadata_eq=eqs[0], index=None,
                               optimize=optimize)
        right = plan_expression(systems, tree.right, scenario=scenario,
                                max_level=max_level, metadata=metas[1],
                                metadata_eq=eqs[1], index=None,
                                optimize=optimize)
        return _plan_join(tree, left, right, metas, optimize=optimize)
    root = _plan_node(systems, normalize(tree), scenario=scenario,
                      max_level=max_level, index=index, optimize=optimize)
    return TreePlan(scenario, dict(metadata_eq or {}), root,
                    _meta_sel(metadata_eq, metadata), index=index,
                    optimized=optimize)


def _side_stats(plan: TreePlan, meta, time_col: str):
    t = np.asarray(meta[time_col], np.float64)
    n = len(t)
    meta_frac = plan.meta_selectivity if plan.meta_selectivity is not None \
        else 1.0
    cand = n * meta_frac
    surv = cand * plan.root.est_sel
    span = max(float(t.max() - t.min()), 1.0) if n else 1.0
    return cand, surv, span, cand * plan.root.est_cost


def _plan_join(tree: Join, left: TreePlan, right: TreePlan, metas, *,
               optimize: bool) -> JoinPlan:
    if metas[0] is None or metas[1] is None:
        raise ValueError("Join planning needs (left, right) metadata "
                         "holding the timestamp columns")
    cl, sl, spl, costl = _side_stats(left, metas[0], tree.left_time)
    cr, sr, spr, costr = _side_stats(right, metas[1], tree.right_time)
    w = 2.0 * float(tree.delta_t)
    # pushdown: after the build side survives, the probe side only
    # evaluates rows inside some window — expected kept fraction
    cov_r = min(1.0, sl * w / spr)     # probe=right if build=left
    cov_l = min(1.0, sr * w / spl)
    cost_left_first = costl + costr * cov_r
    cost_right_first = costr + costl * cov_l
    build = 0 if (cost_left_first <= cost_right_first or not optimize) \
        else 1
    est_pairs = sl * min(1.0, w / spr) * sr if sr else 0.0
    return JoinPlan(left, right, float(tree.delta_t),
                    (tree.left_time, tree.right_time), build,
                    est_pairs=est_pairs,
                    est_cost_s=min(cost_left_first, cost_right_first))


def plan_from_cascades(tree, cascades: Mapping, *, metadata=None,
                       metadata_eq=None, index=None,
                       optimize: bool = True) -> TreePlan:
    """Build a TreePlan (or JoinPlan for a ``Join`` root) from
    pre-compiled cascades (concept -> CompiledCascade) instead of
    trained systems — leaf estimates come from the cascade's own
    ``cost_s``/``selectivity`` fields. The tests' and benchmarks'
    entry point; ``plan_expression`` is the trained-system twin. For a
    Join root, ``metadata``/``metadata_eq`` are (left, right) pairs."""
    if isinstance(tree, Join):
        metas = metadata or (None, None)     # {} (the QuerySpec
        eqs = metadata_eq or (None, None)     # default) means absent
        left = plan_from_cascades(tree.left, cascades, metadata=metas[0],
                                  metadata_eq=eqs[0], optimize=optimize)
        right = plan_from_cascades(tree.right, cascades,
                                   metadata=metas[1], metadata_eq=eqs[1],
                                   optimize=optimize)
        return _plan_join(tree, left, right, metas, optimize=optimize)

    def build(t) -> PlanNode:
        if isinstance(t, (Pred, Not)):
            pred = t.child if isinstance(t, Not) else t
            casc = cascades[pred.concept]
            frac, cost, cached = casc.selectivity, casc.cost_s, 0.0
            if index is not None:
                eval_frac, frac = index.planning_stats(casc.key, frac,
                                                       prefilter=False)
                cached = 1.0 - eval_frac
                cost *= eval_frac
            neg = isinstance(t, Not)
            return PlanNode("pred", cascade=casc, negated=neg,
                            index_cached=cached,
                            est_sel=(1.0 - frac) if neg else frac,
                            est_cost=cost)
        op = "and" if isinstance(t, And) else "or"
        kids = [build(c) for c in t.children]
        if optimize:
            kids = order_children(op, kids)
        sels = [k.est_sel for k in kids]
        prod = float(np.prod(sels)) if op == "and" \
            else float(np.prod([1.0 - s for s in sels]))
        return PlanNode(op, children=kids,
                        est_sel=prod if op == "and" else 1.0 - prod,
                        est_cost=_chain_cost(op, kids))

    root = build(normalize(tree))
    return TreePlan("CAMERA", dict(metadata_eq or {}), root,
                    _meta_sel(metadata_eq, metadata), index=index,
                    optimized=optimize)


# ------------------------------------------------------------ executor ---
@dataclass
class AlgebraResult:
    indices: np.ndarray
    plan: TreePlan
    engine_calls: int = 0
    rows_evaluated: int = 0       # cascade rows actually run (not cached)
    seconds: float = 0.0


@dataclass
class JoinResult:
    pairs: np.ndarray             # (n, 2) int64 (left_row, right_row)
    plan: JoinPlan
    left: AlgebraResult | None = None
    right: AlgebraResult | None = None
    seconds: float = 0.0


def _count(ctr: dict, res) -> None:
    ctr["calls"] += 1
    stats = getattr(res, "stats", None)
    stages = getattr(stats, "stages", None) or []
    ctr["rows"] += int(sum(s.rows_evaluated for s in stages))


def _scan_run(engine, leaves: list, ids: np.ndarray, ctr: dict) \
        -> np.ndarray:
    """One engine call for a maximal run of positive leaves: shared
    pyramid, masked later stages, virtual columns — the existing
    conjunctive hot path."""
    t0 = time.perf_counter()
    if not len(ids):
        out = ids
        stage_rows = [0] * (len(leaves) + 1)
    else:
        res = engine.execute([l.cascade for l in leaves], None,
                             survivors=ids)
        _count(ctr, res)
        out = np.asarray(res.indices, np.int64)
        stages = res.stats.stages
        stage_rows = [s.rows_in for s in stages] + [len(out)]
    dt = time.perf_counter() - t0
    for j, leaf in enumerate(leaves):
        leaf.rows_in, leaf.rows_out = stage_rows[j], stage_rows[j + 1]
        leaf.seconds = dt if j == 0 else 0.0
    return out


def _eval_leaf(engine, node: PlanNode, ids: np.ndarray, ctr: dict) \
        -> np.ndarray:
    if not len(ids):
        return ids
    res = engine.execute([node.cascade], None, survivors=ids)
    _count(ctr, res)
    if not node.negated:
        return np.asarray(res.indices, np.int64)
    # the scan decided EVERY candidate row (evaluated or cache-served);
    # the cascade's int8 virtual column now holds 0 exactly on ¬Pred
    return engine.store.rows_with_label(node.cascade.key, ids, 0)


def _run_groups(children: list) -> list:
    """Maximal runs of consecutive positive pred leaves (one engine
    call each); every other child is its own singleton group."""
    groups, run = [], []
    for c in children:
        if c.op == "pred" and not c.negated:
            run.append(c)
        else:
            if run:
                groups.append(run)
                run = []
            groups.append([c])
    if run:
        groups.append(run)
    return groups


def _eval_node(engine, node: PlanNode, ids: np.ndarray, opt: bool,
               ctr: dict) -> np.ndarray:
    t0 = time.perf_counter()
    node.rows_in = int(len(ids))
    if node.op == "pred":
        out = _eval_leaf(engine, node, ids, ctr)
    elif node.op == "and":
        if opt:
            cur = ids
            for group in _run_groups(node.children):
                if len(group) > 1 or (group[0].op == "pred"
                                      and not group[0].negated):
                    cur = _scan_run(engine, group, cur, ctr)
                else:
                    cur = _eval_node(engine, group[0], cur, opt, ctr)
            out = cur
        else:
            out = ids
            for c in node.children:
                out = np.intersect1d(out,
                                     _eval_node(engine, c, ids, opt, ctr))
    elif node.op == "or":
        if opt:
            remaining, hits = ids, []
            for c in node.children:
                acc = _eval_node(engine, c, remaining, opt, ctr)
                hits.append(acc)
                remaining = np.setdiff1d(remaining, acc)
            out = (np.sort(np.concatenate(hits)) if hits
                   else ids[:0])
        else:
            out = ids[:0]
            for c in node.children:
                out = np.union1d(out,
                                 _eval_node(engine, c, ids, opt, ctr))
    else:
        raise ValueError(f"unknown plan op {node.op!r}")
    out = np.sort(np.asarray(out, np.int64))
    node.rows_out = int(len(out))
    node.seconds = time.perf_counter() - t0
    return out


def execute_tree(engine, plan: TreePlan, *, optimize: bool | None = None,
                 within: np.ndarray | None = None) -> AlgebraResult:
    """Evaluate a TreePlan against a scan engine (serial or sharded).
    ``optimize`` overrides the plan's mode: True short-circuits (each
    child sees only the rows earlier siblings left undecided) and
    lowers positive-leaf runs onto single engine calls; False evaluates
    every child on its node's full input and mask-combines at the end
    (the unoptimized baseline). Both return bit-identical row sets —
    per-row label independence. ``within`` restricts the candidate rows
    (the join executor's window pushdown). Fills per-node actuals the
    EXPLAIN renderer shows."""
    opt = plan.optimized if optimize is None else optimize
    plan.clear_actuals()
    t0 = time.perf_counter()
    if plan.index is not None:
        plan.index.seed_store(engine.store, exact=True)
    ids = np.where(engine.metadata_mask(plan.metadata_eq))[0] \
        .astype(np.int64)
    if within is not None:
        ids = np.intersect1d(ids, np.asarray(within, np.int64))
    ctr = {"calls": 0, "rows": 0}
    out = _eval_node(engine, plan.root, ids, opt, ctr)
    return AlgebraResult(out, plan, ctr["calls"], ctr["rows"],
                         time.perf_counter() - t0)


# ------------------------------------------------------- temporal join ---
def temporal_hash_join(ids_left, t_left, ids_right, t_right,
                       delta: float) -> np.ndarray:
    """Exact band join |t_l − t_r| <= delta as a hash join on binned
    timestamps: the smaller side hashes into width-``delta`` buckets,
    the larger probes its own bucket ± 1 (a window of width 2·delta
    spans at most 3 consecutive buckets) and verifies the band exactly.
    Returns (n, 2) int64 (left_row, right_row) pairs, lexicographically
    sorted — bit-comparable with the naive nested loop."""
    ids_l = np.asarray(ids_left, np.int64)
    ids_r = np.asarray(ids_right, np.int64)
    tl = np.asarray(t_left, np.float64)
    tr = np.asarray(t_right, np.float64)
    if not len(ids_l) or not len(ids_r):
        return np.empty((0, 2), np.int64)
    width = float(delta) if delta > 0 else 1.0
    flip = len(ids_l) > len(ids_r)          # hash the smaller side
    b_ids, b_t = (ids_r, tr) if flip else (ids_l, tl)
    p_ids, p_t = (ids_l, tl) if flip else (ids_r, tr)
    table: dict = {}
    for i, t in zip(b_ids, b_t[b_ids]):
        table.setdefault(int(np.floor(t / width)), []).append(i)
    out = []
    for j, t in zip(p_ids, p_t[p_ids]):
        k = int(np.floor(t / width))
        for kk in (k - 1, k, k + 1):
            for i in table.get(kk, ()):
                ti = b_t[i]
                if abs(t - ti) <= delta:
                    out.append((j, i) if flip else (i, j))
    if not out:
        return np.empty((0, 2), np.int64)
    pairs = np.asarray(out, np.int64)
    order = np.lexsort((pairs[:, 1], pairs[:, 0]))
    return pairs[order]


def execute_join(engines, plan: JoinPlan, *,
                 optimize: bool | None = None) -> JoinResult:
    """Evaluate a JoinPlan against (left_engine, right_engine). With
    optimization, the planned build (cheap) side runs first and the
    probe side's candidates are pushed down to rows within ``delta_t``
    of a surviving build timestamp — exact, because a row outside every
    window can never appear in a pair. ``optimize=False`` evaluates
    both sides in full (the baseline); pairs are bit-identical."""
    t0 = time.perf_counter()
    eng_l, eng_r = engines
    opt = (plan.left.optimized if optimize is None else optimize)
    tl = np.asarray(eng_l.metadata[plan.time_cols[0]], np.float64)
    tr = np.asarray(eng_r.metadata[plan.time_cols[1]], np.float64)
    plan.window_kept = None
    if opt:
        sides = ((eng_l, plan.left, tl), (eng_r, plan.right, tr))
        (b_eng, b_plan, b_t) = sides[plan.build_side]
        (p_eng, p_plan, p_t) = sides[1 - plan.build_side]
        b_res = execute_tree(b_eng, b_plan, optimize=True)
        # window pushdown: probe candidates within delta of a surviving
        # build timestamp
        cand = np.where(p_eng.metadata_mask(p_plan.metadata_eq))[0]
        bt = np.sort(b_t[b_res.indices])
        if len(bt):
            pos = np.searchsorted(bt, p_t[cand])
            near_r = np.take(bt, np.minimum(pos, len(bt) - 1))
            near_l = np.take(bt, np.maximum(pos - 1, 0))
            keep = (np.abs(near_r - p_t[cand]) <= plan.delta_t) | \
                   (np.abs(near_l - p_t[cand]) <= plan.delta_t)
            window = cand[keep]
        else:
            window = cand[:0]
        plan.window_kept = int(len(window))
        p_res = execute_tree(p_eng, p_plan, optimize=True, within=window)
        res_l, res_r = ((b_res, p_res) if plan.build_side == 0
                        else (p_res, b_res))
    else:
        res_l = execute_tree(eng_l, plan.left, optimize=False)
        res_r = execute_tree(eng_r, plan.right, optimize=False)
    pairs = temporal_hash_join(res_l.indices, tl, res_r.indices, tr,
                               plan.delta_t)
    plan.actual_pairs = int(len(pairs))
    return JoinResult(pairs, plan, res_l, res_r,
                      time.perf_counter() - t0)


# ------------------------------------------------------- naive oracle ----
def naive_tree_rows(images, tree, cascades: Mapping, metadata=None,
                    metadata_eq=None, *, chunk: int = 64, jit: bool = True,
                    _fn_cache: dict | None = None) -> np.ndarray:
    """The per-row differential oracle: every DISTINCT leaf concept runs
    its own naive full scan (engine/scan.naive_scan — no sharing, no
    masking, no short-circuit), then the ORIGINAL un-rewritten tree is
    evaluated as pure boolean mask algebra per row. The engine path
    (normalize → order → execute_tree) must return bit-identical rows
    for every tree (tests/test_algebra.py)."""
    n = len(images)
    mask0 = np.ones(n, bool)
    for col, val in (metadata_eq or {}).items():
        mask0 &= np.asarray(metadata[col]) == val
    masks: dict = {}

    def concept_mask(concept: str) -> np.ndarray:
        if concept not in masks:
            rows = naive_scan(images, [cascades[concept]], chunk=chunk,
                              jit=jit, _fn_cache=_fn_cache)
            m = np.zeros(n, bool)
            m[rows] = True
            masks[concept] = m
        return masks[concept]

    def ev(t) -> np.ndarray:
        if isinstance(t, Pred):
            return concept_mask(t.concept)
        if isinstance(t, Not):
            return ~ev(t.child)
        if isinstance(t, And):
            m = np.ones(n, bool)
            for c in t.children:
                m &= ev(c)
            return m
        if isinstance(t, Or):
            m = np.zeros(n, bool)
            for c in t.children:
                m |= ev(c)
            return m
        raise TypeError(f"not a row-wise expression node: {t!r}")

    return np.where(ev(tree) & mask0)[0].astype(np.int64)


def naive_join_pairs(left, right, delta: float) -> np.ndarray:
    """Nested-loop reference for the temporal join: ``left``/``right``
    are (row_ids, timestamps) per side; every id pair within the band
    is emitted, lexicographically sorted."""
    (ids_l, tl), (ids_r, tr) = left, right
    out = [(int(a), int(b)) for a in ids_l for b in ids_r
           if abs(float(tl[a]) - float(tr[b])) <= delta]
    if not out:
        return np.empty((0, 2), np.int64)
    return np.asarray(sorted(out), np.int64)
