"""Sharded multi-device scan engine (DESIGN.md §9).

Partitions the metadata-survivor row set across N shard executors
(`sharding/policy.plan_shards`: range or hash partitioning, skew-aware
when the planner's per-row cost estimates are available) and runs the
PR-2 chunk/stage pipeline per shard with shard-local pyramid
materialization. Two execution backends:

* **lockstep (default)** — shards advance through the scan in
  synchronized supersteps; each superstep stacks one bucketed
  index-slab per shard into a leading device axis and issues ONE
  ``jax.pmap`` dispatch over the shard devices
  (`launch/mesh.shard_devices`). Shard images are committed to their
  devices once per scan (``jax.device_put_sharded``); each superstep
  gathers device-locally, materializes the pyramid shard-locally, and
  ships back only labels plus the small non-base levels — the base
  level is regathered on-device at flush time, so per-superstep host
  traffic is index slabs and labels, not image-sized tensors. On a
  multi-chip host every shard's pyramid/cascade computation runs on its
  own device concurrently. Python-thread-per-shard designs were
  measured and rejected: GIL-serialized dispatch makes threads *slower*
  than serial at 8 shards. Row routing between stages stays host-side
  numpy, exactly the serial engine's cache-aware walk.
* **serial fallback** (``parallel=False``) — one
  ``ScanEngine.scan_rows`` call per shard, the factored shard-invocable
  unit from engine/scan.py. Same row sets, no device concurrency; this
  is also the reference path the differential tests pit the lockstep
  against, and the per-shard unit BENCH_sharded_scan.json times in
  isolation for the critical-path throughput curve (on CPU CI the
  simulated devices share the physical cores, so lockstep wall-clock
  cannot scale there — see DESIGN.md §9.4).

Each shard scans against a shard-local `VirtualColumnStore` seeded from
the corpus-wide store, and the shard stores are merged back
(`VirtualColumnStore.merge_from`: union of computed entries, a computed
label is never overwritten) so re-planned queries reuse every partial
column regardless of which shard computed it.

Exactness: a row's labels depend only on its own pooled pyramid rows at
a fixed batch shape (per-row independence, DESIGN.md §4.2), and the
ShardPlan assigns every surviving row to exactly one shard — so the
merged row set is bit-identical to the single-shard `ScanEngine` and to
`naive_scan`, for any shard count, partitioning strategy, or backend
(tests/test_sharded_scan.py holds all three equal).

Ownership and invariants: each SHARD materializes its own pyramid —
shard-locally, on its own device, covering exactly the same union level
set the serial engine would build (``stage_needs``; ==
``PhysicalPlan.level_set`` + base for a planned query) — the corpus has
no global pyramid. This ENGINE (and only it) merges: shard-local stores
are seeded with their partition's rows before the scan and merged back
corpus-wide after (``VirtualColumnStore.merge_from``: union of computed
entries; a "decided" row — one whose column holds 0/1 — is never
overwritten, by any shard, in any merge order). The planner's
mid-scan re-order hook is a serial-engine feature; the lockstep backend
runs the plan's order unchanged (per-shard re-ordering would desync the
supersteps for zero dispatch savings).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.engine.scan import (CompiledCascade, ScanEngine, ScanStats,
                               StageStats, VirtualColumnStore,
                               level_schedule, stage_needs)
from repro.sharding.policy import ShardPlan, plan_shards


# ---------------------------------------------------------- slab builder --
SLAB_FLOOR = 16


def slab_width(n_valid: int, cap: int, floor: int = SLAB_FLOOR) -> int:
    """Bucketed slab width: smallest power-of-two >= ``n_valid``,
    floored at ``floor`` and capped at ``cap``. Keeps sparse batches
    (late-stage lockstep slabs, deadline-triggered partial serving
    flushes) from paying full-width padding compute while bounding the
    number of distinct compiled shapes to O(log cap). Labels are
    width-independent (per-row independence, DESIGN.md §4.2), so the
    bucket size is purely a perf knob. Shared by the lockstep supersteps
    here and the async service's batch assembler (serve/service.py)."""
    b = floor
    while b < n_valid:
        b *= 2
    return min(b, cap)


def pad_rows(ids: np.ndarray, width: int) -> np.ndarray:
    """Pad a valid id prefix to the slab width by repeating the last id
    (the lockstep/serving padding policy: stale duplicate rows are
    computed and discarded, never recorded). Requires 0 < len <= width."""
    ids = np.asarray(ids, np.int64)
    return np.concatenate([ids, np.full(width - len(ids), ids[-1],
                                        np.int64)])


@dataclass
class ShardedScanStats:
    plan: ShardPlan
    backend: str                       # 'lockstep' | 'serial'
    n_devices: int = 1
    supersteps: int = 0                # lockstep group dispatches issued
    shards: list = field(default_factory=list)   # ScanStats per shard

    @property
    def rows_scanned(self) -> int:
        return sum(s.rows_scanned for s in self.shards)

    @property
    def rows_evaluated(self) -> int:
        return sum(s.rows_evaluated for s in self.shards)

    @property
    def level_rows(self) -> dict:
        """Per-level materialization counters summed across shards
        (same shape as ScanStats.level_rows)."""
        out: dict = {}
        for sh in self.shards:
            for r, n in sh.level_rows.items():
                out[r] = out.get(r, 0) + n
        return out

    @property
    def stages(self) -> list:
        """Per-predicate StageStats summed across shards (same shape the
        single-shard ScanStats exposes)."""
        if not self.shards or not self.shards[0].stages:
            return []
        out = []
        for i, st0 in enumerate(self.shards[0].stages):
            agg = StageStats(st0.concept)
            for sh in self.shards:
                st = sh.stages[i]
                agg.rows_in += st.rows_in
                agg.rows_cached += st.rows_cached
                agg.rows_evaluated += st.rows_evaluated
                agg.batches += st.batches
            out.append(agg)
        return out


@dataclass
class ShardedScanResult:
    indices: np.ndarray
    stats: ShardedScanStats


class _ObserveOnly:
    """Monitor wrapper for the serial-fallback shard loop: forwards
    observed labels (so re-plans see measured selectivities) but
    suppresses re-order proposals — a per-shard re-order would desync
    the shards' stage aggregation for zero dispatch savings."""

    def __init__(self, monitor):
        self._monitor = monitor

    def observe(self, key, labels, *, marginal: bool = False) -> None:
        self._monitor.observe(key, labels, marginal=marginal)

    def propose(self, cascades):
        return None


class ShardedScanEngine:
    """Corpus-wide scan over N shards with one merged virtual-column
    store. Wraps a single-host ScanEngine for the shared pieces
    (metadata masking, the serial shard unit, the corpus-wide store);
    owns the shard planning and the lockstep pmap execution."""

    def __init__(self, images, metadata: Mapping[str, np.ndarray]
                 | None = None, *, shards: int | None = None,
                 chunk: int = 64, jit: bool = True,
                 strategy: str = "range", devices: Sequence | None = None,
                 fused: bool = True, lazy: bool = True, int8: bool = False,
                 use_kernel: bool | None = None):
        from repro.launch.mesh import shard_devices

        self.local = ScanEngine(images, metadata, chunk=chunk, jit=jit,
                                fused=fused, lazy=lazy, int8=int8,
                                use_kernel=use_kernel)
        self.devices = list(devices) if devices is not None \
            else shard_devices(shards)
        self.n_shards = int(shards) if shards is not None \
            else len(self.devices)
        if self.n_shards < 1:
            raise ValueError("need at least one shard")
        self.chunk = int(chunk)
        self.jit = jit
        self.strategy = strategy
        self._fns: dict = {}

    # ------------------------------------------------------- delegation --
    @property
    def images(self) -> np.ndarray:
        return self.local.images

    @property
    def metadata(self) -> Mapping[str, np.ndarray]:
        """The corpus metadata columns (the algebra layer's temporal
        join reads its timestamp column engine-agnostically —
        engine/algebra.execute_join)."""
        return self.local.metadata

    @property
    def store(self) -> VirtualColumnStore:
        """The corpus-wide merged store (shared with the wrapped serial
        engine, so mixed sharded/unsharded sessions see one cache)."""
        return self.local.store

    def reset_cache(self) -> None:
        self.local.reset_cache()

    def metadata_mask(self, metadata_eq: Mapping | None) -> np.ndarray:
        return self.local.metadata_mask(metadata_eq)

    # ---------------------------------------------------- shard planning --
    def row_weights(self, cascades: Sequence[CompiledCascade],
                    ids: np.ndarray, *, monitor=None) -> np.ndarray:
        """Expected evaluation seconds per row under the planner's
        cost/selectivity estimates, refined by the store: a cached label
        costs nothing and collapses the row's survival to 0/1. This is
        the skew-aware signal range partitioning balances on — after a
        partial first query, the un-evaluated region of the corpus is
        more expensive and gets spread across more shards. ``monitor``
        (engine/planner.OnlineReorderer) swaps the static plan-time
        selectivities for the selectivities OBSERVED in earlier flushes
        (``monitor.refined``) — so a re-plan mid-corpus weighs the
        remaining rows by what the scan has actually measured, not by
        eval-split estimates that may have drifted."""
        ids = np.asarray(ids, np.int64)
        w = np.zeros(len(ids))
        alive = np.ones(len(ids))
        for casc in cascades:
            sel = (monitor.refined(casc.key) if monitor is not None
                   else casc.selectivity)
            cached = self.store.lookup(casc.key, ids)
            w += alive * np.where(cached < 0, max(casc.cost_s, 1e-12), 0.0)
            alive *= np.where(cached == 0, 0.0,
                              np.where(cached == 1, 1.0,
                                       np.clip(sel, 0.0, 1.0)))
        return w

    def plan_for(self, cascades: Sequence[CompiledCascade],
                 metadata_eq: Mapping | None = None, *,
                 ids: np.ndarray | None = None, monitor=None) -> ShardPlan:
        """The ShardPlan execute() would use: survivor ids partitioned
        under this engine's strategy with skew-aware weights (observed-
        selectivity-refined when a ``monitor`` is given)."""
        if ids is None:
            ids = np.where(self.metadata_mask(metadata_eq))[0]
        weights = (self.row_weights(cascades, ids, monitor=monitor)
                   if cascades else None)
        return plan_shards(ids, self.n_shards, strategy=self.strategy,
                           weights=weights)

    # --------------------------------------------------------- execution --
    def execute(self, cascades: Sequence[CompiledCascade],
                metadata_eq: Mapping | None = None, *,
                shard_plan: ShardPlan | None = None,
                parallel: bool = True,
                survivors: np.ndarray | None = None,
                monitor: object | None = None) -> ShardedScanResult:
        """SELECT row ids WHERE metadata_eq AND every cascade labels 1,
        sharded. ``shard_plan`` overrides the engine's own planning (it
        must partition exactly the metadata survivors). ``survivors``
        is an index-pruned survivor set (engine/ingest.CandidateIndex
        via PhysicalPlan.index_prefilter): only metadata survivors ALSO
        in it are partitioned and scanned — same semantics as the
        serial engine's ``execute``. ``monitor``
        (engine/planner.OnlineReorderer) is OBSERVE-ONLY here: every
        evaluation flush feeds it measured labels — so the NEXT
        ``plan_for`` partitions on observed selectivities — but the
        sharded backends never apply its re-order proposals mid-scan
        (per-shard re-ordering would desync the lockstep supersteps and
        the cross-shard stage aggregation)."""
        cascades = list(cascades)
        ids_all = np.where(self.metadata_mask(metadata_eq))[0]
        if survivors is not None:
            ids_all = np.intersect1d(ids_all,
                                     np.asarray(survivors, np.int64))
        if shard_plan is None:
            shard_plan = self.plan_for(cascades, ids=ids_all,
                                       monitor=monitor)
        else:
            shard_plan.validate(ids_all)

        backend = "lockstep" if parallel else "serial"
        stats = ShardedScanStats(
            shard_plan, backend,
            n_devices=min(self.n_shards, len(set(self.devices))),
            shards=[ScanStats(stages=[StageStats(c.concept)
                                      for c in cascades])
                    for _ in range(shard_plan.n_shards)])
        for st, part in zip(stats.shards, shard_plan.shards):
            st.rows_scanned = len(part)
        if not cascades:
            return ShardedScanResult(ids_all, stats)

        # shard-local stores seeded from the corpus-wide store (only the
        # shard's own partition rows — all it will ever look up)
        shard_stores = []
        for part in shard_plan.shards:
            st = VirtualColumnStore(len(self.images))
            st.seed_from(self.store, part)
            shard_stores.append(st)
        if parallel:
            accepted = self._lockstep(cascades, shard_plan, shard_stores,
                                      stats, monitor=monitor)
        else:
            proxy = _ObserveOnly(monitor) if monitor is not None else None
            accepted = []
            for si, part in enumerate(shard_plan.shards):
                if not len(part):
                    continue
                r = self.local.scan_rows(cascades, part,
                                         store=shard_stores[si],
                                         monitor=proxy)
                stats.shards[si] = r.stats
                accepted.append(r.indices)

        # merge: union of computed entries, no -1 overwrites
        for st in shard_stores:
            self.store.merge_from(st)

        nonempty = [a for a in accepted if len(a)]
        out = (np.sort(np.concatenate(nonempty)) if nonempty
               else np.empty(0, np.int64))
        return ShardedScanResult(out, stats)

    # ------------------------------------------------- lockstep backend --
    def _slab_runner(self, key: tuple, make_fn):
        """Compile cache for group slab functions: pmap over the shard
        devices when jitting, a per-shard python loop (same padding,
        same results) when not."""
        if key not in self._fns:
            fn = make_fn()
            width = key[-1]
            if self.jit:
                import jax
                devs = list(dict.fromkeys(self.devices))[:width]
                runner = jax.pmap(fn, devices=devs)
            else:
                def runner(*slabs, _fn=fn, _w=width):
                    import jax
                    outs = [_fn(*[jax.tree.map(lambda v: v[j], s)
                                  for s in slabs]) for j in range(_w)]
                    return jax.tree.map(lambda *xs: np.stack(xs), *outs)
            self._fns[key] = runner
        return self._fns[key]

    def _ingest_runner(self, casc: CompiledCascade, out_res: tuple,
                       width: int):
        """Fused ingest superstep: gather the slab's rows from the
        device-resident shard image block, then run the same fused
        pyramid + full-stage-0 program the serial engine builds
        (core/executor.make_fused_ingest — the Pallas pyramid+stage-0
        kernel on TPU with real CNN params, one jit composition
        elsewhere). Ships back ONLY the labels plus the small non-base
        levels later stages carry; the base level never round-trips (it
        is regathered from the block at flush time). Under lazy
        scheduling the program materializes just cascade 0's own levels
        plus ``out_res`` — later-stage-only levels wait for first touch
        at flush. One dispatch per superstep, minimal host bytes."""
        def make():
            import jax.numpy as jnp

            from repro.core.executor import make_fused_ingest
            # same chunk-clamped full-width capacities and int8/kernel
            # resolution as the serial engine's _ingest_fn (argsort
            # slicing clamps cap to the slab width b <= chunk)
            caps = [self.chunk] * (len(casc.model_fns) - 1)
            int8 = (self.local.int8 and casc.stage0 is not None
                    and casc.stage0.qparams is not None)
            use_kernel = (self.local.use_kernel
                          if casc.stage0 is not None else False)
            core = make_fused_ingest(
                casc.model_fns, casc.thresholds, casc.reps, caps,
                out_res, stage0=casc.stage0, use_kernel=use_kernel,
                int8=int8, jit=False)

            def fn(block, idx):
                return core(jnp.take(block, idx, axis=0))
            return fn
        return self._slab_runner(
            ("ingest", casc.key, out_res, width), make)

    def _flush_runner(self, casc: CompiledCascade, base_hw: int,
                      in_res: tuple, out_res: tuple, width: int):
        """Stage-s flush: cascade inputs are the host-carried small
        levels (``in_res`` minus base) plus, when the cascade reads the
        base resolution or must first-touch-derive a level, a
        device-side regather from the shard image block. Levels the
        cascade reads that are NOT in ``in_res`` are derived inside the
        program with exactly the serial engine's _cascade_fn policy
        (smallest provided/derived level that divides — bit-exact from
        base for dyadic pixels); ``out_res`` names the derived levels
        shipped back for downstream stages to carry."""
        with_base = base_hw in in_res

        def make():
            import jax.numpy as jnp

            from repro.core.executor import run_cascade_on_pyramid
            from repro.core.transforms import resize_area
            # full-width levels clamped by slab width, never
            # casc.capacities — see CompiledCascade
            caps = [self.chunk] * (len(casc.model_fns) - 1)
            steps: list[tuple[int, int]] = []
            avail = set(in_res)
            for r in sorted(set(casc.resolutions) - avail, reverse=True):
                steps.append((r, min(m for m in avail if m % r == 0)))
                avail.add(r)

            def fn(block, idx, small):
                pyr = dict(small)
                if with_base:
                    pyr[base_hw] = jnp.take(block, idx, axis=0)
                for r, src in steps:
                    pyr[r] = resize_area(pyr[src], r)
                labels = run_cascade_on_pyramid(
                    pyr, casc.model_fns, casc.thresholds, casc.reps,
                    caps)[0]
                return labels, {r: pyr[r] for r in out_res}
            return fn
        return self._slab_runner(
            ("flush", casc.key, tuple(in_res), tuple(out_res), width),
            make)

    def _slab_width(self, n_valid: int, cap: int | None = None) -> int:
        """Module-level ``slab_width`` bound to this engine's chunk."""
        return slab_width(n_valid, self.chunk if cap is None else cap)

    def _stage_blocks(self, lanes: list, width: int, base_hw: int):
        """Pad each lane's undetermined rows to a common chunk-multiple
        length and commit one image block per shard device
        (pmap-sharded, so every later superstep gathers device-locally
        with only tiny index slabs crossing the host boundary). Eager
        backend keeps the block host-side. NOTE: this stages the whole
        undetermined partition per shard — O(rows/shards) device memory,
        not the serial engine's O(chunk); corpora beyond device memory
        need windowed staging (ROADMAP: multi-host sharding)."""
        m = max((len(u) for u in lanes), default=1)
        L = max(self.chunk, -(-m // self.chunk) * self.chunk)
        block = np.zeros((width, L, base_hw, base_hw, 3), np.float32)
        for j, ids in enumerate(lanes):
            if len(ids):
                block[j, :len(ids)] = self.images[ids]
        if not self.jit:
            return block
        import jax
        devs = list(dict.fromkeys(self.devices))[:width]
        return jax.device_put_sharded(list(block), devs)

    def _lockstep(self, cascades, plan: ShardPlan, stores, stats,
                  monitor=None):
        """Stage-synchronous shard execution: every superstep stacks one
        bucketed index-slab per shard and issues a single pmap dispatch
        over the shard devices. Images are staged device-side once per
        group; only labels and the small non-base pyramid levels cross
        the host boundary. Host-side routing walks cached labels between
        stages, exactly like the serial engine — including the lazy
        level schedule (level_schedule): later-stage-only levels are
        first-touch derived inside the stage's flush dispatch and
        shipped back only when a later stage carries them."""
        needed, union_res = stage_needs(cascades, self.images.shape[1])
        for sh in stats.shards:     # the STATIC union level set, same
            sh.pyramid_levels = union_res    # as the serial shard unit
        schedule = level_schedule(cascades, self.images.shape[1],
                                  self.local.lazy)
        width = min(plan.n_shards, max(len(set(self.devices)), 1))
        accepted: list[np.ndarray] = []

        for g0 in range(0, plan.n_shards, width):
            group = list(range(g0, min(g0 + width, plan.n_shards)))
            accepted += self._run_group(cascades, plan, group, width,
                                        stores, stats, needed, schedule,
                                        monitor)
        return accepted

    def _run_group(self, cascades, plan, group, width, stores, stats,
                   needed, schedule, monitor=None):
        import jax.numpy as jnp

        from repro.core.transforms import resize_area

        ingest_set, carry, derive = schedule

        k = len(cascades)
        chunk = self.chunk
        base_hw = self.images.shape[1]
        accepted: list[np.ndarray] = []

        # ---- presplit: rows whose outcome the seeded store already
        # determines (a cached 0, or cached 1s through every stage)
        # never enter the pipeline — a fully-cached re-run issues ZERO
        # dispatches and stages no images
        lanes = []
        for si in group:
            ids = plan.shards[si]
            walking = np.ones(len(ids), bool)   # on an all-cached-1 path
            unknown = np.zeros(len(ids), bool)  # hit a -1 while walking
            for casc in cascades:
                c = stores[si].lookup(casc.key, ids)
                unknown |= walking & (c < 0)
                walking &= c == 1
            if walking.any():
                accepted.append(ids[walking])
            lanes.append(ids[unknown])
            # cache-determined rows still count as stage traffic (all
            # served from the store), keeping stats comparable with the
            # serial backend, which walks them through route()
            at = ~unknown
            for s, casc in enumerate(cascades):
                if not at.any():
                    break
                st = stats.shards[si].stages[s]
                n = int(at.sum())
                st.rows_in += n
                st.rows_cached += n
                at &= stores[si].lookup(casc.key, ids) == 1
        if not any(len(u) for u in lanes):
            return accepted

        block = self._stage_blocks(lanes, width, base_hw)
        # worklists[s][j]: (ids, pos, rows) segments awaiting evaluation
        # at stage s; pos indexes the lane's staged image block so the
        # base level is regathered device-side instead of host-carried
        worklists: list[list[list]] = [[[] for _ in group]
                                       for _ in range(k)]

        def count_levels(si, res, n):
            lr = stats.shards[si].level_rows
            for r in res:
                lr[r] = lr.get(r, 0) + n

        def route(j, stage, ids, pos, rows):
            si = group[j]
            while len(ids):
                if stage == k:
                    accepted.append(ids)
                    return
                casc = cascades[stage]
                st = stats.shards[si].stages[stage]
                st.rows_in += len(ids)
                cached = stores[si].lookup(casc.key, ids)
                known = cached >= 0
                st.rows_cached += int(known.sum())
                unk = ~known
                if unk.any():
                    sub = {r: rows[r][unk] for r in carry[stage]
                           if r in rows}
                    missing = [r for r in carry[stage] if r not in rows]
                    if missing:
                        # cache-skip backfill, exactly the serial
                        # engine's feed(): rows that hopped over earlier
                        # stages on cached labels never saw those
                        # stages' flush-time derivation — pool their
                        # carry levels straight from base
                        imgs = jnp.asarray(self.images[ids[unk]])
                        for r in missing:
                            sub[r] = np.asarray(resize_area(imgs, r))
                        count_levels(si, missing, int(unk.sum()))
                    worklists[stage][j].append((ids[unk], pos[unk], sub))
                keep = known & (cached == 1)
                ids, pos = ids[keep], pos[keep]
                rows = {r: v[keep] for r, v in rows.items()}
                stage += 1

        # ---- ingest: fused pyramid + FULL cascade 0, lockstep ---------
        casc0 = cascades[0]
        out_res = tuple(carry[1]) if k > 1 else ()
        ingest = self._ingest_runner(casc0, out_res, width)
        n_steps = max(math.ceil(len(u) / chunk) for u in lanes if len(u))
        for t in range(n_steps):
            segs = [u[t * chunk:(t + 1) * chunk] for u in lanes]
            b = self._slab_width(max(len(s) for s in segs))
            idx = np.zeros((width, b), np.int32)
            for j, seg in enumerate(segs):
                idx[j, :len(seg)] = t * chunk + np.arange(len(seg))
            labels_all, levels = ingest(block, jnp.asarray(idx))
            labels_all = np.asarray(labels_all)
            levels = {r: np.asarray(v) for r, v in levels.items()}
            stats.supersteps += 1
            for j, si in enumerate(group):
                nv = len(segs[j])
                if not nv:
                    continue
                sh = stats.shards[si]
                sh.chunks += 1
                count_levels(si, ingest_set, nv)
                st = sh.stages[0]
                ids = segs[j]
                pos = t * chunk + np.arange(nv)
                st.rows_in += nv
                cached = stores[si].lookup(casc0.key, ids)
                known = cached >= 0
                st.rows_cached += int(known.sum())
                lab = labels_all[j, :nv]
                unk = ~known
                if unk.any():
                    # the fused kernel scored the whole slab; only the
                    # genuinely-unknown rows count as evaluations, and
                    # cached labels always win for routing
                    stores[si].record(casc0.key, ids[unk], lab[unk])
                    st.rows_evaluated += int(unk.sum())
                    st.batches += 1
                    if monitor is not None:
                        # stage-0 slabs see the unfiltered shard stream
                        monitor.observe(casc0.key, lab[unk],
                                        marginal=True)
                use = np.where(known, cached, lab)
                keep = use == 1
                route(j, 1, ids[keep], pos[keep],
                      {r: levels[r][j, :nv][keep] for r in out_res})

        # ---- stages 1..k-1: flush worklists in lockstep slabs ---------
        for s in range(1, k):
            casc = cascades[s]
            # host-carried small levels; the device program first-touch
            # derives derive[s] (and regathers base when the cascade or
            # a derivation reads it) — exactly the serial flush()
            need_base = (base_hw in casc.resolutions
                         or bool(derive[s]))
            in_res = tuple(carry[s]) + ((base_hw,) if need_base else ())
            down_carry = tuple(r for r in carry[s]
                               if s + 1 < k and r in needed[s + 1])
            out_dev = tuple(r for r in derive[s]
                            if s + 1 < k and r in needed[s + 1])
            flush = self._flush_runner(casc, base_hw, in_res, out_dev,
                                       width)
            pend = []
            for j in range(len(group)):
                segs = worklists[s][j]
                if segs:
                    ids = np.concatenate([a for a, _, _ in segs])
                    pos = np.concatenate([p for _, p, _ in segs])
                    rows = {r: np.concatenate([rw[r]
                                               for _, _, rw in segs])
                            for r in carry[s]}
                else:
                    ids = np.empty(0, np.int64)
                    pos = np.empty(0, np.int64)
                    rows = {}
                pend.append((ids, pos, rows))
            n_steps = max((math.ceil(len(p[0]) / chunk) for p in pend),
                          default=0)
            for t in range(n_steps):
                sl = slice(t * chunk, (t + 1) * chunk)
                segs = [(p[0][sl], p[1][sl]) for p in pend]
                b = self._slab_width(max(len(x) for x, _ in segs))
                idx = np.zeros((width, b), np.int32)
                small = {r: np.zeros((width, b, r, r, 3), np.float32)
                         for r in carry[s]}
                for j, (sids, spos) in enumerate(segs):
                    if not len(sids):
                        continue
                    idx[j, :len(sids)] = spos
                    for r in carry[s]:
                        small[r][j, :len(sids)] = pend[j][2][r][sl]
                labels_all, dev_levels = flush(
                    block, jnp.asarray(idx),
                    {r: jnp.asarray(v) for r, v in small.items()})
                labels_all = np.asarray(labels_all)
                dev_levels = {r: np.asarray(v)
                              for r, v in dev_levels.items()}
                stats.supersteps += 1
                for j, si in enumerate(group):
                    sids, spos = segs[j]
                    nv = len(sids)
                    if not nv:
                        continue
                    st = stats.shards[si].stages[s]
                    lab = labels_all[j, :nv]
                    stores[si].record(casc.key, sids, lab)
                    st.rows_evaluated += nv
                    st.batches += 1
                    count_levels(si, derive[s], nv)
                    if monitor is not None:
                        monitor.observe(casc.key, lab, marginal=False)
                    keep = lab == 1
                    down = {r: pend[j][2][r][sl][keep]
                            for r in down_carry}
                    for r in out_dev:
                        down[r] = dev_levels[r][j, :nv][keep]
                    route(j, s + 1, sids[keep], spos[keep], down)
        return accepted
