"""Unified multi-predicate scan engine (DESIGN.md §4.2).

Executes a PhysicalPlan (engine/planner.py) over an image corpus:

* the corpus is streamed in fixed-size chunks of the rows that survive
  the metadata predicates; each chunk materializes ONE shared RGB
  representation pyramid (core/transforms.materialize_pyramid) covering
  the union of every selected cascade's levels — no cascade re-reads the
  raw base images;
* binary predicates run as a pipeline of mask-compacted stages: rows
  surviving predicate k-1 accumulate in predicate k's fixed-capacity row
  buffer (carrying their already-pooled pyramid rows, not raw images);
  a full buffer flushes through the cascade at ONE static batch shape
  (core/executor.run_cascade_on_pyramid — jit-compiled once per
  cascade). Rows eliminated earlier are never evaluated;
* every computed label lands in a VirtualColumnStore keyed by
  (concept, cascade-id) — the paper's 'classifier output as a virtual
  column', kept PARTIAL: re-planned queries (different order, different
  constraints, overlapping predicate sets) reuse every row previously
  decided by the same physical cascade and only evaluate the rest.

Because every per-row computation (box-filter pooling, per-sample CNN
inference) is independent of the surrounding batch at a fixed shape, the
selected row set is bit-identical to ``naive_scan``'s one-predicate-at-
a-time full scans (tests/test_query_engine.py).

Ownership and invariants (DESIGN.md §4, §11):

* the PLANNER (engine/planner.py) decides WHAT runs — the cascade set,
  its order, and therefore the pyramid level set; this engine decides
  HOW — it materializes per chunk exactly the union of the executed
  cascades' resolutions plus the raw base (``stage_needs``; for a
  planned query that union == ``PhysicalPlan.level_set``), reported in
  ``ScanStats.pyramid_levels``. Shared levels are materialized ONCE per
  chunk no matter how many cascades read them;
* a row is "decided" for a cascade when its virtual column holds 0/1
  (−1 = unknown). Decided rows are never re-evaluated; a computed label
  is never overwritten (``VirtualColumnStore`` semantics below) — the
  store is the single source of truth shared by the serial engine, the
  sharded engine's shard-local seeds/merges, and the async service;
* the accept condition (every cascade labels 1) is an order-invariant
  conjunction of per-row, batch-independent labels — which is what
  makes predicate re-ordering (including MID-SCAN re-ordering via the
  ``monitor`` hook, engine/planner.OnlineReorderer) and any
  chunk/buffer/shard layout produce bit-identical row sets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.executor import (Stage0, make_fused_ingest,
                                 run_cascade_batch, run_cascade_on_pyramid)
from repro.core.transforms import materialize_pyramid, resize_area


@dataclass
class CompiledCascade:
    """A physically-selected cascade, ready to execute: the planner's
    output unit and the scan engine's unit of work. ``cascade_id`` must
    identify the physical cascade (models + thresholds) stably so the
    virtual-column store can recognize it across plans."""
    concept: str
    cascade_id: tuple
    reps: list                       # list[Representation], one per level
    model_fns: list                  # level input tensor -> scores (B,)
    thresholds: list                 # [(p_low, p_high)...]; final (None, None)
    cost_s: float = 0.0              # estimated seconds/row (planner)
    selectivity: float = 0.5         # estimated P(predicate true)
    # capacities is a SERVING-path knob (make_batch_runner): capped
    # levels force overflow rows to level-0 decisions, which depend on
    # batch packing. Scan paths (ScanEngine / naive_scan) deliberately
    # ignore it and run full-width levels so scan results are exact,
    # batch-packing independent, and safe to cache as virtual columns.
    capacities: list | None = None
    # level-0 model in kernel-foldable form (core/executor.Stage0):
    # raw CNN params (+ optional int8 copy) for the fused Pallas
    # pyramid+stage-0 ingest. None (opaque model_fns only) disables the
    # kernel path; the fused jit composition still applies.
    stage0: Stage0 | None = None

    @property
    def key(self) -> tuple:
        return (self.concept, tuple(self.cascade_id))

    @property
    def resolutions(self) -> list[int]:
        return sorted({r.resolution for r in self.reps}, reverse=True)


class VirtualColumnStore:
    """Partial virtual columns keyed by (concept, cascade-id): int8 labels
    with -1 = not yet evaluated. Shared across executions of one engine so
    re-planned queries reuse prior work."""

    def __init__(self, n_rows: int):
        self.n_rows = n_rows
        self._cols: dict[tuple, np.ndarray] = {}

    def column(self, key: tuple) -> np.ndarray:
        if key not in self._cols:
            self._cols[key] = np.full(self.n_rows, -1, np.int8)
        return self._cols[key]

    def lookup(self, key: tuple, ids: np.ndarray) -> np.ndarray:
        return self.column(key)[ids]

    def record(self, key: tuple, ids: np.ndarray, labels) -> None:
        self.column(key)[ids] = np.asarray(labels, np.int8)

    def known_rows(self, key: tuple) -> int:
        return int((self.column(key) >= 0).sum())

    def rows_with_label(self, key: tuple, ids: np.ndarray,
                        label: int) -> np.ndarray:
        """Of ``ids``, the rows whose stored label equals ``label``.
        The algebra executor's NOT path (engine/algebra.py, DESIGN.md
        §15): after a scan has decided every candidate row, the
        decided-0 rows of a cascade's int8 column are exactly ¬Pred."""
        ids = np.asarray(ids, np.int64)
        return ids[self.column(key)[ids] == label]

    def keys(self) -> list[tuple]:
        return list(self._cols)

    def seed_from(self, other: "VirtualColumnStore", rows) -> None:
        """Copy ``other``'s labels for ``rows`` only — the shard-store
        seed: a shard executor never looks beyond its partition, so
        seeding its row slice is enough (and O(partition), not
        O(corpus), per shard)."""
        assert other.n_rows == self.n_rows
        for key in other.keys():
            self.column(key)[rows] = other.column(key)[rows]

    def merge_from(self, other: "VirtualColumnStore") -> None:
        """Union of computed entries: ``other``'s known labels fill this
        store's unknown (-1) slots. A computed entry is NEVER overwritten
        — neither by -1 nor by a conflicting label — so merging shard
        stores in any order yields the same corpus-wide store as long as
        shards evaluated disjoint rows (the ShardPlan invariant)."""
        assert other.n_rows == self.n_rows
        for key in other.keys():
            src = other.column(key)
            dst = self.column(key)
            fill = (dst < 0) & (src >= 0)
            dst[fill] = src[fill]

    def save(self, path, token: tuple = ()) -> None:
        """Persist the store as an npz so virtual columns (including
        ingest-built label indexes, engine/ingest.py) survive restarts.
        ``token`` is the owning corpus's fingerprint
        (serve/repcache.corpus_token) — ``load`` refuses a different
        corpus, the same first-binder-wins contract as
        RepresentationCache.bind_corpus. Keys round-trip via repr /
        ast.literal_eval; labels are written verbatim (int8), so a
        load is bit-identical."""
        data = {"n_rows": np.int64(self.n_rows),
                "token": np.asarray(token, np.float64),
                "keys": np.array([repr(k) for k in self._cols])}
        for i, col in enumerate(self._cols.values()):
            data[f"col_{i}"] = col
        np.savez(path, **data)

    @classmethod
    def load(cls, path, token: tuple = ()) -> "VirtualColumnStore":
        """Inverse of ``save``. ``token`` must match the saved corpus
        fingerprint — labels are keyed by row position, so loading them
        against a different corpus would serve another corpus's labels
        permanently (exactly the repcache bind_corpus hazard)."""
        import ast
        with np.load(path, allow_pickle=False) as z:
            if not np.array_equal(z["token"],
                                  np.asarray(token, np.float64)):
                raise ValueError(
                    "VirtualColumnStore snapshot was saved for a "
                    "different corpus — its row-indexed labels would "
                    "be misattributed; refusing to load")
            store = cls(int(z["n_rows"]))
            for i, key in enumerate(z["keys"]):
                store._cols[ast.literal_eval(str(key))] = \
                    z[f"col_{i}"].astype(np.int8)
        return store

    def merge_rows_from(self, other: "VirtualColumnStore", rows) -> None:
        """``merge_from`` restricted to ``rows``: identical union /
        never-overwrite semantics at O(len(rows)) per column instead of
        O(corpus) — the serving path's per-delivery commit (a flush
        touches batch-sized row sets, and a full-store sweep per
        delivery would scale with corpus size)."""
        assert other.n_rows == self.n_rows
        rows = np.asarray(rows, np.int64)
        for key in other.keys():
            src = other.column(key)[rows]
            dst = self.column(key)
            take = (dst[rows] < 0) & (src >= 0)
            if take.any():
                dst[rows[take]] = src[take]


def stage_needs(cascades: Sequence[CompiledCascade],
                base_hw: int) -> tuple[list, tuple]:
    """``needed[s]``: pyramid resolutions stages >= s still require (rows
    entering stage s carry exactly these pooled levels); ``union_res``:
    the per-chunk materialization set — needed[0] plus the raw base so
    every level derives from the same progressive pyramid the cost model
    prices. Shared by the serial chunk loop and the sharded lockstep."""
    needed: list[list[int]] = []
    acc: set[int] = set()
    for c in reversed(cascades):
        acc |= {r.resolution for r in c.reps}
        needed.append(sorted(acc, reverse=True))
    needed = needed[::-1]
    union_res = tuple(sorted(set(needed[0]) | {base_hw}, reverse=True))
    return needed, union_res


def level_schedule(cascades: Sequence[CompiledCascade], base_hw: int,
                   lazy: bool = True) -> tuple[tuple, list, list]:
    """The engine's level-materialization schedule (DESIGN.md §13):

    * ``ingest``: non-base levels pooled at chunk ingest. Lazy: only the
      FIRST cascade's levels (its stage-0 run needs them full-width
      anyway). Eager: the whole union (``needed[0]``) — the pre-PR-7
      behavior, kept as the reference/benchmark baseline;
    * ``carry[s]``: non-base levels rows entering stage s carry in their
      stage buffer — ``needed[s]`` restricted to what is materialized by
      then (the base is never buffered; flushes regather it from the
      corpus when a cascade or a derivation reads it);
    * ``derive[s]``: levels stage s's flush must pool from the carried
      levels / base because no earlier stage materialized them — first
      touch AT SURVIVORS, the behavior ``costing='engine'``
      (joint_scan_cost(dense_reps=False)) prices. Always empty for s=0
      and in eager mode.
    """
    needed, _ = stage_needs(cascades, base_hw)
    res = [{r.resolution for r in c.reps} for c in cascades]
    ingest = (set(res[0]) if lazy else set(needed[0])) - {base_hw}
    mat = ingest | {base_hw}
    carry: list[tuple] = []
    derive: list[tuple] = []
    for s in range(len(cascades)):
        carry.append(tuple(sorted((set(needed[s]) & mat) - {base_hw},
                                  reverse=True)))
        derive.append(tuple(sorted(res[s] - mat, reverse=True)))
        mat |= res[s]
    return tuple(sorted(ingest, reverse=True)), carry, derive


@dataclass
class StageStats:
    concept: str
    rows_in: int = 0          # rows routed to this predicate
    rows_cached: int = 0      # resolved from the virtual-column store
    rows_evaluated: int = 0   # rows actually run through the cascade
    batches: int = 0          # cascade invocations (static-shape flushes)


@dataclass
class ScanStats:
    chunks: int = 0           # ingest chunks == shared pyramids built
    rows_scanned: int = 0     # rows surviving metadata (pyramid rows)
    rep_rows_cached: int = 0  # rows whose pooled levels came from the
    #                           cross-query representation cache (no
    #                           per-chunk pyramid materialization)
    reorders: int = 0         # mid-scan predicate re-orderings applied
    #                           (engine/planner.OnlineReorderer hook)
    pyramid_levels: tuple = ()  # the STATIC union level set of the plan
    #                           being executed: every cascade's
    #                           resolutions plus the raw base (==
    #                           PhysicalPlan.level_set + base) — what the
    #                           scan COULD touch, independent of lazy
    #                           scheduling
    level_rows: dict = field(default_factory=dict)  # MEASURED per-level
    #                           materializations: non-base resolution ->
    #                           number of valid rows the level was
    #                           physically pooled for (chunk ingest,
    #                           flush-time first-touch derivation, and
    #                           cache-skip backfill all count). Under
    #                           lazy scheduling on a cold store this
    #                           matches the planner's first-touch
    #                           schedule exactly (PhysicalPlan.explain
    #                           renders estimated-vs-actual)
    stages: list = field(default_factory=list)

    @property
    def rows_evaluated(self) -> int:
        return sum(s.rows_evaluated for s in self.stages)


@dataclass
class ScanResult:
    indices: np.ndarray       # sorted matching row ids
    stats: ScanStats


class _StageBuffer:
    """Fixed-capacity row accumulator for one predicate stage: ids plus
    the pooled pyramid rows every stage >= this one still needs."""

    def __init__(self, cap: int, resolutions: Sequence[int]):
        self.cap = cap
        self.ids = np.zeros(cap, np.int64)
        self.rows = {r: np.zeros((cap, r, r, 3), np.float32)
                     for r in resolutions}
        self.fill = 0


class ScanEngine:
    """Streaming multi-predicate scan over one corpus. Holds the
    virtual-column store and the per-cascade jit caches, so repeated /
    re-planned queries amortize both compilation and inference."""

    def __init__(self, images, metadata: Mapping[str, np.ndarray]
                 | None = None, *, chunk: int = 64, jit: bool = True,
                 repcache=None, fused: bool = True, lazy: bool = True,
                 int8: bool = False, use_kernel: bool | None = None):
        self.images = np.asarray(images, np.float32)
        self.metadata = dict(metadata or {})
        self.chunk = int(chunk)
        self.jit = jit
        # fused: run chunk ingest (pyramid + the FULL first cascade) as
        # one program instead of a pyramid program + stage-0 buffer
        # flushes. lazy: materialize later-stage-only levels at flush-
        # time first touch (level_schedule) instead of at ingest. int8:
        # stage-0 inference on int8-quantized weights (needs
        # CompiledCascade.stage0.qparams; ignored for opaque cascades).
        # use_kernel: force the Pallas pyramid+stage-0 kernel on/off
        # (None = auto: TPU with stage0 params).
        self.fused = bool(fused)
        self.lazy = bool(lazy)
        self.int8 = bool(int8)
        self.use_kernel = use_kernel
        self.store = VirtualColumnStore(len(self.images))
        # optional cross-query representation cache
        # (serve/repcache.RepresentationCache): chunks whose non-base
        # pooled levels are all cached skip pyramid materialization
        # entirely, and freshly pooled levels are published for later
        # queries / the serving path. Bit-exact either way (dyadic
        # box-filter pooling is deterministic).
        self.repcache = repcache
        if repcache is not None:
            from repro.serve.repcache import corpus_token
            repcache.bind_corpus(corpus_token(self.images))
        self._pyr_fns: dict = {}
        self._casc_fns: dict = {}
        self._ingest_fns: dict = {}

    def reset_cache(self) -> None:
        """Drop the virtual-column store (keeps compiled cascades)."""
        self.store = VirtualColumnStore(len(self.images))

    # ------------------------------------------------------- jit caches --
    def _pyramid_fn(self, resolutions: tuple) -> Callable:
        if resolutions not in self._pyr_fns:
            import jax

            def mat(img):
                levels = materialize_pyramid(img, resolutions)
                return {r: levels[r] for r in resolutions}
            self._pyr_fns[resolutions] = jax.jit(mat) if self.jit else mat
        return self._pyr_fns[resolutions]

    def _cascade_fn(self, casc: CompiledCascade, in_res: tuple,
                    out_res: tuple) -> Callable:
        """Flush program for one cascade: pyr ({res: rows} covering
        ``in_res``) -> (labels, {res: derived level for res in
        ``out_res``}). Levels the cascade reads that are NOT in
        ``in_res`` are derived progressively inside the program (each
        from the smallest provided/derived level that divides it — the
        plan_pyramid policy, bit-exact from base for dyadic pixels);
        ``out_res`` names the derived levels downstream stages carry."""
        key = (casc.key, tuple(in_res), tuple(out_res))
        if key not in self._casc_fns:
            import jax
            # full-width levels, never casc.capacities: see CompiledCascade
            caps = [self.chunk] * (len(casc.model_fns) - 1)
            steps: list[tuple[int, int]] = []
            avail = set(in_res)
            for r in sorted(set(casc.resolutions) - avail, reverse=True):
                steps.append((r, min(m for m in avail if m % r == 0)))
                avail.add(r)

            def run(pyr):
                cache = dict(pyr)
                for r, src in steps:
                    cache[r] = resize_area(cache[src], r)
                labels = run_cascade_on_pyramid(
                    cache, casc.model_fns, casc.thresholds, casc.reps,
                    caps)[0]
                return labels, {r: cache[r] for r in out_res}
            self._casc_fns[key] = jax.jit(run) if self.jit else run
        return self._casc_fns[key]

    def _ingest_fn(self, casc: CompiledCascade, out_res: tuple) -> Callable:
        """Fused chunk-ingest program (core/executor.make_fused_ingest):
        imgs -> (stage-0 labels, carried levels). The materialize
        callable resolves this module's ``materialize_pyramid`` at call
        time so invocation-counting tests can intercept it."""
        key = (casc.key, tuple(out_res))
        if key not in self._ingest_fns:
            caps = [self.chunk] * (len(casc.model_fns) - 1)
            int8 = (self.int8 and casc.stage0 is not None
                    and casc.stage0.qparams is not None)
            use_kernel = self.use_kernel
            if casc.stage0 is None:
                use_kernel = False
            self._ingest_fns[key] = make_fused_ingest(
                casc.model_fns, casc.thresholds, casc.reps, caps,
                out_res, stage0=casc.stage0,
                materialize=lambda img, res: materialize_pyramid(img, res),
                use_kernel=use_kernel, int8=int8, jit=self.jit)
        return self._ingest_fns[key]

    # --------------------------------------------------------- execution --
    def metadata_mask(self, metadata_eq: Mapping | None) -> np.ndarray:
        mask = np.ones(len(self.images), bool)
        for col, val in (metadata_eq or {}).items():
            mask &= np.asarray(self.metadata[col]) == val
        return mask

    def execute(self, cascades: Sequence[CompiledCascade],
                metadata_eq: Mapping | None = None, *,
                survivors: np.ndarray | None = None,
                monitor=None) -> ScanResult:
        """SELECT row ids WHERE metadata_eq AND every cascade labels 1,
        evaluating cascades in the given (planner's) order. ``monitor``
        (engine/planner.OnlineReorderer) enables mid-scan predicate
        re-ordering from observed selectivities. ``survivors`` is an
        index-pruned survivor set (engine/ingest.CandidateIndex via
        PhysicalPlan.index_prefilter, DESIGN.md §14): only metadata
        survivors ALSO in ``survivors`` are scanned — rows the ingest
        index excluded never touch a cascade."""
        mask = self.metadata_mask(metadata_eq)
        ids_all = np.where(mask)[0]
        if survivors is not None:
            ids_all = np.intersect1d(ids_all,
                                     np.asarray(survivors, np.int64))
        if not cascades:
            return ScanResult(ids_all, ScanStats())
        return self.scan_rows(cascades, ids_all, monitor=monitor)

    def scan_rows(self, cascades: Sequence[CompiledCascade],
                  ids_all: np.ndarray, *,
                  store: VirtualColumnStore | None = None,
                  monitor=None) -> ScanResult:
        """The shard-invocable scan unit: run the chunk/stage pipeline
        over exactly ``ids_all`` (already metadata-filtered row ids),
        reading and writing ``store`` (default: this engine's corpus-wide
        store). ShardedScanEngine (engine/sharded.py) drives one call per
        shard against shard-local stores; ``execute`` is the 1-shard
        case over the whole survivor set.

        ``monitor`` is the planner's online-refinement hook
        (engine/planner.OnlineReorderer): every evaluation flush feeds
        it observed labels, and at each chunk boundary it may propose a
        cheaper predicate order — the engine then drains its stage
        buffers under the old order (identical to the end-of-scan
        drain) and rebuilds the pipeline in the new order. Final row
        sets are bit-identical with or without re-ordering (per-row
        label independence; the accept condition is an order-invariant
        conjunction)."""
        import jax.numpy as jnp

        store = self.store if store is None else store
        cascades = list(cascades)
        k = len(cascades)
        stats = ScanStats(stages=[StageStats(c.concept) for c in cascades])
        ids_all = np.asarray(ids_all, np.int64)
        if k == 0:
            return ScanResult(np.sort(ids_all), stats)

        base_hw = self.images.shape[1]
        needed, union_res = stage_needs(cascades, base_hw)
        stats.pyramid_levels = union_res
        ingest_set, carry, derive = level_schedule(cascades, base_hw,
                                                   self.lazy)
        buffers = [_StageBuffer(self.chunk, carry[s]) for s in range(k)]
        accepted: list[np.ndarray] = []

        def count_levels(res, n: int) -> None:
            for r in res:
                stats.level_rows[r] = stats.level_rows.get(r, 0) + n

        def route(stage: int, ids: np.ndarray, rows: dict) -> None:
            """Advance rows through cached labels; buffer the first
            stage that actually needs evaluation."""
            while len(ids):
                if stage == k:
                    accepted.append(ids)
                    return
                casc = cascades[stage]
                st = stats.stages[stage]
                st.rows_in += len(ids)
                cached = store.lookup(casc.key, ids)
                known = cached >= 0
                st.rows_cached += int(known.sum())
                unknown = ~known
                if unknown.any():
                    feed(stage, ids[unknown],
                         {r: v[unknown] for r, v in rows.items()
                          if r in buffers[stage].rows})
                keep = known & (cached == 1)
                ids = ids[keep]
                rows = {r: v[keep] for r, v in rows.items()}
                stage += 1

        def feed(stage: int, ids: np.ndarray, rows: dict) -> None:
            buf = buffers[stage]
            missing = [r for r in buf.rows if r not in rows]
            if missing:
                # cache-skip backfill: rows that hopped over earlier
                # stages on cached labels never saw those stages' flush-
                # time derivation — pool their carry levels straight
                # from base (bit-exact for dyadic pixels, the
                # materialize_pyramid caveat)
                rows = dict(rows)
                imgs = jnp.asarray(self.images[ids])
                for r in missing:
                    rows[r] = np.asarray(resize_area(imgs, r))
                count_levels(missing, len(ids))
            pos = 0
            while pos < len(ids):
                take = min(buf.cap - buf.fill, len(ids) - pos)
                sl = slice(pos, pos + take)
                buf.ids[buf.fill:buf.fill + take] = ids[sl]
                for r in buf.rows:
                    buf.rows[r][buf.fill:buf.fill + take] = rows[r][sl]
                buf.fill += take
                pos += take
                if buf.fill == buf.cap:
                    flush(stage)

        def flush(stage: int) -> None:
            buf = buffers[stage]
            nv = buf.fill
            if nv == 0:
                return
            casc = cascades[stage]
            st = stats.stages[stage]
            bres = tuple(buf.rows)
            down_carry = tuple(r for r in bres
                               if stage + 1 < k and r in needed[stage + 1])
            out_dev = tuple(r for r in derive[stage]
                            if stage + 1 < k and r in needed[stage + 1])
            need_base = base_hw in casc.resolutions or bool(derive[stage])
            fn = self._cascade_fn(
                casc, bres + ((base_hw,) if need_base else ()), out_dev)
            # rows past ``fill`` are stale padding: per-row independence
            # keeps the valid rows' labels exact regardless
            pyr = {r: jnp.asarray(buf.rows[r]) for r in bres}
            if need_base:
                pyr[base_hw] = jnp.asarray(self.images[buf.ids])
            labels, dev_levels = fn(pyr)
            labels = np.asarray(labels)[:nv]
            ids = buf.ids[:nv].copy()
            down = {r: buf.rows[r][:nv].copy() for r in down_carry}
            for r in out_dev:
                down[r] = np.asarray(dev_levels[r])[:nv]
            count_levels(derive[stage], nv)
            buf.fill = 0
            st.rows_evaluated += nv
            st.batches += 1
            store.record(casc.key, ids, labels)
            if monitor is not None:
                # only a FIRST-POSITION flush sees the unfiltered row
                # stream, so only it observes the marginal selectivity
                # (OnlineReorderer.observe; conditional otherwise)
                monitor.observe(casc.key, labels, marginal=stage == 0)
            keep = labels == 1
            route(stage + 1, ids[keep], {r: v[keep]
                                         for r, v in down.items()})

        def apply_order(perm: list) -> None:
            """Re-order the stage pipeline mid-scan: drain every buffer
            under the CURRENT order (exactly the end-of-scan drain, so
            buffered rows complete normally), then permute the
            per-stage structures and rebuild empty buffers with the new
            order's carry lists. The cascade SET is unchanged, so the
            union level set (union_res) stays valid — but the lazy
            schedule is order-dependent and is recomputed."""
            nonlocal needed, ingest_set, carry, derive, small
            for s in range(k):
                flush(s)
            cascades[:] = [cascades[i] for i in perm]
            stats.stages[:] = [stats.stages[i] for i in perm]
            needed, _ = stage_needs(cascades, base_hw)
            ingest_set, carry, derive = level_schedule(
                cascades, base_hw, self.lazy)
            small = list(ingest_set)
            buffers[:] = [_StageBuffer(self.chunk, carry[s])
                          for s in range(k)]
            stats.reorders += 1

        stats.rows_scanned = len(ids_all)
        small = list(ingest_set)
        for lo in range(0, len(ids_all), self.chunk):
            sel = ids_all[lo:lo + self.chunk]
            casc0 = cascades[0]
            cached0 = store.lookup(casc0.key, sel)
            unk = cached0 < 0
            n_unknown = int(unk.sum())
            cached = (self.repcache.lookup_rows(sel, small)
                      if self.repcache is not None and small else None)
            if cached is not None:
                # every ingest level of every chunk row is cached: skip
                # materialization entirely; stage 0 evaluates through
                # its buffer like any later stage
                stats.rep_rows_cached += len(sel)
                route(0, sel, dict(cached))
            elif n_unknown == 0:
                # stage-0 labels all known: no ingest work at all —
                # rows that reach a later unknown stage get their carry
                # levels backfilled at feed time
                route(0, sel, {})
            elif self.fused:
                # fused ingest: pyramid + the FULL first cascade in one
                # program (on TPU with stage0 params, pyramid + level 0
                # are ONE Pallas pass). The whole padded chunk is
                # evaluated; only unknown rows are recorded/counted —
                # known rows keep their stored labels.
                imgs = self.images[sel]
                if len(sel) < self.chunk:  # static-shape pad (one compile)
                    pad = np.repeat(imgs[-1:], self.chunk - len(sel),
                                    axis=0)
                    imgs = np.concatenate([imgs, pad])
                # with a repcache every ingest level is emitted (so the
                # cache sees complete chunks); otherwise only the levels
                # later stages carry leave the program
                out_res = (tuple(ingest_set) if self.repcache is not None
                           else (carry[1] if k > 1 else ()))
                labels, levels = self._ingest_fn(casc0, out_res)(
                    jnp.asarray(imgs))
                labels = np.asarray(labels)[:len(sel)]
                rows = {r: np.asarray(v)[:len(sel)]
                        for r, v in levels.items()}
                stats.chunks += 1
                count_levels(ingest_set, len(sel))
                if self.repcache is not None:
                    for r in small:
                        if r in rows:
                            self.repcache.put_rows(sel, r, rows[r])
                st = stats.stages[0]
                st.rows_in += len(sel)
                st.rows_cached += len(sel) - n_unknown
                st.rows_evaluated += n_unknown
                st.batches += 1
                store.record(casc0.key, sel[unk], labels[unk])
                if monitor is not None:
                    monitor.observe(casc0.key, labels[unk], marginal=True)
                final = np.where(unk, labels, cached0)
                keep = final == 1
                route(1, sel[keep], {r: v[keep] for r, v in rows.items()})
            else:
                # unfused ingest (reference/benchmark baseline): one
                # pyramid program per chunk, stage 0 through its buffer
                imgs = self.images[sel]
                if len(sel) < self.chunk:
                    pad = np.repeat(imgs[-1:], self.chunk - len(sel),
                                    axis=0)
                    imgs = np.concatenate([imgs, pad])
                pyr_fn = self._pyramid_fn(
                    tuple(sorted(set(ingest_set) | {base_hw},
                                 reverse=True)))
                levels = pyr_fn(jnp.asarray(imgs))
                rows = {r: np.asarray(levels[r])[:len(sel)]
                        for r in ingest_set}
                stats.chunks += 1
                count_levels(ingest_set, len(sel))
                if self.repcache is not None:
                    for r in small:
                        self.repcache.put_rows(sel, r, rows[r])
                route(0, sel, rows)
            if monitor is not None and k > 1:
                perm = monitor.propose(cascades)
                if perm is not None:
                    apply_order(perm)
        for s in range(k):                # drain partial buffers in order
            flush(s)

        if accepted:
            out = np.sort(np.concatenate(accepted))
        else:
            out = np.empty(0, np.int64)
        return ScanResult(out, stats)


# ------------------------------------------------------- reference paths --
def naive_scan(images, cascades: Sequence[CompiledCascade],
               metadata: Mapping[str, np.ndarray] | None = None,
               metadata_eq: Mapping | None = None, *, chunk: int = 64,
               jit: bool = True,
               _fn_cache: dict | None = None) -> np.ndarray:
    """The seed workflow: each predicate's cascade runs a FULL corpus scan
    (its own pyramid per chunk, no sharing, no masking); masks are ANDed
    at the end. Bit-identical row set to ScanEngine.execute for the same
    cascades — the engine only removes redundant work. ``_fn_cache``
    (dict) lets benchmarks reuse compiled cascades across calls."""
    import jax
    import jax.numpy as jnp

    images = np.asarray(images, np.float32)
    n = len(images)
    mask = np.ones(n, bool)
    for col, val in (metadata_eq or {}).items():
        mask &= np.asarray(metadata[col]) == val

    cache = _fn_cache if _fn_cache is not None else {}
    for casc in cascades:
        key = (casc.key, chunk)
        if key not in cache:
            # full-width levels, matching ScanEngine (see CompiledCascade)
            caps = [chunk] * (len(casc.model_fns) - 1)
            res = tuple(casc.resolutions)

            def run(imgs, _c=casc, _caps=caps, _res=res):
                # same progressive derivation policy as the engine's
                # shared pyramid, so labels match bit-for-bit
                pyr = materialize_pyramid(imgs, _res)
                return run_cascade_on_pyramid(
                    pyr, _c.model_fns, _c.thresholds, _c.reps, _caps)[0]
            cache[key] = jax.jit(run) if jit else run
        fn = cache[key]
        col = np.zeros(n, np.int8)
        for lo in range(0, n, chunk):
            sel = slice(lo, min(lo + chunk, n))
            imgs = images[sel]
            nv = imgs.shape[0]
            if nv < chunk:
                pad = np.repeat(imgs[-1:], chunk - nv, axis=0)
                imgs = np.concatenate([imgs, pad])
            col[sel] = np.asarray(fn(jnp.asarray(imgs)))[:nv]
        mask &= col == 1
    return np.where(mask)[0]


def make_batch_runner(casc: CompiledCascade, batch_size: int,
                      jit: bool = True) -> Callable[[list], list]:
    """``run_batch`` callable for serve.Batcher / CascadeService: stacks
    request payloads, runs the cascade (pyramid derivation inside
    run_cascade_batch), returns per-request int labels."""
    import jax
    import jax.numpy as jnp

    caps = (list(casc.capacities) if casc.capacities is not None
            else [batch_size] * (len(casc.model_fns) - 1))

    def run(imgs):
        return run_cascade_batch(imgs, casc.model_fns, casc.thresholds,
                                 casc.reps, caps)[0]
    fn = jax.jit(run) if jit else run

    def run_batch(payloads: list) -> list:
        labels = fn(jnp.stack([jnp.asarray(p) for p in payloads]))
        return [int(v) for v in np.asarray(labels)]
    return run_batch
