"""Streaming ingest-time indexing (DESIGN.md §14; ROADMAP top item).

Today's engines scan a COLD resident corpus: every row enters untouched
and all filtering happens at query time. This module adds the
production shape for continuous camera streams (NoScope's difference
detectors + Focus's ingest-time approximate candidate index, PAPERS.md):
as frames arrive, an ``IngestPipeline`` consumes them chunk-by-chunk and
runs two cheap passes whose output — a ``CandidateIndex`` — the query
planner consults as a metadata-like pre-filter, so most queries are
answered from the index instead of a scan:

* a **temporal-difference skip detector**: consecutive frames whose
  downsampled grayscale signatures differ by less than a threshold are
  near-duplicates; each is ALIASED to the last distinct (reference)
  frame and never scored at ingest. Aliased rows inherit the
  reference's candidates and decided labels in 'approx' mode; the
  exactness escape hatch ('exact' mode) never trusts an alias — aliased
  rows are re-verified by the query-time cascade like any cold row;
* an **ingest-time candidate-concept index**: each reference frame runs
  ONE cheap stage-0 cascade rung per planned concept, fused with the
  pyramid via ``core/executor.make_fused_ingest(emit_scores=True)`` (the
  anchor concept's rung also emits the pooled levels the other concepts'
  stage-0 heads read, so the chunk's pyramid is materialized once). The
  scores yield two artifacts with DIFFERENT exactness grades:

  - **exact decided labels**: where stage-0 is confident
    (s0 <= p_low or s0 >= p_high, the cascade's own thresholds), the
    query-time cascade would terminate at stage 0 with the SAME label —
    per-row independence at fixed static shapes makes the ingest score
    bit-identical to the query-time one — so these labels are recorded
    in a ``VirtualColumnStore`` keyed by the cascade and can seed any
    engine/service store verbatim, in both modes;
  - **approximate candidates**: per frame, the concepts whose stage-0
    score clears a recall-knob threshold (p_low shifted by
    ``prune_margin`` toward the undecided band), optionally capped to
    the ``top_k`` best margins (Focus's top-K). A query predicate whose
    concept is NOT in a row's candidate set skips that row's cascade
    entirely — 'approx' mode only, with ``measured_recall`` reporting
    what the knob costs on labeled data.

Query integration: ``plan_query(..., index=...)`` attaches the index to
the ``PhysicalPlan``; ``PhysicalPlan.index_prefilter`` computes the
index-pruned survivor set and both scan engines accept it via
``execute(..., survivors=)``. ``indexed_execute`` below bundles the
seed-store + prefilter + execute sequence. The async service seeds its
store the same way (``AsyncCascadeService(ingest_index=...)``), so
indexed rows are answered at submit with zero model invocations.

Exactness contract (differential-tested in tests/test_ingest.py): in
'exact' mode the indexed row set is bit-identical to a cold
``ScanEngine``/``naive_scan`` for any shard count and skip-detector
setting — only exact decided labels are seeded (identical to what the
cascade computes) and only exact decided-0 rows are pruned (rows the
seeded engine would reject from cache anyway). 'approx' mode trades
bounded recall for skipping aliased and non-candidate rows entirely.

The index must be built from the SAME physical cascades the plan
selects (labels are keyed by ``CompiledCascade.key``); plan first, then
ingest with ``plan.cascades`` — or keep standing per-concept cascades
for both. A mismatched cascade simply contributes no seeds/pruning for
its concept in exact mode (candidates still prune in approx mode, as an
uncalibrated recall knob).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.executor import make_fused_ingest
from repro.core.transforms import color_transform
from repro.engine.scan import CompiledCascade, VirtualColumnStore


# ------------------------------------------------------ skip detector ----
def frame_signature(frames: np.ndarray, res: int = 8) -> np.ndarray:
    """Downsampled grayscale detector signature (B, res, res): channel
    mean then box-mean pooling — pure host numpy, a few hundred bytes
    per frame, the cheap difference feature NoScope's detectors use."""
    frames = np.asarray(frames, np.float32)
    b, hw = frames.shape[0], frames.shape[1]
    res = min(res, hw)
    k = hw // res
    gray = frames[:, : res * k, : res * k].mean(axis=3)
    return gray.reshape(b, res, k, res, k).mean(axis=(2, 4))


@dataclass
class IngestStats:
    frames: int = 0            # frames consumed
    chunks: int = 0            # fused scoring dispatches issued
    refs: int = 0              # distinct (reference) frames scored
    skipped: int = 0           # near-duplicate frames aliased, not scored
    decided_labels: int = 0    # exact stage-0 decisions recorded
    stage0_scores: int = 0     # stage-0 scores computed (refs x concepts)


class CandidateIndex:
    """The ingest pipeline's output: per-row skip-aliases, per-concept
    candidate masks, and a ``VirtualColumnStore`` of exact stage-0
    decided labels (see module docstring for the exactness grades).
    Row-indexed against one corpus; ``save``/``load`` persist it as an
    npz with the same corpus-token guard as the store."""

    def __init__(self, n_rows: int, cascades: Sequence[CompiledCascade],
                 *, top_k: int | None = None, prune_margin: float = 0.25):
        self.n_rows = int(n_rows)
        self.concepts = [c.concept for c in cascades]
        self.cascade_keys = {c.concept: c.key for c in cascades}
        self.top_k = top_k
        self.prune_margin = float(prune_margin)
        self.alias = np.arange(self.n_rows, dtype=np.int64)
        self.indexed = np.zeros(self.n_rows, bool)
        self.candidates = {c: np.zeros(self.n_rows, bool)
                           for c in self.concepts}
        self.scores = {c: np.full(self.n_rows, np.nan, np.float32)
                       for c in self.concepts}
        self.decided = VirtualColumnStore(self.n_rows)

    # ------------------------------------------------------- queries ----
    def survivors(self, ids: np.ndarray,
                  cascades: Sequence[CompiledCascade], *,
                  exact: bool = True) -> np.ndarray:
        """The metadata-like pre-filter: of ``ids``, the rows a scan for
        the AND of ``cascades`` must still consider. Always drops rows
        with an exact own-pixel decided-0 label (the seeded engine would
        reject them from cache — pruning them is a pure work skip, row
        sets unchanged). 'approx' additionally drops rows whose
        skip-alias reference is decided 0 or whose alias-resolved
        candidate set excludes a planned concept (unless decided 1)."""
        ids = np.asarray(ids, np.int64)
        keep = np.ones(len(ids), bool)
        ref = self.alias[ids]
        idx = self.indexed[ids]
        for casc in cascades:
            col = self.decided.column(casc.key)
            keep &= col[ids] != 0
            if exact:
                continue
            ali = col[ref]
            keep &= ~(idx & (ali == 0))
            cand = self.candidates.get(casc.concept)
            if cand is not None:
                keep &= ~(idx & ~cand[ref] & (ali != 1))
        return ids[keep]

    def planning_stats(self, key: tuple, base_sel: float, *,
                       prefilter: bool = True) -> tuple[float, float]:
        """Index-conditioned planning statistics for ONE cascade
        (DESIGN.md §14.5): ``(eval_frac, selectivity)`` where
        ``eval_frac`` is the fraction of candidate rows whose label the
        seeded store does NOT already hold (rows a scan must still
        evaluate — the rest are cache hits), and ``selectivity`` is
        P(label == 1) over the rows the scan will consider, combining
        the index's exact decided counts with ``base_sel`` (the eval-
        split estimate) on the undecided remainder. ``prefilter=True``
        conditions both on the exact-mode survivor set (decided-0 rows
        pruned up front — the conjunctive planner's path);
        ``prefilter=False`` keeps every row in the denominator (the
        algebra executor only SEEDS the store: pruning decided-0 rows
        is unsound under OR/NOT). A ``key`` the index never built
        returns ``(1.0, base_sel)`` unchanged. Per-cascade
        conditioning only — cross-concept prefilter correlation is
        deliberately ignored (each pool entry is priced against its own
        column)."""
        if self.n_rows == 0 or key not in set(self.decided.keys()):
            return 1.0, float(base_sel)
        col = self.decided.column(key)
        n0 = int((col == 0).sum())
        n1 = int((col == 1).sum())
        und = self.n_rows - n0 - n1
        denom = (self.n_rows - n0) if prefilter else self.n_rows
        if denom <= 0:
            return 0.0, 0.0
        sel = (n1 + und * float(base_sel)) / denom
        return und / denom, float(min(max(sel, 0.0), 1.0))

    def seed_store(self, store: VirtualColumnStore, *,
                   exact: bool = True) -> int:
        """Seed an engine/service ``VirtualColumnStore`` from ingest-time
        decisions with merge semantics (a computed label is never
        overwritten). Exact mode copies only own-pixel decided labels —
        bit-identical to what the query-time cascade computes. Approx
        mode additionally propagates a reference frame's labels to its
        skip-aliases (the NoScope approximation). Returns labels
        seeded."""
        n = 0
        for key in self.decided.keys():
            src = self.decided.column(key)
            dst = store.column(key)
            lab = src if exact else np.where(self.indexed,
                                             src[self.alias], src)
            fill = (dst < 0) & (lab >= 0)
            dst[fill] = lab[fill]
            n += int(fill.sum())
        return n

    def measured_recall(self, concept: str, truth: np.ndarray,
                        ids: np.ndarray | None = None) -> float:
        """The recall knob's measured cost on labeled rows: of the
        indexed rows whose ground-truth ``concept`` label is 1, the
        fraction the 'approx' pre-filter keeps (candidate, decided 1,
        or alias thereof). 1.0 means pruning loses nothing on this
        data."""
        ids = (np.arange(self.n_rows, dtype=np.int64) if ids is None
               else np.asarray(ids, np.int64))
        ids = ids[self.indexed[ids]]
        truth = np.asarray(truth)
        pos = ids[truth[ids] == 1]
        if not len(pos):
            return 1.0
        ref = self.alias[pos]
        col = self.decided.column(self.cascade_keys[concept])
        kept = (self.candidates[concept][ref] | (col[ref] == 1)) \
            & (col[ref] != 0) & (col[pos] != 0)
        return float(kept.mean())

    def describe(self, cascades: Sequence[CompiledCascade], *,
                 exact: bool = True) -> str:
        """One EXPLAIN line (PhysicalPlan.explain renders it)."""
        n_idx = int(self.indexed.sum())
        n_alias = int((self.alias != np.arange(self.n_rows))
                      [self.indexed].sum())
        ids = np.arange(self.n_rows, dtype=np.int64)
        surv = len(self.survivors(ids, cascades, exact=exact))
        mode = "exact" if exact else (
            f"approx, top_k={self.top_k}, margin={self.prune_margin:g}")
        frac = surv / self.n_rows if self.n_rows else 1.0
        return (f"{n_idx}/{self.n_rows} rows indexed, {n_alias} "
                f"skip-aliased; prefilter keeps {surv} ({frac:.0%}) "
                f"[{mode}]")

    # --------------------------------------------------- persistence ----
    def save(self, path, token: tuple = ()) -> None:
        """Persist as npz with the corpus-token guard (see
        VirtualColumnStore.save): an ingest-built index loaded against
        a different corpus would alias and prune the wrong rows."""
        data = {"n_rows": np.int64(self.n_rows),
                "token": np.asarray(token, np.float64),
                "top_k": np.int64(-1 if self.top_k is None else self.top_k),
                "prune_margin": np.float64(self.prune_margin),
                "alias": self.alias, "indexed": self.indexed,
                "concepts": np.array(self.concepts),
                "concept_keys": np.array(
                    [repr(self.cascade_keys[c]) for c in self.concepts]),
                "dec_keys": np.array([repr(k)
                                      for k in self.decided.keys()])}
        for c in self.concepts:
            data[f"cand_{c}"] = self.candidates[c]
            data[f"score_{c}"] = self.scores[c]
        for i, k in enumerate(self.decided.keys()):
            data[f"dec_{i}"] = self.decided.column(k)
        np.savez(path, **data)

    @classmethod
    def load(cls, path, token: tuple = ()) -> "CandidateIndex":
        import ast
        with np.load(path, allow_pickle=False) as z:
            if not np.array_equal(z["token"],
                                  np.asarray(token, np.float64)):
                raise ValueError(
                    "CandidateIndex snapshot was saved for a different "
                    "corpus — row-indexed aliases/candidates would "
                    "misattribute rows; refusing to load")
            out = cls.__new__(cls)
            out.n_rows = int(z["n_rows"])
            out.concepts = [str(c) for c in z["concepts"]]
            out.cascade_keys = {
                c: ast.literal_eval(str(k))
                for c, k in zip(out.concepts, z["concept_keys"])}
            tk = int(z["top_k"])
            out.top_k = None if tk < 0 else tk
            out.prune_margin = float(z["prune_margin"])
            out.alias = z["alias"].astype(np.int64)
            out.indexed = z["indexed"].astype(bool)
            out.candidates = {c: z[f"cand_{c}"].astype(bool)
                              for c in out.concepts}
            out.scores = {c: z[f"score_{c}"].astype(np.float32)
                          for c in out.concepts}
            out.decided = VirtualColumnStore(out.n_rows)
            for i, k in enumerate(z["dec_keys"]):
                out.decided._cols[ast.literal_eval(str(k))] = \
                    z[f"dec_{i}"].astype(np.int8)
        return out


class IngestPipeline:
    """Streaming chunk-by-chunk frame consumer building a
    ``CandidateIndex`` (module docstring). Construct with the planned
    cascades and the corpus capacity, then feed arriving frames with
    ``ingest(frames, ids)`` (global row ids; chunks split internally)
    or sweep a resident corpus with ``run(images)``. Stateful across
    calls: the skip detector chains through the previous call's last
    frame, so a camera stream can be fed in any batch granularity."""

    def __init__(self, cascades: Sequence[CompiledCascade], n_rows: int,
                 *, chunk: int = 64, skip: bool = True,
                 skip_threshold: float | None = 0.008, skip_res: int = 8,
                 calib_frames: int = 48,
                 top_k: int | None = None, prune_margin: float = 0.25,
                 jit: bool = True, use_kernel: bool | None = None,
                 int8: bool = False):
        if not cascades:
            raise ValueError("need at least one cascade to index")
        self.cascades = list(cascades)
        self.chunk = int(chunk)
        self.skip = bool(skip)
        # skip_threshold=None LEARNS the per-camera threshold from the
        # first ``calib_frames`` consecutive-frame signature diffs (the
        # warmup window) instead of trusting the pinned default; no
        # frame is skipped until calibration completes, so warmup is
        # conservative (every frame a scored reference), never lossy.
        self.skip_threshold = (None if skip_threshold is None
                               else float(skip_threshold))
        self.calib_frames = int(calib_frames)
        self._calib_diffs: list[float] = []
        self.skip_res = int(skip_res)
        self.jit = jit
        self.use_kernel = use_kernel
        self.int8 = bool(int8)
        self.index = CandidateIndex(n_rows, cascades, top_k=top_k,
                                    prune_margin=prune_margin)
        self.stats = IngestStats()
        self._prev_sig: np.ndarray | None = None
        self._prev_ref: int | None = None
        self._anchor_fn: Callable | None = None
        self._head_fns: list = []

    # ------------------------------------------------- scoring rungs ----
    def _build(self, base_hw: int) -> None:
        """One cheap stage-0 rung per concept, pyramid shared: the
        ANCHOR concept's rung is a truncated (level-0-only) cascade
        through core/executor.make_fused_ingest(emit_scores=True) —
        pyramid + stage-0 one program, the Pallas pyramid+stage-0
        kernel on TPU — emitting the pooled levels the OTHER concepts'
        stage-0 heads read, so per scored chunk the pyramid is
        materialized exactly once."""
        import jax

        c0 = self.cascades[0]
        head_res = [c.reps[0].resolution for c in self.cascades[1:]]
        out_res = tuple(sorted(set(head_res), reverse=True))
        int8 = (self.int8 and c0.stage0 is not None
                and c0.stage0.qparams is not None)
        use_kernel = self.use_kernel if c0.stage0 is not None else False
        self._anchor_fn = make_fused_ingest(
            c0.model_fns[:1], [c0.thresholds[0]], c0.reps[:1], [],
            out_res, stage0=c0.stage0, use_kernel=use_kernel,
            int8=int8, jit=self.jit, emit_scores=True)
        self._head_fns = []
        for c in self.cascades[1:]:
            def head(level, _fn=c.model_fns[0], _rep=c.reps[0]):
                return _fn(color_transform(level, _rep.color))
            self._head_fns.append(jax.jit(head) if self.jit else head)

    def _score_refs(self, frames: np.ndarray) -> np.ndarray:
        """Stage-0 scores (n_ref, n_concepts) for a batch of reference
        frames, padded to the static chunk shape."""
        import jax.numpy as jnp

        nv = len(frames)
        if self._anchor_fn is None:
            self._build(frames.shape[1])
        if nv < self.chunk:
            pad = np.repeat(frames[-1:], self.chunk - nv, axis=0)
            frames = np.concatenate([frames, pad])
        _, levels, s0 = self._anchor_fn(jnp.asarray(frames))
        cols = [np.asarray(s0)[:nv]]
        for c, fn in zip(self.cascades[1:], self._head_fns):
            lvl = levels[c.reps[0].resolution]
            cols.append(np.asarray(fn(lvl))[:nv])
        self.stats.chunks += 1
        self.stats.stage0_scores += nv * len(self.cascades)
        return np.stack(cols, axis=1)

    # ----------------------------------------------------- streaming ----
    def ingest(self, frames: np.ndarray, ids: np.ndarray) -> None:
        """Consume arriving frames (global row ``ids``): detect skips,
        score reference frames, record candidates + exact decided
        labels into the index."""
        frames = np.asarray(frames, np.float32)
        ids = np.asarray(ids, np.int64)
        idx = self.index
        for lo in range(0, len(ids), self.chunk):
            blk = frames[lo:lo + self.chunk]
            bids = ids[lo:lo + self.chunk]
            self.stats.frames += len(bids)
            idx.indexed[bids] = True
            sigs = frame_signature(blk, self.skip_res)
            ref_rows: list[int] = []
            for i, rid in enumerate(bids):
                diff = (float(np.abs(sigs[i] - self._prev_sig).mean())
                        if self._prev_sig is not None else None)
                if diff is not None and self.skip_threshold is None:
                    self._calib_diffs.append(diff)
                    if len(self._calib_diffs) >= self.calib_frames:
                        self.skip_threshold = self.calibrate_threshold(
                            self._calib_diffs)
                dup = (self.skip and diff is not None
                       and self._prev_ref is not None
                       and self.skip_threshold is not None
                       and diff <= self.skip_threshold)
                if dup:
                    idx.alias[rid] = self._prev_ref
                    self.stats.skipped += 1
                else:
                    idx.alias[rid] = rid
                    self._prev_ref = int(rid)
                    ref_rows.append(i)
                self._prev_sig = sigs[i]
            if not ref_rows:
                continue
            ref_rows = np.asarray(ref_rows, np.int64)
            rids = bids[ref_rows]
            scores = self._score_refs(blk[ref_rows])
            self.stats.refs += len(rids)
            margins = np.empty_like(scores)
            for k, casc in enumerate(self.cascades):
                s0 = scores[:, k]
                idx.scores[casc.concept][rids] = s0
                lab, decided, margin = self._grade(casc, s0)
                if decided.any():
                    idx.decided.record(casc.key, rids[decided],
                                       lab[decided])
                    self.stats.decided_labels += int(decided.sum())
                margins[:, k] = margin
            cand = margins > 0.0
            if idx.top_k is not None and idx.top_k < len(self.cascades):
                # Focus-style cap: keep only the top_k best margins
                order = np.argsort(-margins, axis=1, kind="stable")
                capped = np.zeros_like(cand)
                np.put_along_axis(capped, order[:, : idx.top_k], True,
                                  axis=1)
                cand &= capped
            for k, casc in enumerate(self.cascades):
                # decided-1 frames are always candidates; decided-0 never
                col = idx.decided.column(casc.key)[rids]
                idx.candidates[casc.concept][rids] = \
                    (cand[:, k] | (col == 1)) & (col != 0)

    @staticmethod
    def calibrate_threshold(diffs, *, min_ratio: float = 4.0,
                            fallback: float = 0.008) -> float:
        """Per-camera skip threshold from a warmup window of
        consecutive-frame signature diffs (NoScope-style difference-
        detector calibration). On a real stream the diffs are bimodal:
        within-scene sensor jitter sits orders of magnitude below
        scene-change diffs. Sort the diffs and split at the largest
        MULTIPLICATIVE gap between neighbors; the threshold is the
        geometric mean of the gap's endpoints — maximum margin toward
        both clusters, so the margin property the pinned default is
        tested for (tests/test_ingest.py) holds by construction
        whenever the gap ratio exceeds ``min_ratio``². Falls back to
        the pinned default on too few samples or no clear gap (static
        camera: nothing but jitter in the window)."""
        d = np.sort(np.asarray([x for x in diffs if x > 0.0], np.float64))
        if len(d) < 8:
            return float(fallback)
        ratios = d[1:] / d[:-1]
        k = int(np.argmax(ratios))
        if ratios[k] < min_ratio:
            return float(fallback)
        return float(np.sqrt(d[k] * d[k + 1]))

    def _grade(self, casc: CompiledCascade, s0: np.ndarray):
        """(labels, exact-decided mask, candidate margin) for one
        concept's stage-0 scores. Decisions use the cascade's OWN
        thresholds — bit-identical to the query-time stage-0 exit. The
        candidate margin shifts p_low toward the undecided band by
        ``prune_margin`` (the recall knob): margin <= 0 marks a
        non-candidate."""
        lo, hi = casc.thresholds[0]
        if lo is None:               # single-level cascade: stage 0 final
            lab = (s0 >= 0.5).astype(np.int8)
            return lab, np.ones(len(s0), bool), s0 - 0.5
        decided = (s0 <= lo) | (s0 >= hi)
        lab = (s0 >= hi).astype(np.int8)
        tau = lo + self.index.prune_margin * max(0.5 - lo, 0.0)
        return lab, decided, s0 - tau

    def run(self, images: np.ndarray,
            ids: np.ndarray | None = None) -> CandidateIndex:
        """Sweep a resident corpus (or a contiguous stream slice)
        through ``ingest`` in chunk steps; returns the index."""
        images = np.asarray(images, np.float32)
        if ids is None:
            ids = np.arange(len(images), dtype=np.int64)
        self.ingest(images, ids)
        return self.index


# ------------------------------------------------------ orchestration ----
def indexed_execute(engine, plan, *, monitor=None):
    """Execute a ``PhysicalPlan`` carrying an ingest index against a
    scan engine (serial or sharded): seed the engine's store from the
    index (exact-only labels in 'exact' mode, alias-propagated in
    'approx'), pre-filter the metadata survivors through the index, and
    scan only what remains. Returns the engine's ScanResult /
    ShardedScanResult; in 'exact' mode the row set is bit-identical to
    a cold scan of the same plan."""
    exact = plan.index_mode == "exact"
    if plan.index is not None:
        plan.index.seed_store(engine.store, exact=exact)
        surv = plan.index_prefilter(
            np.where(engine.metadata_mask(plan.metadata_eq))[0])
    else:
        surv = None
    return engine.execute(plan.cascades, plan.metadata_eq,
                          survivors=surv, monitor=monitor)
