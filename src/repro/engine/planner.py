"""Logical→physical query planner (DESIGN.md §4.1; paper Fig. 2, §IV–V).

A content-based query = metadata equality predicates AND N
contains-object predicates. The planner turns that LOGICAL query into a
PHYSICAL plan:

1. per predicate, pick ONE cascade from the concept's Pareto frontier
   under the current CostProfile / deployment scenario (core/selector),
   honoring the clause's accuracy/throughput constraint;
2. estimate each selected cascade's per-row cost (the §VI expected
   seconds/image of the evaluated space) and selectivity (positive
   fraction simulated over the cached eval scores — core/selector);
3. order the binary predicates by the classical rank
   cost / (1 - selectivity), ascending — the optimal order for
   independent AND predicates: it minimizes
   Σ_k cost_k · Π_{j<k} selectivity_j
   (NoScope / probabilistic-predicates style predicate ordering).

The resulting PhysicalPlan carries CompiledCascades (engine/scan.py)
plus the estimates, and prints an EXPLAIN-style physical plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

from repro.core.selector import Selection, select
from repro.engine.scan import CompiledCascade


@dataclass
class PredicateClause:
    """Logical contains_object(<concept>) with the user's constraint."""
    concept: str
    min_accuracy: float | None = None
    min_throughput: float | None = None


@dataclass
class QuerySpec:
    """SELECT frames WHERE metadata_eq AND contains(c1) AND ... ."""
    metadata_eq: dict = field(default_factory=dict)
    predicates: list = field(default_factory=list)   # [PredicateClause]


@dataclass
class PlannedPredicate:
    cascade: CompiledCascade
    selection: Selection
    description: str      # human-readable cascade (space.describe)
    rank: float           # cost / (1 - selectivity); plan order key


@dataclass
class PhysicalPlan:
    scenario: str
    metadata_eq: dict
    predicates: list      # [PlannedPredicate] in execution order
    meta_selectivity: float | None = None

    @property
    def cascades(self) -> list:
        return [p.cascade for p in self.predicates]

    def estimated_cost_per_row(self) -> float:
        """Expected engine seconds per metadata-surviving row."""
        return expected_scan_cost(
            [p.cascade.cost_s for p in self.predicates],
            [p.cascade.selectivity for p in self.predicates])

    def explain(self, n_rows: int | None = None,
                shard_plan=None) -> str:
        """EXPLAIN-style physical plan: predicate order, chosen cascade,
        estimated cost + selectivity per predicate, totals. With a
        ``ShardPlan`` (sharding/policy.py) the plan also reports the
        shard layout and the estimated per-shard scan cost."""
        lines = [f"PHYSICAL PLAN  scenario={self.scenario}  "
                 f"binary predicates={len(self.predicates)}"]
        meta = " AND ".join(f"{k} == {v!r}"
                            for k, v in (self.metadata_eq or {}).items())
        if meta:
            sel = ("" if self.meta_selectivity is None
                   else f"   (est. selectivity {self.meta_selectivity:.2f})")
            lines.append(f"  metadata: {meta}{sel}")
        survive = 1.0
        for i, p in enumerate(self.predicates, 1):
            c = p.cascade
            lines.append(
                f"  {i}. contains({c.concept})  cascade[{c.cascade_id}] "
                f"{p.description}")
            lines.append(
                f"     acc={p.selection.accuracy:.3f}  "
                f"cost/row={c.cost_s * 1e6:.1f}us  "
                f"sel={c.selectivity:.2f}  rank={p.rank * 1e6:.1f}us  "
                f"rows reaching: {survive:.2f}")
            survive *= c.selectivity
        naive = sum(p.cascade.cost_s for p in self.predicates)
        eng = self.estimated_cost_per_row()
        lines.append(f"  est. cost/row {eng * 1e6:.1f}us (engine, ordered+"
                     f"masked) vs {naive * 1e6:.1f}us (per-predicate full "
                     f"scans){f'  [{naive / eng:.1f}x]' if eng else ''}")
        if n_rows is not None:
            m = self.meta_selectivity if self.meta_selectivity is not None \
                else 1.0
            lines.append(f"  est. rows: {n_rows} scanned -> "
                         f"{n_rows * m:.0f} past metadata -> "
                         f"{n_rows * m * survive:.0f} returned")
        if shard_plan is not None:
            lines.append(f"  sharding: {shard_plan.describe()}")
            # per-shard cost follows the plan's own (possibly skew-aware)
            # weights: shard i's share of the total estimated scan cost
            total_w = sum(shard_plan.weights) or 1.0
            total_cost = eng * shard_plan.n_rows
            for i, (part, w) in enumerate(zip(shard_plan.shards,
                                              shard_plan.weights)):
                cost = total_cost * w / total_w
                lines.append(f"    shard {i}: {len(part)} rows  "
                             f"weight {w:.3g}  est {cost * 1e3:.1f}ms")
        return "\n".join(lines)


# ----------------------------------------------------------- ordering -----
def predicate_rank(cost: float, selectivity: float) -> float:
    """The ordering key cost / (1 - selectivity): expected spend per unit
    of filtering. A predicate that filters nothing (selectivity 1) ranks
    infinite and goes last. The SAME value is stored on
    PlannedPredicate.rank and shown by EXPLAIN."""
    s = min(max(float(selectivity), 0.0), 1.0)
    denom = 1.0 - s
    return float(cost) / denom if denom > 0.0 else float("inf")


def order_predicates(costs, selectivities) -> list[int]:
    """Optimal evaluation order for independent AND predicates: ascending
    predicate_rank (ties: cheaper first). Greedy-exchange argument:
    swapping adjacent out-of-rank predicates never decreases
    Σ_k c_k · Π_{j<k} s_j — verified against brute force in
    tests/test_query_engine.py."""
    rank = np.array([predicate_rank(c, s)
                     for c, s in zip(costs, selectivities)])
    return list(np.lexsort((np.asarray(costs, np.float64), rank)))


def expected_scan_cost(costs, selectivities, order=None) -> float:
    """Expected per-row cost of an AND chain evaluated in ``order``:
    predicate k only runs on rows surviving 1..k-1."""
    if order is None:
        order = range(len(costs))
    total, p = 0.0, 1.0
    for i in order:
        total += p * float(costs[i])
        p *= float(np.clip(selectivities[i], 0.0, 1.0))
    return total


# ------------------------------------------------------------ planning ----
def plan_query(systems: Mapping, spec: QuerySpec, *,
               scenario: str = "CAMERA", max_level: int = 3,
               metadata: Mapping[str, np.ndarray] | None = None
               ) -> PhysicalPlan:
    """systems: concept -> TahomaSystem (core/pipeline.py) holding the
    trained grid + cached evaluated spaces. metadata: the corpus metadata
    columns, if available, to estimate the metadata selectivity shown in
    EXPLAIN. Returns the ordered PhysicalPlan."""
    planned = []
    for clause in spec.predicates:
        system = systems[clause.concept]
        space = system.cascade_space(scenario, max_level=max_level)
        sel = select(space, min_accuracy=clause.min_accuracy,
                     min_throughput=clause.min_throughput)
        casc = system.compiled_cascade(space, sel.index,
                                       concept=clause.concept)
        planned.append(PlannedPredicate(
            casc, sel,
            space.describe(sel.index, system.bank.names, system.targets),
            predicate_rank(casc.cost_s, casc.selectivity)))

    order = order_predicates([p.cascade.cost_s for p in planned],
                             [p.cascade.selectivity for p in planned])
    planned = [planned[i] for i in order]

    meta_sel = None
    if metadata is not None and spec.metadata_eq:
        mask = np.ones(len(next(iter(metadata.values()))), bool)
        for col, val in spec.metadata_eq.items():
            mask &= np.asarray(metadata[col]) == val
        meta_sel = float(mask.mean())
    return PhysicalPlan(scenario, dict(spec.metadata_eq), planned,
                        meta_sel)
