"""Logical→physical query planner (DESIGN.md §4.1, §11; paper Fig. 2,
§IV–VI).

A content-based query = metadata equality predicates AND N
contains-object predicates. The planner turns that LOGICAL query into a
PHYSICAL plan, in one of two modes:

**Independent** (``joint=False``, the PR-2 planner):

1. per predicate, pick ONE cascade from the concept's Pareto frontier
   under the current CostProfile / deployment scenario (core/selector),
   honoring the clause's accuracy/throughput constraint;
2. estimate each selected cascade's per-row cost (the §VI expected
   seconds/image of the evaluated space) and selectivity (positive
   fraction simulated over the cached eval scores — core/selector);
3. order the binary predicates by the classical rank
   cost / (1 - selectivity), ascending — the optimal order for
   independent AND predicates: it minimizes
   Σ_k cost_k · Π_{j<k} selectivity_j
   (NoScope / probabilistic-predicates style predicate ordering).

**Joint** (``joint=True``, DESIGN.md §11): the scan engine materializes
ONE shared representation pyramid per chunk covering the union of every
selected cascade's levels, so per-predicate standalone costing
double-charges every shared level. Joint planning selects the cascade
SET across all predicates instead: per-predicate Pareto frontiers are
the candidate pools (core/selector.select_candidates), each candidate
carries a decomposed cost (core/costs.DecomposedCost: inference
separated from per-pyramid-level representation handling), and the
search minimizes ``joint_scan_cost`` — shared pyramid levels priced
ONCE, at the survival fraction of the first predicate that touches them;
later predicates pay only their MARGINAL representation cost. The
independent selection is always a member of the search space, so the
joint plan never prices worse than the independent plan (property-tested
in tests/test_joint_planner.py, with a brute-force oracle on tiny
spaces).

Ownership: the planner owns WHAT runs (cascade set, pyramid level set,
predicate order) and hands the engine CompiledCascades; engine/scan.py
owns HOW (chunking, the shared pyramid materialization of exactly
``PhysicalPlan.level_set``, buffering, virtual columns). ``explain()``
prints the EXPLAIN-style physical plan including per-predicate
shared-representation savings. ``OnlineReorderer`` is the planner's
mid-scan hook: the engine feeds observed per-flush selectivities back
and the hook re-orders surviving predicates when the estimates drift —
bit-identical row sets by per-row label independence (DESIGN.md §11.3).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.costs import FULL_LOAD, DecomposedCost
from repro.core.selector import (Selection, estimate_selectivity, select,
                                 select_candidates)
from repro.engine.scan import CompiledCascade


@dataclass
class PredicateClause:
    """Logical contains_object(<concept>) with the user's constraint."""
    concept: str
    min_accuracy: float | None = None
    min_throughput: float | None = None


@dataclass
class QuerySpec:
    """SELECT frames WHERE metadata_eq AND contains(c1) AND ... .

    ``where`` generalizes the conjunctive ``predicates`` list to a full
    boolean expression tree (engine/algebra: And/Or/Not/Pred, or a
    root Join; DESIGN.md §15). When set, ``plan_query`` compiles it via
    the tree algebra instead and returns a TreePlan/JoinPlan —
    ``predicates`` must then be empty."""
    metadata_eq: dict = field(default_factory=dict)
    predicates: list = field(default_factory=list)   # [PredicateClause]
    where: object | None = None                      # algebra expression


@dataclass
class PlannedPredicate:
    cascade: CompiledCascade
    selection: Selection
    description: str      # human-readable cascade (space.describe)
    rank: float           # cost / (1 - selectivity); plan order key
    # joint-plan extras (None/() on independent plans): the §VI cost
    # split, the rep cost NOT covered by earlier predicates' levels, and
    # the pyramid levels inherited from them (DESIGN.md §11)
    decomposed: DecomposedCost | None = None
    marginal_rep_s: float | None = None
    shared_levels: tuple = ()


@dataclass
class PhysicalPlan:
    scenario: str
    metadata_eq: dict
    predicates: list      # [PlannedPredicate] in execution order
    meta_selectivity: float | None = None
    joint: bool = False   # cascade set chosen by the joint optimizer
    costing: str = "paper"   # joint costing mode: 'engine' prices the
    #                          scan paths' full-width (dense) level
    #                          execution with LAZY first-touch level
    #                          materialization (engine/scan
    #                          .level_schedule); 'paper' the §VI
    #                          per-image walk
    # ingest-time candidate-concept index (engine/ingest.CandidateIndex)
    # consulted as a metadata-like pre-filter: rows whose candidate set
    # excludes a planned concept skip that predicate's cascade entirely
    # (DESIGN.md §14). index_mode 'exact' restricts the pre-filter to
    # ingest decisions that are bit-identical to the query-time cascade
    # (own-pixel confident stage-0 labels) — the exactness escape hatch;
    # 'approx' additionally trusts skip-aliases and candidate pruning at
    # the index's measured-recall knob.
    index: object | None = None
    index_mode: str = "exact"

    @property
    def cascades(self) -> list:
        return [p.cascade for p in self.predicates]

    @property
    def level_set(self) -> tuple:
        """Union of pyramid resolutions the plan's cascades touch,
        descending — exactly the per-chunk materialization set the scan
        engine builds (engine/scan.stage_needs adds the raw base)."""
        return tuple(sorted({r.resolution for p in self.predicates
                             for r in p.cascade.reps}, reverse=True))

    def estimated_cost_per_row(self) -> float:
        """Expected engine seconds per metadata-surviving row. Joint
        plans price shared pyramid levels once (joint_scan_cost), at
        the survival fraction of the first stage touching them — the
        engine's LAZY first-touch materialization (dense_reps=False);
        independent plans keep the standalone per-cascade sum."""
        if self.joint and all(p.decomposed is not None
                              for p in self.predicates):
            return joint_scan_cost(
                [p.decomposed for p in self.predicates],
                [p.cascade.selectivity for p in self.predicates],
                dense_reps=False)
        return expected_scan_cost(
            [p.cascade.cost_s for p in self.predicates],
            [p.cascade.selectivity for p in self.predicates])

    def materialization_schedule(self, base_hw: int) -> dict:
        """Non-base pyramid level -> the stage that first materializes
        it under the engine's lazy schedule (engine/scan
        .level_schedule): 0 for chunk-ingest levels (the first
        cascade's own resolutions), s >= 1 for levels first-touch
        derived inside stage s's flush. The measured counterpart is
        ScanStats.level_rows: on a cold scan, an ingest level is pooled
        for every scanned row and a first-touch level for exactly the
        rows its stage evaluates."""
        from repro.engine.scan import level_schedule
        ingest, _, derive = level_schedule(self.cascades, base_hw, True)
        out = {r: 0 for r in ingest}
        for s, res in enumerate(derive):
            for r in res:
                out[r] = s
        return out

    def expected_level_rows(self, n_rows: int, base_hw: int) -> dict:
        """Estimated per-level materialization counts for a COLD scan
        of ``n_rows`` metadata-surviving rows: level -> expected rows
        pooled. Ingest levels are charged for every scanned row; a
        first-touch level for the estimated survivors reaching its
        stage. The measured counterpart is ScanStats.level_rows
        (rendered side by side by ``explain(actual=...)``)."""
        sched = self.materialization_schedule(base_hw)
        survive = [1.0]
        for p in self.predicates:
            survive.append(survive[-1]
                           * min(max(p.cascade.selectivity, 0.0), 1.0))
        return {r: n_rows * survive[s] for r, s in sched.items()}

    def index_prefilter(self, ids: np.ndarray) -> np.ndarray:
        """The metadata-like ingest-index pre-filter (DESIGN.md §14):
        of the metadata-surviving ``ids``, the rows a scan must still
        evaluate. Rows the index already decided 0 for any planned
        predicate — or, in 'approx' mode, rows whose candidate set
        excludes a planned concept — are dropped here and their
        cascades never run. No-op (all ids survive) without an index."""
        ids = np.asarray(ids, np.int64)
        if self.index is None:
            return ids
        return self.index.survivors(ids, self.cascades,
                                    exact=self.index_mode == "exact")

    def unshared_cost_per_row(self) -> float:
        """The SAME cascades and order priced without representation
        sharing (every predicate pays its standalone cost, in this
        plan's costing mode) — the baseline of explain()'s
        shared-representation savings. Under engine costing the
        unshared rep charges are at probability 1 per predicate while
        the joint pricing charges marginal rep costs at the (<= 1)
        survival fraction of the first touch, so savings are always
        >= 0."""
        sels = [p.cascade.selectivity for p in self.predicates]
        if self.joint and self.costing == "engine" and \
                all(p.decomposed is not None for p in self.predicates):
            return (sum(p.decomposed.rep_total_s
                        for p in self.predicates)
                    + expected_scan_cost(
                        [p.decomposed.infer_s for p in self.predicates],
                        sels))
        return expected_scan_cost(
            [p.cascade.cost_s if p.decomposed is None
             else p.decomposed.total_s for p in self.predicates], sels)

    def explain(self, n_rows: int | None = None,
                shard_plan=None, *, base_hw: int | None = None,
                actual=None) -> str:
        """EXPLAIN-style physical plan: predicate order, chosen cascade,
        estimated cost + selectivity per predicate, totals. Joint plans
        additionally print, per predicate, the pyramid levels it touches
        (``levels=``), the levels inherited from earlier predicates
        (``shared=``), and its marginal vs standalone representation
        cost — plus a summary line with the plan-wide
        shared-representation savings and the pyramid level set the
        engine touches. With ``base_hw`` (the corpus base resolution)
        the plan also prints the lazy materialization schedule
        (which stage first touches each level) and the estimated
        per-level row counts; ``actual`` (a ScanStats /
        ShardedScanStats from executing this plan, or a bare
        ``level_rows`` dict) renders measured counts side by side —
        estimated-vs-actual agreement is the engine-costing contract
        (DESIGN.md §13). With a ``ShardPlan`` (sharding/policy.py) the
        plan also reports the shard layout and the estimated per-shard
        scan cost."""
        lines = [f"PHYSICAL PLAN  scenario={self.scenario}  "
                 f"binary predicates={len(self.predicates)}"
                 + (f"  [joint, {self.costing} costing]"
                    if self.joint else "")]
        meta = " AND ".join(f"{k} == {v!r}"
                            for k, v in (self.metadata_eq or {}).items())
        if meta:
            sel = ("" if self.meta_selectivity is None
                   else f"   (est. selectivity {self.meta_selectivity:.2f})")
            lines.append(f"  metadata: {meta}{sel}")
        if self.index is not None:
            lines.append("  ingest index: "
                         + self.index.describe(
                             self.cascades,
                             exact=self.index_mode == "exact"))
        survive = 1.0
        for i, p in enumerate(self.predicates, 1):
            c = p.cascade
            lines.append(
                f"  {i}. contains({c.concept})  cascade[{c.cascade_id}] "
                f"{p.description}")
            lines.append(
                f"     acc={p.selection.accuracy:.3f}  "
                f"cost/row={c.cost_s * 1e6:.1f}us  "
                f"sel={c.selectivity:.2f}  rank={p.rank * 1e6:.1f}us  "
                f"rows reaching: {survive:.2f}")
            if p.decomposed is not None:
                d = p.decomposed
                lvl = ",".join(str(r) for r in
                               sorted(set(d.rep_s) - {FULL_LOAD},
                                      reverse=True))
                sh = (",".join(str(r) for r in p.shared_levels)
                      if p.shared_levels else "-")
                marg = (d.rep_total_s if p.marginal_rep_s is None
                        else p.marginal_rep_s)
                lines.append(
                    f"     levels={{{lvl}}}  shared={{{sh}}}  rep/row "
                    f"marginal {marg * 1e6:.1f}us vs standalone "
                    f"{d.rep_total_s * 1e6:.1f}us  "
                    f"infer/row {d.infer_s * 1e6:.1f}us")
            survive *= c.selectivity
        naive = sum(p.cascade.cost_s if p.decomposed is None
                    else p.decomposed.total_s for p in self.predicates)
        eng = self.estimated_cost_per_row()
        lines.append(f"  est. cost/row {eng * 1e6:.1f}us (engine, ordered+"
                     f"masked) vs {naive * 1e6:.1f}us (per-predicate full "
                     f"scans){f'  [{naive / eng:.1f}x]' if eng else ''}")
        if self.joint:
            unshared = self.unshared_cost_per_row()
            saved = unshared - eng
            ratio = f"  [{unshared / eng:.2f}x]" if eng else ""
            lines.append(
                f"  shared-representation savings: {saved * 1e6:.1f}us/row"
                f" — joint {eng * 1e6:.1f}us vs unshared "
                f"{unshared * 1e6:.1f}us{ratio}; pyramid level set "
                f"{{{','.join(str(r) for r in self.level_set)}}} "
                f"materialized once per chunk")
        if n_rows is not None:
            m = self.meta_selectivity if self.meta_selectivity is not None \
                else 1.0
            lines.append(f"  est. rows: {n_rows} scanned -> "
                         f"{n_rows * m:.0f} past metadata -> "
                         f"{n_rows * m * survive:.0f} returned")
        if base_hw is not None:
            sched = self.materialization_schedule(base_hw)
            if sched:
                lines.append(
                    "  lazy level schedule: " + ", ".join(
                        f"{r}@" + ("ingest" if s == 0
                                   else f"stage{s + 1}")
                        for r, s in sorted(sched.items(), reverse=True)))
                lr = (actual if actual is None or isinstance(actual, dict)
                      else actual.level_rows)
                if n_rows is not None or lr is not None:
                    m = (self.meta_selectivity
                         if self.meta_selectivity is not None else 1.0)
                    est = (self.expected_level_rows(
                        int(round(n_rows * m)), base_hw)
                        if n_rows is not None else {})
                    parts = []
                    for r in sorted(set(est) | set(lr or {}),
                                    reverse=True):
                        e = f"{est[r]:.0f} est" if r in est else "? est"
                        a = (f" -> {int((lr or {}).get(r, 0))} actual"
                             if lr is not None else "")
                        parts.append(f"{r}: {e}{a}")
                    lines.append("  level rows: " + "; ".join(parts))
        if shard_plan is not None:
            lines.append(f"  sharding: {shard_plan.describe()}")
            # per-shard cost follows the plan's own (possibly skew-aware)
            # weights: shard i's share of the total estimated scan cost
            total_w = sum(shard_plan.weights) or 1.0
            total_cost = eng * shard_plan.n_rows
            for i, (part, w) in enumerate(zip(shard_plan.shards,
                                              shard_plan.weights)):
                cost = total_cost * w / total_w
                lines.append(f"    shard {i}: {len(part)} rows  "
                             f"weight {w:.3g}  est {cost * 1e3:.1f}ms")
        return "\n".join(lines)


# ----------------------------------------------------------- ordering -----
def predicate_rank(cost: float, selectivity: float) -> float:
    """The ordering key cost / (1 - selectivity): expected spend per unit
    of filtering. A predicate that filters nothing (selectivity 1) ranks
    infinite and goes last. The SAME value is stored on
    PlannedPredicate.rank and shown by EXPLAIN."""
    s = min(max(float(selectivity), 0.0), 1.0)
    denom = 1.0 - s
    return float(cost) / denom if denom > 0.0 else float("inf")


def order_predicates(costs, selectivities) -> list[int]:
    """Optimal evaluation order for independent AND predicates: ascending
    predicate_rank (ties: cheaper first). Greedy-exchange argument:
    swapping adjacent out-of-rank predicates never decreases
    Σ_k c_k · Π_{j<k} s_j — verified against brute force in
    tests/test_query_engine.py."""
    rank = np.array([predicate_rank(c, s)
                     for c, s in zip(costs, selectivities)])
    return list(np.lexsort((np.asarray(costs, np.float64), rank)))


def expected_scan_cost(costs, selectivities, order=None) -> float:
    """Expected per-row cost of an AND chain evaluated in ``order``:
    predicate k only runs on rows surviving 1..k-1."""
    if order is None:
        order = range(len(costs))
    total, p = 0.0, 1.0
    for i in order:
        total += p * float(costs[i])
        p *= min(max(float(selectivities[i]), 0.0), 1.0)
    return total


# ------------------------------------------- shared-representation cost ---
def joint_scan_cost(decs: Sequence[DecomposedCost], selectivities,
                    order=None, *, dense_reps: bool = False) -> float:
    """Expected per-row cost of an AND chain under shared-representation
    pricing (DESIGN.md §11): predicate k pays its inference plus only
    the pyramid levels NO earlier predicate materialized — each shared
    level is priced once. With ``dense_reps=False`` a level is charged
    at the survival fraction of the first predicate touching it (the
    §VI-style rule); with disjoint level sets this reduces exactly to
    ``expected_scan_cost`` of the standalone totals and never exceeds
    it for any fixed (set, order). With lazy scheduling
    (engine/scan.level_schedule, the engines' default) the scan paths
    materialize each later-stage-only level at first touch BY
    SURVIVORS, so the survival-weighted rule prices exactly what they
    pay — 'engine' costing uses it too. ``dense_reps=True`` charges
    each first-touched level at probability 1 instead, pricing the
    EAGER (``lazy=False``) engine, which materializes the full union
    pyramid at chunk ingest for every scanned row; it is kept as the
    reference/benchmark-baseline pricing."""
    if order is None:
        order = range(len(decs))
    total, p = 0.0, 1.0
    mat: set = set()
    for i in order:
        d = decs[i]
        rep_w = 1.0 if dense_reps else p
        total += p * d.infer_s + rep_w * d.marginal_rep_s(mat)
        mat |= d.levels
        p *= min(max(float(selectivities[i]), 0.0), 1.0)
    return total


def order_predicates_shared(decs: Sequence[DecomposedCost],
                            selectivities, *,
                            exhaustive_limit: int = 6,
                            dense_reps: bool = False) -> list[int]:
    """Evaluation order under shared-representation pricing. Marginal
    rep cost depends on what earlier predicates materialized, so the
    adjacent-exchange argument behind ``order_predicates`` no longer
    applies; for k <= ``exhaustive_limit`` (every realistic query) the
    k! orders are searched exactly — cheap, since ``joint_scan_cost``
    is O(k x levels). Longer chains fall back to the greedy
    marginal-rank rule: repeatedly take the remaining predicate with
    the smallest marginal_cost / (1 - selectivity), accumulating its
    levels into the materialized set (ties: cheaper marginal cost,
    then original position)."""
    k = len(decs)
    if k <= exhaustive_limit:
        best = min(itertools.permutations(range(k)),
                   key=lambda o: (joint_scan_cost(decs, selectivities, o,
                                                  dense_reps=dense_reps),
                                  o))
        return list(best)
    order: list[int] = []
    mat: set = set()
    remaining = list(range(k))
    while remaining:
        pick = min(remaining,
                   key=lambda i: (predicate_rank(decs[i].marginal_s(mat),
                                                 selectivities[i]),
                                  decs[i].marginal_s(mat), i))
        order.append(pick)
        remaining.remove(pick)
        mat |= decs[pick].levels
    return order


# ------------------------------------------------------------ planning ----
def _meta_selectivity(spec: QuerySpec, metadata) -> float | None:
    if metadata is None or not spec.metadata_eq:
        return None
    mask = np.ones(len(next(iter(metadata.values()))), bool)
    for col, val in spec.metadata_eq.items():
        mask &= np.asarray(metadata[col]) == val
    return float(mask.mean())


def plan_query(systems: Mapping, spec: QuerySpec, *,
               scenario: str = "CAMERA", max_level: int = 3,
               metadata: Mapping[str, np.ndarray] | None = None,
               joint: bool = False, costing: str = "engine",
               max_combos: int = 20000, index=None,
               index_mode: str = "exact") -> PhysicalPlan:
    """systems: concept -> TahomaSystem (core/pipeline.py) holding the
    trained grid + cached evaluated spaces. metadata: the corpus metadata
    columns, if available, to estimate the metadata selectivity shown in
    EXPLAIN. ``joint=True`` selects the cascade SET across predicates
    under shared-representation costing (see module docstring; the
    search enumerates at most ``max_combos`` frontier combinations,
    trimming pools cheapest-standalone-first beyond that but always
    retaining the independent selection, which caps the search while
    preserving the never-worse guarantee). ``costing`` (joint only):
    'engine' (default) prices cascades as the scan paths execute them —
    full-width DENSE levels (core/costs.decompose_cascade_cost
    dense_levels) — so the optimizer minimizes what the engine actually
    pays; 'paper' keeps the §VI reach-weighted per-image walk (whose
    totals equal CascadeSpace.time_s). ``index`` attaches an ingest-time
    candidate-concept index (engine/ingest.CandidateIndex) the plan
    consults as a metadata-like pre-filter (PhysicalPlan.index_prefilter,
    DESIGN.md §14); ``index_mode`` is 'exact' (only bit-identical ingest
    decisions prune — the exactness escape hatch, re-verifying
    skip-aliased rows on query) or 'approx' (skip-aliases + candidate
    pruning at the index's measured-recall knob). Returns the ordered
    PhysicalPlan."""
    if index_mode not in ("exact", "approx"):
        raise ValueError(f"unknown index mode {index_mode!r}")
    if getattr(spec, "where", None) is not None:
        # boolean expression tree / cross-corpus join: compile through
        # the tree algebra (engine/algebra, DESIGN.md §15). The index
        # conditions leaf costing and seeds stores (exact labels only —
        # decided-0 pruning is unsound under OR/NOT, so 'approx'
        # prefiltering does not apply to trees).
        from repro.engine.algebra import plan_expression
        if spec.predicates:
            raise ValueError("QuerySpec.where and QuerySpec.predicates "
                             "are mutually exclusive")
        if index is not None and index_mode != "exact":
            raise ValueError("expression trees support index_mode="
                             "'exact' only (seeding, no pruning)")
        return plan_expression(systems, spec.where, scenario=scenario,
                               max_level=max_level, metadata=metadata,
                               metadata_eq=spec.metadata_eq, index=index)
    if joint and spec.predicates:
        if costing not in ("engine", "paper"):
            raise ValueError(f"unknown costing mode {costing!r}")
        plan = _plan_query_joint(systems, spec, scenario=scenario,
                                 max_level=max_level, metadata=metadata,
                                 costing=costing, max_combos=max_combos,
                                 index=index)
        plan.index, plan.index_mode = index, index_mode
        return plan
    planned = []
    for clause in spec.predicates:
        system = systems[clause.concept]
        space = system.cascade_space(scenario, max_level=max_level)
        sel = select(space, min_accuracy=clause.min_accuracy,
                     min_throughput=clause.min_throughput)
        casc = system.compiled_cascade(space, sel.index,
                                       concept=clause.concept)
        planned.append(PlannedPredicate(
            casc, sel,
            space.describe(sel.index, system.bank.names, system.targets),
            predicate_rank(casc.cost_s, casc.selectivity)))

    order = order_predicates([p.cascade.cost_s for p in planned],
                             [p.cascade.selectivity for p in planned])
    planned = [planned[i] for i in order]
    return PhysicalPlan(scenario, dict(spec.metadata_eq), planned,
                        _meta_selectivity(spec, metadata),
                        index=index, index_mode=index_mode)


def _plan_query_joint(systems: Mapping, spec: QuerySpec, *,
                      scenario: str, max_level: int, metadata,
                      costing: str, max_combos: int,
                      index=None) -> PhysicalPlan:
    """Joint cascade-set selection (DESIGN.md §11.2). Candidate pools =
    per-predicate constrained Pareto frontiers; each candidate carries
    (Selection, DecomposedCost, selectivity). The search prices every
    pool combination at its best order (order_predicates_shared) under
    joint_scan_cost, starting from the independent selection as the
    incumbent and replacing it only on strict improvement — so the
    returned plan NEVER prices worse than the independent plan, and a
    brute-force oracle over (set x order) matches it on small spaces
    (tests/test_joint_planner.py). A clause WITHOUT an explicit
    min_accuracy keeps the independent rule's promise (most accurate
    qualifying cascade): its pool is just the independent pick, and only
    ordering + shared-level pricing remain to optimize for it.

    ``index`` (engine/ingest.CandidateIndex, DESIGN.md §14.5) makes the
    search cost candidates against INDEX-REDUCED cardinality instead of
    the full corpus: a candidate whose cascade key the index holds
    decided labels for is priced at its undecided-row fraction
    (DecomposedCost.scaled — rows the seeded store answers cost
    nothing) with its selectivity conditioned on the exact-mode
    prefilter survivors (CandidateIndex.planning_stats). Candidates the
    index never scored keep full-corpus pricing, so the never-worse
    guarantee is preserved within the indexed costing."""
    clauses = spec.predicates
    spaces, pools, ind_pos = [], [], []
    for clause in clauses:
        system = systems[clause.concept]
        space = system.cascade_space(scenario, max_level=max_level)
        ind = select(space, min_accuracy=clause.min_accuracy,
                     min_throughput=clause.min_throughput)
        if clause.min_accuracy is not None:
            cands = select_candidates(space,
                                      min_accuracy=clause.min_accuracy,
                                      min_throughput=clause.min_throughput)
        else:
            # no explicit accuracy floor: the independent rule promises
            # the most accurate (qualifying) cascade — the joint search
            # must not trade that accuracy away for cost, so the pool
            # collapses to the independent pick and only the ORDER and
            # the shared-level pricing remain to optimize
            cands = [ind]
        entries = []
        for s in cands:
            dec = system.decomposed_cost(space, s.index, scenario,
                                         dense_levels=costing == "engine")
            frac = estimate_selectivity(space, s.index, system.eval_scores,
                                        system.p_low, system.p_high)
            if index is not None:
                # candidate-index-aware costing: price this candidate
                # against the rows the index leaves for it (its cascade
                # key, computed without compiling)
                key = (clause.concept, (int(space.kind[s.index]),
                                        int(space.i1[s.index]),
                                        int(space.i2[s.index])))
                eval_frac, frac = index.planning_stats(key, frac,
                                                       prefilter=True)
                dec = dec.scaled(eval_frac)
            entries.append((s, dec, frac))
        spaces.append(space)
        pools.append(entries)
        ind_pos.append(next(j for j, (s, _, _) in enumerate(entries)
                            if s.index == ind.index))

    pools, ind_pos = _trim_pools(pools, ind_pos, max_combos)
    # dense_reps=False in BOTH costing modes: the engines' lazy
    # first-touch schedule charges each level at the survival fraction
    # of the stage that first touches it (level_schedule); 'engine'
    # costing differs from 'paper' in the per-level execution pricing
    # (dense_levels above), not in the rep-charge weighting
    best_combo, best_order, _ = search_joint(
        [[(dec, frac) for _, dec, frac in entries] for entries in pools],
        tuple(ind_pos), dense_reps=False)

    planned = []
    mat: set = set()
    for pos in best_order:
        clause, system, space = clauses[pos], systems[clauses[pos].concept], \
            spaces[pos]
        sel, dec, frac = pools[pos][best_combo[pos]]
        casc = system.compiled_cascade(space, sel.index,
                                       concept=clause.concept)
        marg = dec.marginal_rep_s(mat)
        shared = tuple(sorted((set(dec.rep_s) & mat) - {FULL_LOAD},
                              reverse=True))
        planned.append(PlannedPredicate(
            casc, sel,
            space.describe(sel.index, system.bank.names, system.targets),
            predicate_rank(dec.infer_s + marg, casc.selectivity),
            decomposed=dec, marginal_rep_s=marg, shared_levels=shared))
        mat |= dec.levels
    return PhysicalPlan(scenario, dict(spec.metadata_eq), planned,
                        _meta_selectivity(spec, metadata), joint=True,
                        costing=costing)


def search_joint(pools, incumbent: tuple, *, dense_reps: bool = False,
                 order_budget: int = 200_000):
    """Exhaustive joint cascade-set search. ``pools``: one list of
    (DecomposedCost, selectivity) candidates per predicate;
    ``incumbent``: the tuple of pool positions holding the independent
    selection. Every pool combination is priced at its best order
    (order_predicates_shared) under joint_scan_cost; the incumbent is
    replaced only on STRICT improvement, so the result never prices
    worse than the independent plan. Returns (combo, order, cost) —
    oracle-tested against a full (set x order) enumeration in
    tests/test_joint_planner.py.

    Cost bound: pricing every combo at its exhaustive best order is
    O(n_combos x k!) Python-loop evaluations — fine for the 2-4
    predicate queries here, minutes at k=6 x max_combos pools. When
    that product exceeds ``order_budget``, combos are ranked with the
    greedy marginal-rank order instead and only the winner (and the
    incumbent) get the exhaustive ordering — the set choice becomes
    heuristic at that scale (the pools are already trimmed anyway) but
    the never-worse guarantee is preserved because the incumbent is
    always priced at its exhaustive best order."""
    import math

    k = len(pools)
    n_combos = 1
    for p in pools:
        n_combos *= len(p)
    exhaustive_orders = n_combos * math.factorial(k) <= order_budget

    def combo_cost(combo, exact):
        decs = [pools[i][j][0] for i, j in enumerate(combo)]
        sels = [pools[i][j][1] for i, j in enumerate(combo)]
        order = order_predicates_shared(
            decs, sels, dense_reps=dense_reps,
            exhaustive_limit=6 if exact else 0)
        return joint_scan_cost(decs, sels, order,
                               dense_reps=dense_reps), order

    best_combo = tuple(incumbent)
    best_cost, best_order = combo_cost(best_combo, True)
    for combo in itertools.product(*[range(len(p)) for p in pools]):
        if combo == tuple(incumbent):
            continue
        cost, order = combo_cost(combo, exhaustive_orders)
        if cost < best_cost * (1.0 - 1e-12):
            best_combo, best_cost, best_order = combo, cost, order
    if not exhaustive_orders and best_combo != tuple(incumbent):
        best_cost, best_order = combo_cost(best_combo, True)
    return best_combo, best_order, best_cost


# ------------------------------------------ online selectivity refinement -
class OnlineReorderer:
    """Mid-scan selectivity refinement (DESIGN.md §11.3; ROADMAP item).

    The planner's selectivity estimates come from the eval split and can
    drift on the queried corpus. The scan engine feeds observed labels
    back per evaluation flush (``observe``) and asks at chunk boundaries
    (``propose``) whether the surviving predicate order is still the
    cheapest under the refined estimates; when a predicate with at least
    ``min_rows`` observations has drifted by more than
    ``drift_threshold``, the order is re-derived — with shared-
    representation pricing when the plan carries decomposed costs, the
    classical rank rule otherwise — and the engine re-orders its stage
    pipeline mid-scan (ScanEngine.scan_rows drains its buffers first).

    Exactness: a proposal only ever permutes WHICH rows are evaluated
    early. Every row's per-cascade label is independent of batch
    composition and evaluation order (full-width levels, DESIGN.md
    §4.2), and a row is accepted iff every cascade labels it 1 — a
    conjunction, which is order-invariant. So mid-scan re-ordering
    cannot change the final row set (differential-tested in
    tests/test_joint_planner.py). Refined estimates are adopted whenever
    a drift check fires, so the same drift never re-triggers; ``propose``
    is O(k!) at most (order_predicates_shared) and only runs on drift.

    Conditional vs marginal selectivity (the PR 5 caveat, FIXED here):
    a stage's flushes only ever contain rows that SURVIVED the
    predicates ordered before it, so the observed rate estimates
    P(k | earlier pass), while everything downstream — the rank rule,
    expected_scan_cost, and plan_shards' skew weights via ``refined``
    — needs the marginal P(k). For correlated predicates the two
    differ, and adopting the conditional rate as if marginal can flip
    an ordering the true marginals get right (regression-tested in
    tests/test_ingest.py). The estimator therefore tracks EXPOSURE AT
    FIRST POSITION: the engines flag stage-0 observations
    (``observe(..., marginal=True)``) — stage 0 sees the unfiltered
    row stream, so its positive rate IS the marginal — and only those
    observations refine estimates. Later-stage (conditional)
    observations are accumulated separately for introspection
    (``conditional``) but never drive re-ordering or skew weights;
    predicates that have not yet held first position keep the static
    planner estimate. After a mid-scan re-order a different predicate
    occupies first position and starts accumulating ITS marginal.
    Re-ordering remains EXACT regardless (row sets cannot change) —
    only the cost of the chosen order is at stake.
    """

    def __init__(self, cascades: Sequence[CompiledCascade], *,
                 decomposed: Sequence[DecomposedCost] | None = None,
                 drift_threshold: float = 0.1, min_rows: int = 64,
                 dense_reps: bool = False):
        self.est = {c.key: float(c.selectivity) for c in cascades}
        self.cost = {c.key: float(c.cost_s) for c in cascades}
        self.dec = (dict(zip((c.key for c in cascades), decomposed))
                    if decomposed is not None else None)
        self.dense_reps = dense_reps
        self.drift_threshold = float(drift_threshold)
        # at least one observation: min_rows <= 0 would make observed()
        # trust cascades that never flushed (and KeyError on them)
        self.min_rows = max(1, int(min_rows))
        self.n: dict = {}          # marginal (first-position) exposure
        self.pos: dict = {}
        self.n_cond: dict = {}     # conditional (later-stage) exposure
        self.pos_cond: dict = {}
        self.reorders = 0

    @classmethod
    def from_plan(cls, plan: PhysicalPlan, **kw) -> "OnlineReorderer":
        decs = [p.decomposed for p in plan.predicates]
        # lazy first-touch rep pricing in every costing mode — matches
        # the plan search (see _plan_query_joint) and the engines
        kw.setdefault("dense_reps", False)
        return cls(plan.cascades,
                   decomposed=decs if all(d is not None for d in decs)
                   else None, **kw)

    def observe(self, key: tuple, labels, *, marginal: bool = False) -> None:
        """Fold one evaluation flush's labels into cascade ``key``'s
        observed selectivity. ``marginal=True`` marks a FIRST-POSITION
        flush (stage 0 of the pipeline at flush time — the unfiltered
        stream), the only exposure whose positive rate estimates the
        marginal P(key); anything else is conditional on the earlier
        predicates and is kept out of the refinement estimate."""
        labels = np.asarray(labels)
        if marginal:
            self.n[key] = self.n.get(key, 0) + len(labels)
            self.pos[key] = self.pos.get(key, 0) + int((labels == 1).sum())
        else:
            self.n_cond[key] = self.n_cond.get(key, 0) + len(labels)
            self.pos_cond[key] = (self.pos_cond.get(key, 0)
                                  + int((labels == 1).sum()))

    def observed(self, key: tuple) -> float | None:
        """Marginal selectivity measured at first position, or None
        until ``min_rows`` first-position rows have been seen."""
        n = self.n.get(key, 0)
        return self.pos[key] / n if n >= self.min_rows else None

    def conditional(self, key: tuple) -> float | None:
        """P(key | earlier predicates pass) from later-stage flushes —
        introspection only; never drives re-ordering or skew weights."""
        n = self.n_cond.get(key, 0)
        return self.pos_cond[key] / n if n >= self.min_rows else None

    def refined(self, key: tuple) -> float:
        obs = self.observed(key)
        return self.est[key] if obs is None else obs

    def propose(self, cascades: Sequence[CompiledCascade]) -> list | None:
        """None, or the permutation of ``cascades`` (indices into the
        given order) that is cheaper under refined selectivities."""
        keys = [c.key for c in cascades]
        drifted = any(
            obs is not None and abs(obs - self.est[k]) > self.drift_threshold
            for k in keys for obs in (self.observed(k),))
        if not drifted:
            return None
        sels = [self.refined(k) for k in keys]
        if self.dec is not None and all(k in self.dec for k in keys):
            order = order_predicates_shared([self.dec[k] for k in keys],
                                            sels,
                                            dense_reps=self.dense_reps)
        else:
            order = order_predicates([self.cost[k] for k in keys], sels)
        for k, s in zip(keys, sels):    # adopt: same drift fires once
            self.est[k] = s
        if order == list(range(len(keys))):
            return None
        self.reorders += 1
        return order


def _trim_pools(pools, ind_pos, max_combos: int):
    """Cap the product of pool sizes at ``max_combos`` by keeping each
    pool's cheapest-standalone candidates; the independent pick is
    always retained (the never-worse guarantee needs it enumerable)."""
    total = 1
    for p in pools:
        total *= len(p)
    if total <= max_combos:
        return pools, ind_pos
    cap = max(1, int(max_combos ** (1.0 / len(pools))))
    out_pools, out_ind = [], []
    for pool, ip in zip(pools, ind_pos):
        order = sorted(range(len(pool)), key=lambda j: pool[j][1].total_s)
        keep = order[:cap]
        if ip not in keep:
            keep[-1] = ip
        keep = sorted(set(keep))
        out_pools.append([pool[j] for j in keep])
        out_ind.append(keep.index(ip))
    return out_pools, out_ind
