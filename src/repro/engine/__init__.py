"""Query engine (DESIGN.md §4, §11): logical→physical planner (joint or
independent cascade selection) + unified multi-predicate scan executor
over physically-optimized cascades."""
from repro.engine.ingest import (CandidateIndex, IngestPipeline,
                                 frame_signature, indexed_execute)
from repro.engine.planner import (OnlineReorderer, PhysicalPlan,
                                  PlannedPredicate, PredicateClause,
                                  QuerySpec, expected_scan_cost,
                                  joint_scan_cost, order_predicates,
                                  order_predicates_shared, plan_query,
                                  predicate_rank)
from repro.engine.scan import (CompiledCascade, ScanEngine, ScanResult,
                               ScanStats, VirtualColumnStore,
                               make_batch_runner, naive_scan, stage_needs)
from repro.engine.sharded import (ShardedScanEngine, ShardedScanResult,
                                  ShardedScanStats)

__all__ = [
    "CandidateIndex", "CompiledCascade", "IngestPipeline",
    "OnlineReorderer", "PhysicalPlan",
    "PlannedPredicate", "PredicateClause", "QuerySpec", "ScanEngine",
    "ScanResult", "ScanStats", "ShardedScanEngine", "ShardedScanResult",
    "ShardedScanStats", "VirtualColumnStore", "expected_scan_cost",
    "frame_signature", "indexed_execute", "joint_scan_cost",
    "make_batch_runner", "naive_scan",
    "order_predicates", "order_predicates_shared", "plan_query",
    "predicate_rank", "stage_needs",
]
