"""Query engine (DESIGN.md §4): logical→physical planner + unified
multi-predicate scan executor over physically-optimized cascades."""
from repro.engine.planner import (PhysicalPlan, PlannedPredicate,
                                  PredicateClause, QuerySpec,
                                  expected_scan_cost, order_predicates,
                                  plan_query, predicate_rank)
from repro.engine.scan import (CompiledCascade, ScanEngine, ScanResult,
                               ScanStats, VirtualColumnStore,
                               make_batch_runner, naive_scan, stage_needs)
from repro.engine.sharded import (ShardedScanEngine, ShardedScanResult,
                                  ShardedScanStats)

__all__ = [
    "CompiledCascade", "PhysicalPlan", "PlannedPredicate",
    "PredicateClause", "QuerySpec", "ScanEngine", "ScanResult",
    "ScanStats", "ShardedScanEngine", "ShardedScanResult",
    "ShardedScanStats", "VirtualColumnStore", "expected_scan_cost",
    "make_batch_runner", "naive_scan", "order_predicates", "plan_query",
    "predicate_rank", "stage_needs",
]
