"""Query engine (DESIGN.md §4, §11, §15): logical→physical planner
(joint or independent cascade selection), boolean expression-tree
algebra with cross-corpus temporal joins, + unified multi-predicate
scan executor over physically-optimized cascades."""
from repro.engine.algebra import (AlgebraResult, And, Join, JoinPlan,
                                  JoinResult, Not, Or, PlanNode, Pred,
                                  TreePlan, execute_join, execute_tree,
                                  naive_join_pairs, naive_tree_rows,
                                  normalize, order_children,
                                  plan_expression, temporal_hash_join)
from repro.engine.ingest import (CandidateIndex, IngestPipeline,
                                 frame_signature, indexed_execute)
from repro.engine.planner import (OnlineReorderer, PhysicalPlan,
                                  PlannedPredicate, PredicateClause,
                                  QuerySpec, expected_scan_cost,
                                  joint_scan_cost, order_predicates,
                                  order_predicates_shared, plan_query,
                                  predicate_rank)
from repro.engine.scan import (CompiledCascade, ScanEngine, ScanResult,
                               ScanStats, VirtualColumnStore,
                               make_batch_runner, naive_scan, stage_needs)
from repro.engine.sharded import (ShardedScanEngine, ShardedScanResult,
                                  ShardedScanStats)

__all__ = [
    "AlgebraResult", "And", "CandidateIndex", "CompiledCascade",
    "IngestPipeline", "Join", "JoinPlan", "JoinResult", "Not",
    "OnlineReorderer", "Or", "PhysicalPlan", "PlanNode",
    "PlannedPredicate", "Pred", "PredicateClause", "QuerySpec",
    "ScanEngine", "ScanResult", "ScanStats", "ShardedScanEngine",
    "ShardedScanResult", "ShardedScanStats", "TreePlan",
    "VirtualColumnStore", "execute_join", "execute_tree",
    "expected_scan_cost", "frame_signature", "indexed_execute",
    "joint_scan_cost", "make_batch_runner", "naive_join_pairs",
    "naive_scan", "naive_tree_rows", "normalize", "order_children",
    "order_predicates", "order_predicates_shared", "plan_expression",
    "plan_query", "predicate_rank", "stage_needs", "temporal_hash_join",
]
