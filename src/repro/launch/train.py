"""Training launcher: fault-tolerant LM training on any --arch.

On this container it runs reduced ("smoke") configs on the host mesh; on a
real fleet the same entry point runs the full config on the production
mesh (scripts/launch_pod.sh shows the per-host invocation).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 50 --batch 8 --seq 128 [--full] [--compress topk] \
      [--inject-failure 7] [--ckpt-dir /tmp/ckpt]
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real fleet) vs smoke")
    ap.add_argument("--compress", choices=["none", "topk", "int8"],
                    default="none")
    ap.add_argument("--inject-failure", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_arch, smoke_config
    from repro.data.synthetic import lm_token_batches
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.steps import make_train_step
    from repro.models.factory import build_model, count_params
    from repro.train.compression import int8_compressor, topk_compressor
    from repro.train.optimizer import adamw, cosine_schedule
    from repro.train.runtime import RuntimeConfig, TrainRuntime

    cfg = get_arch(args.arch) if args.full else smoke_config(args.arch)
    mesh = (make_production_mesh() if args.full else make_host_mesh())
    model = build_model(cfg)
    shape = ShapeConfig(name="cli", kind="train", seq_len=args.seq,
                        global_batch=args.batch)
    opt = adamw(cosine_schedule(args.lr, warmup=max(2, args.steps // 10),
                                total=args.steps))
    comp = {"none": None, "topk": topk_compressor(0.05),
            "int8": int8_compressor()}[args.compress]
    step_fn, info = make_train_step(model, mesh, shape, opt,
                                    compressor=comp)

    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} {info}")
    opt_state = opt.init(params)
    if comp is not None:
        opt_state = {"opt": opt_state, "residual": comp.init(params)}

    data = list(lm_token_batches(cfg.vocab_size, args.batch, args.seq,
                                 args.steps + 1, seed=0))
    extras = {}
    if cfg.family == "audio":
        extras["enc_frames"] = np.random.default_rng(0).standard_normal(
            (args.batch, cfg.encoder.n_frames, cfg.d_model)).astype(
                np.float32) * 0.1
    if cfg.family == "vlm":
        extras["mrope_positions"] = np.broadcast_to(
            np.arange(args.seq, dtype=np.int32)[None, None],
            (3, args.batch, args.seq)).copy()

    def batches(step):
        return {**data[step % len(data)], **extras}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    rt = TrainRuntime(jax.jit(step_fn, donate_argnums=(0, 1)),
                      RuntimeConfig(ckpt_dir, ckpt_every=args.ckpt_every),
                      mesh=mesh)
    if args.inject_failure >= 0:
        rt.inject_failure_at = {args.inject_failure}

    with mesh:
        params, opt_state, hist = rt.run(params, opt_state, batches,
                                         num_steps=args.steps)
    losses = [h["loss"] for h in hist]
    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f} | "
          f"recoveries={rt.recoveries} "
          f"stragglers={len(rt.straggler.flagged)}")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
