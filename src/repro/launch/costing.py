"""Roofline cost extraction (DESIGN.md §7).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically in this container), which undercounts scanned layer stacks by
~L x. We therefore derive the three roofline terms ourselves:

  * FLOPs  — exact walk of the step function's jaxpr (dot_general / conv
    einsum math), multiplying scan bodies by their static trip counts. The
    jaxpr is post-autodiff, so backward and remat recompute FLOPs are
    counted exactly. Logical (global) FLOPs; per-device = /chips (all
    large ops are sharded; head-padding waste is included in the shapes).
  * HBM bytes — analytic obligatory-traffic model (params/grads/optimizer
    streams, remat-boundary activations, attention score materialization,
    logits, KV-cache reads) — the classical roofline accounting; raw
    ``cost_analysis`` numbers are kept in the artifact for reference.
  * Collective bytes — parsed from the compiled (post-SPMD) HLO text,
    per computation, multiplied by enclosing while-loop trip counts
    (recovered from each loop condition's comparison constant).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass


# ---------------------------------------------------------------- jaxpr ----
def _prod(xs):
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = _prod(a.shape[i] for i in lb)
    contract = _prod(a.shape[i] for i in lc)
    m = _prod(a.shape[i] for i in range(a.ndim)
              if i not in lb and i not in lc)
    n = _prod(b.shape[i] for i in range(b.ndim)
              if i not in rb and i not in rc)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    groups = eqn.params.get("feature_group_count", 1)
    k_spatial = _prod(rhs.shape[i] for i in dn.rhs_spec[2:])
    in_ch = rhs.shape[dn.rhs_spec[1]]
    return 2.0 * _prod(out.shape) * k_spatial * in_ch / max(groups, 1) \
        * 1.0  # in_ch already per-group in HLO rhs layout


_CALL_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def jaxpr_flops(jaxpr) -> float:
    """Total FLOPs of a (closed) jaxpr, scan-aware."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0.0
    for eqn in jx.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif name == "scan":
            total += eqn.params["length"] * jaxpr_flops(eqn.params["jaxpr"])
        elif name == "while":
            total += jaxpr_flops(eqn.params["body_jaxpr"])  # trip unknown: x1
        elif name == "cond":
            total += max((jaxpr_flops(b) for b in eqn.params["branches"]),
                         default=0.0)
        else:
            recursed = False
            for k in _CALL_PARAM_KEYS:
                if k in eqn.params:
                    total += jaxpr_flops(eqn.params[k])
                    recursed = True
                    break
            if not recursed and name == "custom_vjp_call":
                pass
            elif not recursed:
                # elementwise/reduction etc: 1 flop per output element
                total += sum(_prod(o.aval.shape) for o in eqn.outvars
                             if hasattr(o.aval, "shape"))
    return total


# ------------------------------------------------- HLO collective parsing --
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_COLL = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_REF = re.compile(r"(?:body|condition|to_apply|calls)=\{?%?([\w\.\-]+)")
_WHILE = re.compile(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,"
                    r"\s*body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "f64": 8, "s64": 8,
               "u64": 8}


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            if line[:1] in ("%", "E") and line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            comps[cur].append(line)
            if line.strip() == "}":
                cur = None
    return comps


def _entry_name(hlo: str) -> str | None:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                return m.group(1)
    return None


def parse_collectives(hlo: str) -> dict:
    """Collective output bytes per device, trip-count corrected, by type."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo) or next(iter(comps), None)

    def trip_count(cond_name: str) -> int:
        ints = [int(x) for line in comps.get(cond_name, [])
                for x in _CONST_INT.findall(line)]
        return max(ints) if ints else 1

    bytes_by: dict[str, float] = {}
    count_by: dict[str, int] = {}
    seen: set[tuple[str, float]] = set()

    def visit(name: str, mult: float, depth=0):
        if depth > 50 or (name, mult) in seen:
            return
        seen.add((name, mult))
        for line in comps.get(name, []):
            cm = _COLL.search(line)
            if cm:
                dt, dims, kind = cm.groups()
                n = _prod(int(d) for d in dims.split(",") if d) if dims \
                    else 1
                bytes_by[kind] = bytes_by.get(kind, 0.0) \
                    + n * DTYPE_BYTES.get(dt, 4) * mult
                count_by[kind] = count_by.get(kind, 0) + int(mult)
            wm = _WHILE.search(line)
            if wm:
                cond, body = wm.groups()
                visit(body, mult * trip_count(cond), depth + 1)
                continue
            for ref in _REF.findall(line):
                if ref in comps and ref != name:
                    visit(ref, mult, depth + 1)

    if entry:
        visit(entry, 1.0)
    return {"bytes_by_type": bytes_by, "count_by_type": count_by,
            "total_bytes": sum(bytes_by.values())}


# ------------------------------------------------------- analytic memory ---
@dataclass
class MemModel:
    total: float
    breakdown: dict


def _layer_act_bytes(arch, tokens: int, seq: int, chunked_attn: bool) -> float:
    """Forward HBM traffic per layer for activations (bf16), one pass."""
    d = arch.d_model
    by = 2.0
    t = float(tokens)
    total = 4 * t * d * by  # block in/out + two norms
    if arch.family == "ssm" or (arch.family == "hybrid"):
        di = arch.d_inner_padded
        total += t * (2 * di + 2 * arch.conv_dim_padded) * by
    if arch.uses_attention and arch.family != "ssm":
        if arch.mla is not None:
            m = arch.mla
            hdim = arch.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            total += t * (hdim + 2 * arch.n_heads * m.v_head_dim
                          + m.kv_lora_rank * 3) * by
        else:
            from repro.models.attention import layout_from_cfg
            lo = layout_from_cfg(arch)
            total += t * (2 * lo.hp + 2 * lo.khp) * arch.head_dim * by
        if not chunked_attn and seq > 1:
            from repro.models.attention import layout_from_cfg
            hp = (arch.n_heads if arch.mla is not None
                  else layout_from_cfg(arch).hp)
            batch = tokens // seq
            total += batch * hp * float(seq) ** 2 * 4.0  # fp32 scores
    if arch.moe is not None:
        cap_tokens = t * arch.moe.top_k * arch.moe.capacity_factor
        total += 3 * cap_tokens * arch.moe.d_ff_expert * by
        if arch.moe.num_shared_experts:
            total += 3 * t * arch.moe.num_shared_experts \
                * arch.moe.d_ff_shared * by
    elif arch.d_ff:
        total += 3 * t * arch.d_ff * by
    return total


def analytic_bytes(kind: str, arch, shape, n_params: int, n_micro: int,
                   cache_bytes: float, chips: int,
                   weight_read_factor: float = 1.0) -> MemModel:
    """Global HBM traffic per step (per-device = /chips; all large tensors
    are sharded). Documented model — see module docstring."""
    b, s = shape.global_batch, shape.seq_len
    tokens = b * (1 if kind == "decode" else s)
    vp = arch.padded_vocab()
    chunked = (kind == "prefill" and s > 8192) or (
        kind == "train" and getattr(shape, "train_attn_chunk", 0) > 0)
    layers = arch.n_layers + (arch.encoder.n_layers
                              if arch.encoder else 0)
    br: dict[str, float] = {}
    if kind == "train":
        recompute_reads = 1 if shape.remat_policy != "none" else 0
        br["weights"] = n_params * 2.0 * (2 + recompute_reads) * n_micro
        br["grad_accum"] = n_params * 4.0 * 2 * n_micro
        br["optimizer"] = n_params * (4 * 2 * 2 + 2 + 2)
        per_layer = _layer_act_bytes(arch, tokens // n_micro, s, chunked)
        # fwd (1x) + recompute (1x) + bwd reads/writes (~2x)
        br["activations"] = per_layer * layers * n_micro \
            * (2 + 2 * recompute_reads)
        br["boundaries"] = tokens * arch.d_model * 2.0 * layers * 2
        br["logits"] = tokens * vp * 2.0 * 3  # write, read in loss, bwd
    elif kind == "prefill":
        # params_tp_only: weights replicated across the dp axes -> each
        # device streams its full TP shard (global-equivalent x dp).
        br["weights"] = n_params * 2.0 * weight_read_factor
        br["activations"] = _layer_act_bytes(arch, tokens, s, chunked) \
            * layers
        logit_positions = b if getattr(shape, "prefill_last_only", False) \
            else tokens
        br["logits"] = logit_positions * vp * 2.0
        br["cache_write"] = cache_bytes
    else:  # decode
        br["weights"] = n_params * 2.0 * weight_read_factor
        br["cache_read"] = cache_bytes
        br["cache_write"] = cache_bytes / max(float(s), 1.0)
        br["activations"] = _layer_act_bytes(arch, tokens, 1, False) * layers
        br["logits"] = tokens * vp * 2.0
    return MemModel(total=sum(br.values()), breakdown=br)


def tree_bytes(shapes_tree) -> float:
    import jax
    import numpy as np
    return float(sum(np.prod(x.shape) * x.dtype.itemsize
                     for x in jax.tree.leaves(shapes_tree)))
