"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state.

``jax.sharding.AxisType`` (and ``make_mesh(axis_types=...)``) only exist on
newer JAX releases; this module degrades gracefully to plain meshes on the
installed version (every axis defaults to Auto semantics there anyway).
"""
from __future__ import annotations

import inspect

import jax


def _axis_type_kwargs(n: int) -> dict:
    """{'axis_types': (Auto,)*n} when the installed JAX supports it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins
        return {}
    if "axis_types" not in params:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh_compat(shape, axes):
    """jax.make_mesh across JAX versions (with Auto axis types when the
    installed version distinguishes them)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model_axis: int = 1, data_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data_axis = data_axis or (n // model_axis)
    return make_mesh_compat((data_axis, model_axis), ("data", "model"))


# --------------------------------------------------- scan-shard placement --
def host_device_count() -> int:
    """Devices visible to this process. On CPU CI this is 1 unless
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` was set
    before the first jax import (tests/conftest.py does)."""
    return len(jax.devices())


def shard_devices(n_shards: int | None = None) -> list:
    """Device placement for the sharded scan engine (DESIGN.md §9): one
    device per shard executor, round-robin when shards outnumber
    devices. The pmap lockstep path only uses the leading
    ``min(n_shards, device_count)`` distinct devices; the round-robin
    tail is for callers that drive shards individually."""
    devs = jax.devices()
    if n_shards is None:
        n_shards = len(devs)
    return [devs[i % len(devs)] for i in range(n_shards)]
