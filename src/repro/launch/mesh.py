"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_axis: int = 1, data_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data_axis = data_axis or (n // model_axis)
    return jax.make_mesh((data_axis, model_axis), ("data", "model"),
                         axis_types=_auto(2))
