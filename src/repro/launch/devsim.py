"""Simulated multi-device host bootstrap (DESIGN.md §9).

jax reads ``XLA_FLAGS`` once, at first import — so forcing the host
platform to expose N simulated devices must happen before anything
imports jax. This module deliberately imports nothing heavy; call
``force_host_devices`` at the very top of an entry point, before the
repro imports. tests/conftest.py applies the same flag for the test
suite (inline, so it also runs before the hypothesis shim setup).
"""
from __future__ import annotations

import os
import sys

_DEVFLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int = 8, *, when_flag: str | None = None) -> None:
    """Idempotently force the XLA host platform to expose ``n`` devices.

    No-op when jax is already imported (the flag would be read too late)
    or when the operator already set a device count. ``when_flag``
    restricts the bootstrap to invocations carrying that CLI flag, in
    either the ``--flag value`` or ``--flag=value`` spelling."""
    if when_flag is not None and not any(
            a == when_flag or a.startswith(when_flag + "=")
            for a in sys.argv):
        return
    if "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVFLAG in flags:
        return
    os.environ["XLA_FLAGS"] = f"{flags} {_DEVFLAG}={n}".strip()
