"""Step builders: train_step / prefill_step / decode_step with production
shardings, microbatched gradient accumulation, and ShapeDtypeStruct
input_specs for the dry-run (no allocation).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.factory import Model, build_model
from repro.sharding import policy
from repro.train.optimizer import adamw

MOE_AUX_COEF = 0.01


# ------------------------------------------------------------------ loss ---
def lm_loss(logits, labels, vocab_size: int):
    """Next-token CE; labels already aligned (labels[t] = target at t);
    label < 0 masks. Handles vocab padding by masking padded columns."""
    vp = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    if vp > vocab_size:
        col = jnp.arange(vp)
        lg = lg + jnp.where(col < vocab_size, 0.0, -1e9)[None, None, :]
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    lab = jnp.clip(labels, 0, vocab_size - 1)
    gold = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * valid) / jnp.maximum(jnp.sum(valid), 1.0)


# ------------------------------------------------------------ input specs --
def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _dp(mesh):
    dp = policy.dp_axes(mesh)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[a] for a in policy.dp_axes(mesh))


def batch_shardable(shape_cfg: ShapeConfig, mesh) -> bool:
    return shape_cfg.global_batch % dp_size(mesh) == 0


def input_specs(arch: ArchConfig, shape_cfg: ShapeConfig, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    dp = _dp(mesh) if batch_shardable(shape_cfg, mesh) else None
    dt = jnp.dtype(arch.dtype)
    batch: dict[str, Any] = {}
    if shape_cfg.kind == "decode":
        batch["tokens"] = _sds((b, 1), jnp.int32, mesh, P(dp, None))
    else:
        batch["tokens"] = _sds((b, s), jnp.int32, mesh, P(dp, None))
        if shape_cfg.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32, mesh, P(dp, None))
    if arch.family == "audio":
        batch["enc_frames"] = _sds((b, arch.encoder.n_frames, arch.d_model),
                                   dt, mesh, P(dp, None, None))
    if arch.family == "vlm":
        sl = 1 if shape_cfg.kind == "decode" else s
        batch["mrope_positions"] = _sds((3, b, sl), jnp.int32, mesh,
                                        P(None, dp, None))
        if shape_cfg.kind != "decode":
            batch["vision_embeds"] = _sds((b, arch.vision.n_patches,
                                           arch.d_model), dt, mesh,
                                          P(dp, None, None))
    return batch


# ------------------------------------------------------------ cache specs --
def cache_pspecs(cache_shapes, shape_cfg: ShapeConfig, mesh):
    """Decode-cache PartitionSpecs. batch-shardable cells: batch over dp,
    cache sequence over 'model' (flash-decoding style LSE combine is left
    to SPMD). long-context (batch=1): sequence over 'data', heads/channels
    over 'model'."""
    shardable = batch_shardable(shape_cfg, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = _dp(mesh)

    def div(axis, dim: int):
        """axis (or axis tuple) only if it divides dim, else None."""
        if axis is None:
            return None
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        prod = 1
        for a in axes:
            prod *= sizes.get(a, 1)
        return axis if prod > 1 and dim % prod == 0 else None

    def leaf_spec(path, x):
        name = policy.leaf_name(path)
        nd = len(x.shape)
        if name == "pos":
            return P(div(dp, x.shape[0])) if shardable else P()
        b_ax = div(dp, x.shape[1]) if (shardable and nd > 1) else None
        if name in ("k", "v"):            # (L,B,T,KH,Dh)
            seq_ax = div("model" if shardable else "data", x.shape[2])
            kh_ax = None
            if not shardable:
                kh_ax = div("model", x.shape[3])
            return P(None, b_ax, seq_ax, kh_ax, None)
        if name in ("k_scale", "v_scale"):
            seq_ax = div("model" if shardable else "data", x.shape[2])
            return P(None, b_ax, seq_ax, None)
        if name in ("c_kv", "k_rope"):    # (L,B,T,r)
            seq_ax = div("model" if shardable else "data", x.shape[2])
            return P(None, b_ax, seq_ax, None)
        if name in ("conv_x", "conv_b", "conv_c"):  # (L,B,ch,K-1)
            return P(None, b_ax, div("model", x.shape[2]), None)
        if name == "state":               # (L,B,H,P,N)
            return P(None, b_ax, div("model", x.shape[2]), None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def cache_specs_sds(model: Model, shape_cfg: ShapeConfig, mesh):
    shapes = jax.eval_shape(
        functools.partial(model.init_cache, shape_cfg.global_batch,
                          shape_cfg.seq_len, shape_cfg.kv_dtype))
    specs = cache_pspecs(shapes, shape_cfg, mesh)
    return jax.tree.map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs)


# ------------------------------------------------------------ train step ---
def _split_micro(x, n_micro: int, batch_axis: int):
    b = x.shape[batch_axis]
    mb = b // n_micro
    shape = x.shape[:batch_axis] + (n_micro, mb) + x.shape[batch_axis + 1:]
    x = x.reshape(shape)
    return jnp.moveaxis(x, batch_axis, 0)


def make_train_step(model: Model, mesh, shape_cfg: ShapeConfig,
                    optimizer=None, aux_coef: float = MOE_AUX_COEF,
                    compressor=None):
    """compressor: optional train.compression.Compressor — when given, the
    opt_state becomes {"opt": ..., "residual": ...} and gradients go
    through an error-feedback compress->decompress round trip ahead of the
    optimizer (stands in for the pre-reduce compression on a real fleet)."""
    cfg = model.cfg
    optimizer = optimizer or adamw(1e-4)
    dpn = dp_size(mesh)
    per_shard = max(1, shape_cfg.global_batch // dpn)
    n_micro = max(1, per_shard // max(shape_cfg.microbatch_seqs_per_shard, 1))
    while shape_cfg.global_batch % n_micro:
        n_micro -= 1
    moe_groups = dpn if shape_cfg.global_batch % dpn == 0 else 1

    batch_axes = {"mrope_positions": 1}

    train_chunk = shape_cfg.train_attn_chunk or (
        shape_cfg.attn_chunk if shape_cfg.seq_len > 8192 else 0)
    acc_dtype = jnp.dtype(shape_cfg.grad_accum_dtype)

    def loss_fn(params, micro):
        logits, aux, _ = model.forward(
            params, micro, remat_policy=shape_cfg.remat_policy,
            attn_chunk=train_chunk, moe_groups=moe_groups)
        loss = lm_loss(logits, micro["labels"], cfg.vocab_size)
        return loss + aux_coef * aux, loss

    def train_step(params, opt_state, batch):
        with policy.use_ctx_mesh(mesh):
            batch = {k: policy.constrain_batch(v, mesh)
                     if k != "mrope_positions" else v
                     for k, v in batch.items()}
            micros = {k: _split_micro(v, n_micro, batch_axes.get(k, 0))
                      for k, v in batch.items()}
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                                 params)

            def micro_step(carry, micro):
                g_acc, l_acc = carry
                (_, loss), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, micro)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(acc_dtype),
                                     g_acc, grads)
                return (g_acc, l_acc + loss), None

            (g_acc, loss_sum), _ = jax.lax.scan(micro_step, (zeros, 0.0),
                                                micros)
            grads = jax.tree.map(lambda g: g / n_micro, g_acc)
            if compressor is not None:
                grads, resid, _ = compressor.apply(
                    grads, opt_state["residual"])
                params2, opt2, om = optimizer.update(
                    grads, opt_state["opt"], params)
                opt2 = {"opt": opt2, "residual": resid}
            else:
                params2, opt2, om = optimizer.update(grads, opt_state,
                                                     params)
            metrics = {"loss": loss_sum / n_micro, **om}
            return params2, opt2, metrics

    return train_step, {"n_micro": n_micro, "moe_groups": moe_groups}


# ------------------------------------------------------ serve step fns -----
def make_prefill_step(model: Model, mesh, shape_cfg: ShapeConfig):
    dpn = dp_size(mesh)
    moe_groups = dpn if shape_cfg.global_batch % dpn == 0 else 1

    def prefill_step(params, batch):
        with policy.use_ctx_mesh(mesh):
            batch = {k: policy.constrain_batch(v, mesh)
                     if k != "mrope_positions" else v
                     for k, v in batch.items()}
            return model.prefill(params, batch,
                                 attn_chunk=shape_cfg.attn_chunk,
                                 kv_dtype=shape_cfg.kv_dtype,
                                 moe_groups=moe_groups,
                                 last_only=shape_cfg.prefill_last_only)
    return prefill_step


def make_decode_step(model: Model, mesh, shape_cfg: ShapeConfig):
    def decode_step(params, cache, batch):
        with policy.use_ctx_mesh(mesh):
            logits, new_cache = model.decode(params, cache, batch,
                                             moe_groups=1)
            return logits, new_cache
    return decode_step


# --------------------------------------------------------- param helpers ---
def abstract_params(model: Model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _drop_fsdp(spec: P) -> P:
    """Serving-mode param sharding: keep TP ('model'), drop ZeRO axes —
    weights stay resident instead of being all-gathered every step."""
    def clean(part):
        if part is None:
            return None
        axes = (part,) if isinstance(part, str) else tuple(part)
        keep = tuple(a for a in axes if a == "model")
        return keep[0] if len(keep) == 1 else (keep if keep else None)
    return P(*(clean(p) for p in spec))


def params_sds(model: Model, mesh, tp_only: bool = False):
    shapes = abstract_params(model)
    specs = policy.param_pspecs(shapes, mesh)
    if tp_only:
        specs = jax.tree.map(_drop_fsdp, specs,
                             is_leaf=lambda x: isinstance(x, P))
    shards = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda sh, sd: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=sd),
        shapes, shards), shards


def opt_state_sds(optimizer, params_shapes, mesh):
    shapes = jax.eval_shape(optimizer.init, params_shapes)
    shards = policy.param_shardings(shapes, mesh)
    return jax.tree.map(
        lambda sh, sd: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=sd),
        shapes, shards), shards


def count_params_from_shapes(shapes) -> int:
    return sum(math.prod(x.shape) if x.shape else 1
               for x in jax.tree.leaves(shapes))


def count_active_params(shapes, arch: ArchConfig) -> int:
    """MoE: non-routed params + top_k/E of routed expert params."""
    if arch.moe is None:
        return count_params_from_shapes(shapes)
    total = routed = 0

    def visit(path, x):
        nonlocal total, routed
        n = math.prod(x.shape) if x.shape else 1
        total += n
        if policy.leaf_name(path) in ("w_gate_e", "w_up_e", "w_down_e"):
            routed += n
    jax.tree_util.tree_map_with_path(visit, shapes)
    frac = arch.moe.top_k / arch.moe.num_experts
    return int(total - routed + routed * frac)
