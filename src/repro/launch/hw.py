"""TPU v5e hardware constants for the roofline model (per chip)."""
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (spec: chips x link_bw)
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
