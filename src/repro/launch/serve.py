"""Serving launcher: prefill + batched decode for any --arch (smoke scale
on this container; full configs lower on a real fleet).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
      --batch 8 --prompt-len 64 --gen 32 [--kv-dtype int8]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "float32", "int8"])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import get_arch, smoke_config
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.factory import build_model, count_params

    cfg = get_arch(args.arch) if args.full else smoke_config(args.arch)
    mesh = make_production_mesh() if args.full else make_host_mesh()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,} "
          f"kv={args.kv_dtype}")

    rng = np.random.default_rng(0)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))

    total = s + args.gen
    prefill = jax.jit(lambda p, bt: model.prefill(
        p, bt, kv_dtype=args.kv_dtype))
    decode = jax.jit(lambda p, c, bt: model.decode(p, c, bt))

    with mesh:
        logits, cache = prefill(params, batch)
        # grow cache capacity to prompt+gen
        def grow(path, x):
            name = next((str(e.key) for e in reversed(path)
                         if isinstance(e, jax.tree_util.DictKey)), "")
            in_cross = any(isinstance(e, jax.tree_util.DictKey)
                           and str(e.key) == "cross" for e in path)
            if name in ("k", "v", "c_kv", "k_rope", "k_scale", "v_scale") \
                    and not in_cross and x.ndim >= 3:
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, args.gen)
                return jnp.pad(x, pad)
            return x
        cache = jax.tree_util.tree_map_with_path(grow, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        out_toks = [tok]
        for i in range(args.gen):
            db = {"tokens": tok}
            if cfg.family == "vlm":
                db["mrope_positions"] = jnp.full((3, b, 1), s + i,
                                                 jnp.int32)
            logits, cache = decode(params, cache, db)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out_toks.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    print(f"decoded {args.gen} toks x batch {b} in {dt:.3f}s "
          f"({args.gen * b / dt:.1f} tok/s) | sample: "
          f"{np.asarray(jnp.concatenate(out_toks, 1))[0, :8]}")


if __name__ == "__main__":
    main()
