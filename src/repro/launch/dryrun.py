import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, account roofline terms
(launch/costing.py), and write one JSON artifact per cell.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep [--mesh both] [--variant v --set k=v]
"""
import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_flops(kind: str, n_active: int, global_batch: int,
                seq_len: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward-only); D = tokens."""
    tokens = global_batch * (1 if kind == "decode" else seq_len)
    return (6.0 if kind == "train" else 2.0) * n_active * tokens


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             overrides: dict, variant: str = "") -> dict:
    import jax
    from repro.configs.registry import get_arch
    from repro.configs.shapes import SHAPES, shape_applicable
    from repro.launch import costing, hw, steps
    from repro.launch.mesh import make_production_mesh
    from repro.models.factory import build_model

    t0 = time.time()
    arch = get_arch(arch_name).replace(head_pad_to=16)
    shape = SHAPES[shape_name]
    shape_kw = {k: v for k, v in overrides.items()
                if k in type(shape).__dataclass_fields__}
    arch_kw = {k: v for k, v in overrides.items()
               if k in type(arch).__dataclass_fields__}
    if shape_kw:
        import dataclasses
        shape = dataclasses.replace(shape, **shape_kw)
    if arch_kw:
        arch = arch.replace(**arch_kw)

    if overrides.get("tuned"):
        from repro.configs.deployment import tuned_shape
        shape = tuned_shape(arch, shape)

    ok, reason = shape_applicable(arch, shape)
    mesh_name = "multi" if multi_pod else "single"
    meta = dict(arch=arch_name, shape=shape_name, mesh=mesh_name,
                variant=variant, overrides=overrides)
    if not ok:
        return {**meta, "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(arch)
    p_sds, _ = steps.params_sds(model, mesh,
                                tp_only=shape.params_tp_only)
    batch = steps.input_specs(arch, shape, mesh)

    cache_bytes = 0.0
    if shape.kind == "train":
        from repro.train.optimizer import adamw
        opt = adamw(1e-4)
        step_fn, info = steps.make_train_step(model, mesh, shape, opt)
        o_sds, _ = steps.opt_state_sds(opt, steps.abstract_params(model),
                                       mesh)
        args = (p_sds, o_sds, batch)
        jit_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    elif shape.kind == "prefill":
        step_fn = steps.make_prefill_step(model, mesh, shape)
        args = (p_sds, batch)
        jit_fn = jax.jit(step_fn)
        info = {"n_micro": 1}
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     shape.kv_dtype))
        cache_bytes = costing.tree_bytes(cache_shapes)
    else:
        step_fn = steps.make_decode_step(model, mesh, shape)
        c_sds = steps.cache_specs_sds(model, shape, mesh)
        cache_bytes = costing.tree_bytes(c_sds)
        args = (p_sds, c_sds, batch)
        jit_fn = jax.jit(step_fn, donate_argnums=(1,))
        info = {"n_micro": 1}

    with mesh:
        jaxpr = jax.make_jaxpr(step_fn)(*args)
        flops_global = costing.jaxpr_flops(jaxpr)
        del jaxpr
        lowered = jit_fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    print("memory_analysis:", ma)
    print("cost_analysis (raw, while-bodies-once) flops/bytes:",
          ca.get("flops"), ca.get("bytes accessed"))
    coll = costing.parse_collectives(compiled.as_text())

    n_shapes = steps.abstract_params(model)
    n_total = steps.count_params_from_shapes(n_shapes)
    n_active = steps.count_active_params(n_shapes, arch)
    wf = (steps.dp_size(mesh)
          if shape.params_tp_only and shape.kind != "train" else 1.0)
    mem = costing.analytic_bytes(shape.kind, arch, shape, n_total,
                                 info.get("n_micro", 1), cache_bytes,
                                 chips, weight_read_factor=wf)
    mf = model_flops(shape.kind, n_active, shape.global_batch,
                     shape.seq_len)

    flops_dev = flops_global / chips
    bytes_dev = mem.total / chips
    coll_dev = float(coll["total_bytes"])
    terms = {
        "compute_s": flops_dev / hw.PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / hw.HBM_BW,
        "collective_s": coll_dev / hw.ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    mem_stats = {f: getattr(ma, f) for f in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")} if ma else {}

    return {
        **meta, "status": "ok", "chips": chips, "step_info": info,
        "seconds": {"lower": round(t_lower, 1),
                    "compile": round(t_compile, 1)},
        "per_device": {"hlo_flops": flops_dev, "hbm_bytes": bytes_dev,
                       "collective_bytes": coll_dev},
        "global": {"hlo_flops": flops_global, "hbm_bytes": mem.total,
                   "collective_bytes": coll_dev * chips},
        "mem_breakdown_global": mem.breakdown,
        "collectives": coll,
        "xla_cost_analysis_raw": {k: float(ca[k]) for k in
                                  ("flops", "bytes accessed") if k in ca},
        "memory_analysis_per_device": mem_stats,
        "cache_bytes_global": cache_bytes,
        "params": {"total": n_total, "active": n_active},
        "model_flops_global": mf,
        "useful_flops_ratio": mf / flops_global if flops_global else None,
        "roofline_terms_s": terms, "dominant": dominant,
        "step_time_bound_s": bound_s,
        "roofline_fraction": (terms["compute_s"] / bound_s
                              if bound_s else None),
    }


def cell_path(arch: str, shape: str, mesh: str, variant: str = "") -> Path:
    v = f"__{variant}" if variant else ""
    safe = arch.replace("/", "_").replace(".", "_")
    return ART_DIR / f"{safe}__{shape}__{mesh}{v}.json"


def all_cells():
    from repro.configs.registry import ARCHS
    from repro.configs.shapes import SHAPES
    for a in ARCHS:
        for s in SHAPES:
            yield a, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="override: key=value (shape or arch field)")
    ap.add_argument("--tuned", action="store_true",
                    help="apply configs/deployment.py tuned settings")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    if args.tuned:
        overrides["tuned"] = True

    ART_DIR.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.sweep:
        for arch, shape in all_cells():
            for mesh in meshes:
                path = cell_path(arch, shape, mesh, args.variant)
                if path.exists() and not args.force:
                    print(f"skip (exists): {path.name}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh]
                if args.variant:
                    cmd += ["--variant", args.variant]
                if args.tuned:
                    cmd += ["--tuned"]
                for kv in args.set:
                    cmd += ["--set", kv]
                print(f"=== {arch} x {shape} x {mesh}", flush=True)
                try:
                    r = subprocess.run(cmd, timeout=args.timeout,
                                       capture_output=True, text=True)
                    if r.returncode != 0:
                        err = (r.stderr or "")[-2000:]
                        path.write_text(json.dumps(
                            dict(arch=arch, shape=shape, mesh=mesh,
                                 variant=args.variant, status="error",
                                 error=err), indent=1))
                        print(f"ERROR: {err[-400:]}", flush=True)
                    else:
                        print(r.stdout[-400:], flush=True)
                except subprocess.TimeoutExpired:
                    path.write_text(json.dumps(
                        dict(arch=arch, shape=shape, mesh=mesh,
                             variant=args.variant, status="timeout"),
                        indent=1))
                    print("TIMEOUT", flush=True)
        return

    assert args.arch and args.shape, "--arch/--shape required (or --sweep)"
    for mesh in meshes:
        res = run_cell(args.arch, args.shape, mesh == "multi", overrides,
                       args.variant)
        path = cell_path(args.arch, args.shape, mesh, args.variant)
        path.write_text(json.dumps(res, indent=1, default=str))
        print(json.dumps({k: res.get(k) for k in (
            "arch", "shape", "mesh", "status", "roofline_terms_s",
            "dominant", "useful_flops_ratio", "roofline_fraction",
            "reason")}, indent=1, default=str))


if __name__ == "__main__":
    main()
