"""Roofline table from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json and renders the per-(arch x shape x mesh)
three-term roofline with the dominant bottleneck, MODEL_FLOPS ratio, and
skip annotations. ``--markdown`` writes EXPERIMENTS.md §Roofline's table.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(variant: str = "") -> list[dict]:
    rows = []
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("variant", "") == variant:
            rows.append(d)
    return rows


def fmt_row(d: dict) -> dict:
    if d["status"] != "ok":
        return {"arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
                "status": d["status"],
                "note": d.get("reason", d.get("error", ""))[:60]}
    t = d["roofline_terms_s"]
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "status": "ok",
        "compute_s": t["compute_s"], "memory_s": t["memory_s"],
        "collective_s": t["collective_s"], "dominant":
            d["dominant"].replace("_s", ""),
        "useful": d["useful_flops_ratio"],
        "frac": d["roofline_fraction"],
        "bound_s": d["step_time_bound_s"],
    }


def render(rows, markdown: bool = False) -> str:
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s",
           "collective_s", "dominant", "useful", "roofline_frac"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(f"{'arch':22s} {'shape':12s} {'mesh':6s} "
                     f"{'compute_s':>10s} {'memory_s':>10s} "
                     f"{'collect_s':>10s} {'dom':>10s} {'useful':>7s} "
                     f"{'frac':>8s}")
    for d in rows:
        r = fmt_row(d)
        if r["status"] != "ok":
            cells = [r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                     r["status"], "-", r.get("note", "")]
        else:
            cells = [r["arch"], r["shape"], r["mesh"],
                     f"{r['compute_s']:.3g}", f"{r['memory_s']:.3g}",
                     f"{r['collective_s']:.3g}", r["dominant"],
                     f"{r['useful']:.2f}", f"{r['frac']:.4f}"]
        if markdown:
            lines.append("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            lines.append(f"{cells[0]:22s} {cells[1]:12s} {cells[2]:6s} "
                         f"{cells[3]:>10s} {cells[4]:>10s} {cells[5]:>10s} "
                         f"{cells[6]:>10s} {cells[7]:>7s} {cells[8]:>8s}")
    return "\n".join(lines)


def bench_roofline(csv=None):
    rows = load()
    singles = [r for r in rows if r["mesh"] == "single"]
    ok = [r for r in singles if r["status"] == "ok"]
    print(render(singles))
    if csv is not None and ok:
        import numpy as np
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        csv.add("roofline_cells_ok", 0.0,
                f"{len(ok)}/{len(singles)} single-pod cells ok "
                f"(+{len(rows)-len(singles)} multi-pod)")
        csv.add("roofline_worst_cell", 0.0,
                f"{worst['arch']}x{worst['shape']} "
                f"frac={worst['roofline_fraction']:.5f} "
                f"dom={worst['dominant']}")
        csv.add("roofline_median_frac", 0.0,
                f"{np.median([r['roofline_fraction'] for r in ok]):.4f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--mesh", default="")
    args = ap.parse_args()
    rows = load(args.variant)
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]
    print(render(rows, markdown=args.markdown))


if __name__ == "__main__":
    main()
