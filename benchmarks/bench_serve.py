"""Serving benchmark: the shard-aware AsyncCascadeService (DESIGN.md
§10 — deadline wheel, per-shard device queues, dispatch-ahead,
virtual-column commit, cross-query representation cache) against the
synchronous-polling CascadeService baseline (serve/batcher.py), on 8
simulated host devices. Writes ``BENCH_serve.json`` at the repo root
(``--quick``: artifacts/bench/BENCH_serve.quick.json).

  PYTHONPATH=src python -m benchmarks.bench_serve [--quick]

Protocol: one resident frame corpus, two concepts with 2-level CNN
cascades (random-init params — serving cost is inference shape, not
accuracy), and an interactive mixed request stream where a fraction of
requests re-asks hot frames (the paper's ONGOING scenario: users
revisit). Both services run the identical stream; labels must agree
request-for-request (the async path runs full-width levels, so its
labels are the exact ScanEngine semantics). Each mode is timed over
fresh-state repeats with compilation pre-warmed (shared fn caches), so
the curve prices serving machinery — queueing, flush policy, padding,
store/representation reuse, dispatch-ahead — not jit compile time.

The sync baseline recomputes every request; the async service answers
re-asked decided frames from the shard-owned virtual columns with zero
model invocations, pads deadline flushes to power-of-2 buckets instead
of full batch width, and overlaps host assembly with device compute.
On real multi-chip hosts the 8 shard queues also run concurrently; on
shared-core CPU CI most of the headline comes from the reuse + padding
wins, which are device-count independent.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# the serving bench models an 8-device host; the device-count flag must
# land before the repro imports below pull jax in
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.transforms import Representation  # noqa: E402
from repro.data.synthetic import DEFAULT_PREDICATES, make_corpus  # noqa: E402
from repro.engine.scan import CompiledCascade, make_batch_runner  # noqa: E402
from repro.models.cnn import cnn_predict_proba, init_cnn  # noqa: E402
from repro.serve import (AsyncCascadeService, CascadeService,  # noqa: E402
                         RepresentationCache, Request)

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_serve.json"
QUICK = ROOT / "artifacts" / "bench" / "BENCH_serve.quick.json"


def build_cascades(hw: int = 32, seed: int = 0) -> dict:
    """Two concepts, each a 2-level cascade (gray@16 -> rgb@hw) with
    random-init CNNs: realistic inference shapes, zero training time."""
    out = {}
    for i, spec in enumerate(DEFAULT_PREDICATES[:2]):
        rep_fast = Representation(16, "gray")
        rep_full = Representation(hw, "rgb")
        fast = TahomaCNNConfig(1, 8, 16, input_hw=16, input_channels=1)
        full = TahomaCNNConfig(2, 16, 32, input_hw=hw, input_channels=3)
        p_fast = init_cnn(jax.random.PRNGKey(seed + 2 * i), fast)
        p_full = init_cnn(jax.random.PRNGKey(seed + 2 * i + 1), full)
        out[spec.name] = CompiledCascade(
            concept=spec.name, cascade_id=("bench-2level", spec.name),
            reps=[rep_fast, rep_full],
            model_fns=[lambda z, p=p_fast: cnn_predict_proba(p, z),
                       lambda z, p=p_full: cnn_predict_proba(p, z)],
            thresholds=[(0.3, 0.7), (None, None)])
    return out


def make_stream(n_requests: int, n_corpus: int, concepts, *,
                hot: int = 64, repeat: float = 0.5, seed: int = 13):
    """Interactive mixed stream: every concept is asked about every
    frame the session walks (the multi-predicate session: "does frame X
    contain a? ...contain b?"), and ``repeat`` of late requests re-ask a
    frame from the hot set (users revisit). Cross-concept overlap is
    what the representation cache monetizes: concept b's batches reuse
    the pooled levels concept a's flushes published. Returns
    [(concept, row)]."""
    rng = np.random.default_rng(seed)
    stream = []
    for i in range(n_requests):
        c = concepts[i % len(concepts)]
        if i >= 2 * hot and rng.uniform() < repeat:
            row = int(rng.integers(0, hot))
        else:
            row = (i // len(concepts)) % n_corpus
        stream.append((c, row))
    return stream


def run_sync(corpus, runners, stream, batch_size, max_wait_s) -> tuple:
    svc = CascadeService(runners, batch_size, max_wait_s)
    reqs = []
    t0 = time.perf_counter()
    for i, (c, row) in enumerate(stream):
        r = Request(i, jnp.asarray(corpus[row]))
        svc.submit(c, r)
        reqs.append(r)
        svc.poll()
    svc.drain()
    dt = time.perf_counter() - t0
    return dt, [int(r.result) for r in reqs], np.array(svc.latencies())


def run_async(corpus, cascades, stream, batch_size, max_wait_s, *,
              shards, fn_cache) -> tuple:
    svc = AsyncCascadeService(corpus, cascades, shards=shards,
                              batch_size=batch_size,
                              max_wait_s=max_wait_s,
                              repcache=RepresentationCache(64 << 20),
                              fn_cache=fn_cache)
    reqs = []
    t0 = time.perf_counter()
    for i, (c, row) in enumerate(stream):
        r = Request(i, row)
        svc.submit(c, r)
        reqs.append(r)
        svc.poll()
    svc.drain()
    dt = time.perf_counter() - t0
    return dt, [int(r.result) for r in reqs], \
        np.array(svc.latencies()), svc.summary()


def _pcts(lat: np.ndarray) -> dict:
    lat = lat * 1e3
    return {"p50_ms": round(float(np.percentile(lat, 50)), 2),
            "p99_ms": round(float(np.percentile(lat, 99)), 2)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller stream (CI smoke), writes under "
                         "artifacts/bench/")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--repeat", type=float, default=0.5)
    args = ap.parse_args()

    n_requests = args.requests or (256 if args.quick else 2048)
    n_corpus = 192 if args.quick else 768
    batch_size = args.batch_size
    max_wait_s = 0.002
    repeats = 2 if args.quick else 3

    print(f"[bench] {n_requests} requests over {n_corpus} frames, "
          f"batch={batch_size}, repeat={args.repeat}, "
          f"{jax.device_count()} devices")
    cascades = build_cascades()
    corpus = np.ascontiguousarray(
        (np.random.default_rng(7).integers(0, 256, (n_corpus, 32, 32, 3))
         .astype(np.float32) / 256.0))
    concepts = list(cascades)
    stream = make_stream(n_requests, n_corpus, concepts,
                         repeat=args.repeat)

    # pre-compile both paths so the timed repeats price serving
    # machinery, not jit; runners/fn caches are shared across the
    # fresh-state repeat services. The async warmup exercises every
    # (device, concept, slab width, variant) executable — the serving
    # cold-start elimination the subsystem ships with.
    runners = {c: make_batch_runner(casc, batch_size)
               for c, casc in cascades.items()}
    async_fns: dict[int, dict] = {1: {}, 8: {}}
    run_sync(corpus, runners, stream[: 4 * batch_size], batch_size,
             max_wait_s)
    for k in async_fns:
        svc = AsyncCascadeService(corpus, cascades, shards=k,
                                  batch_size=batch_size,
                                  fn_cache=async_fns[k])
        t0 = time.perf_counter()
        n = svc.warmup()
        print(f"  warmup shards={k}: {n} executables in "
              f"{time.perf_counter() - t0:.1f}s")

    # ---- timed fresh-state repeats --------------------------------------
    sync_best, sync_labels, sync_lat = None, None, None
    for _ in range(repeats):
        dt, labels, lat = run_sync(corpus, runners, stream, batch_size,
                                   max_wait_s)
        if sync_best is None or dt < sync_best:
            sync_best, sync_labels, sync_lat = dt, labels, lat
    sync_tput = n_requests / sync_best
    print(f"  sync   : {sync_best:.3f}s  {sync_tput:7.0f} req/s  "
          f"{_pcts(sync_lat)}")

    curve = []
    for k in (1, 8):
        best = None
        for _ in range(repeats):
            dt, labels, lat, summ = run_async(
                corpus, cascades, stream, batch_size, max_wait_s,
                shards=k, fn_cache=async_fns[k])
            if best is None or dt < best[0]:
                best = (dt, labels, lat, summ)
        dt, labels, lat, summ = best
        identical = labels == sync_labels
        if not identical:
            print(f"[bench] ERROR: async labels diverged at {k} shards")
        entry = {
            "shards": k,
            "devices": summ["devices"],
            "wall_s": round(dt, 4),
            "requests_per_s": round(n_requests / dt, 1),
            "speedup_vs_sync_x": round(sync_best / dt, 2),
            **_pcts(lat),
            "identical_labels": bool(identical),
            "store_hits": summ["store_hits"],
            "store_hit_rate": round(summ["store_hit_rate"], 4),
            "rows_evaluated": summ["rows_evaluated"],
            "batches": summ["batches"],
            "padded_slots": summ["padded_slots"],
            "deadline_flushes": summ["deadline_flushes"],
            "size_flushes": summ["size_flushes"],
            "repcache_hit_rate": summ["repcache"]["hit_rate"],
            "repcache": summ["repcache"],
        }
        curve.append(entry)
        print(f"  async{k:2d}: {dt:.3f}s  {entry['requests_per_s']:7.0f} "
              f"req/s  {entry['speedup_vs_sync_x']}x vs sync  "
              f"store_hit_rate={entry['store_hit_rate']}  "
              f"repcache_hit_rate={entry['repcache_hit_rate']}")

    peak = next(c for c in curve if c["shards"] == 8)
    report = {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "physical_cores": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "protocol":
            "identical mixed 2-concept request stream through the sync "
            "batcher and the async service (fresh state per repeat, "
            "compilation pre-warmed, min over repeats). The async "
            "service answers re-asked decided frames from shard-owned "
            "virtual columns (zero invocations), pads partial flushes "
            "to power-of-2 buckets, and defers block_until_ready to "
            "delivery (dispatch-ahead). Labels are checked "
            "request-for-request against the sync baseline.",
        "requests": n_requests,
        "corpus_rows": n_corpus,
        "batch_size": batch_size,
        "max_wait_s": max_wait_s,
        "repeat_fraction": args.repeat,
        "sync": {"wall_s": round(sync_best, 4),
                 "requests_per_s": round(sync_tput, 1),
                 **_pcts(sync_lat)},
        "async_curve": curve,
        "speedup_8dev_x": peak["speedup_vs_sync_x"],
        "repcache_hit_rate_8dev": peak["repcache_hit_rate"],
        "all_identical": all(c["identical_labels"] for c in curve),
    }
    out = QUICK if args.quick else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}  (async @8 devices: "
          f"{report['speedup_8dev_x']}x vs sync batcher)")


if __name__ == "__main__":
    main()
