"""Cascade-space evaluation + representation-transform throughput bench
(referenced by core/cascade.py; starts the perf trajectory for this PR's
two subsystems). Writes ``BENCH_cascade_eval.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.bench_eval_speed [--quick]

Measured:
  1. evaluate->Pareto-select end-to-end, dense (evaluate_cascades +
     pareto_indices over the full N-cascade arrays — the seed workflow)
     vs streaming (evaluate_cascades_streaming: chunked jitted blocks
     folded into the streaming frontier; never materializes N arrays).
     Same grid, identical frontier, cascades/sec compared.
  2. the streaming evaluator on a ~10x larger cascade space, with peak
     traced memory required to stay under the dense base-grid peak.
  3. transform throughput: one progressive pyramid pass materializing
     every representation (core/transforms.materialize_representations)
     vs the seed's per-representation from-base path, in images/sec and
     analytic bytes moved.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import tracemalloc
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.cascade import (evaluate_cascades,                 # noqa: E402
                                evaluate_cascades_streaming)
from repro.core.costs import CostProfile                           # noqa: E402
from repro.core.pareto import pareto_indices                       # noqa: E402
from repro.core.thresholds import compute_thresholds_batch         # noqa: E402
from repro.core.transforms import (Representation,                 # noqa: E402
                                   apply_transform,
                                   materialize_representations,
                                   pyramid_bytes_moved,
                                   representation_space,
                                   transform_cost)

OUT = Path(__file__).resolve().parents[1] / "BENCH_cascade_eval.json"
TARGETS = (0.91, 0.93, 0.95, 0.97, 0.99)


def make_grid(m_models: int, n_img: int = 1000, seed: int = 0):
    """Synthetic paper-scale evaluation state (scores already cached —
    the regime §V-E measures)."""
    rng = np.random.default_rng(seed)
    truth = rng.integers(0, 2, n_img)
    scores = np.clip(truth[None] * 0.4
                     + rng.normal(0.3, 0.25, (m_models, n_img)),
                     0, 1).astype(np.float32)
    p_low, p_high = compute_thresholds_batch(scores, truth, list(TARGETS))
    reps = [Representation([28, 56, 112, 224][j % 4],
                           ["rgb", "r", "g", "b", "gray"][j % 5])
            for j in range(m_models)]
    reps[-1] = Representation(224, "rgb")
    infer = rng.uniform(1e-5, 1e-2, m_models)
    profile = CostProfile.modeled({}, list(set(reps)), 224)
    return dict(scores=scores, truth=truth, p_low=p_low, p_high=p_high,
                reps=reps, infer=infer, profile=profile,
                trusted=m_models - 1)


def n_cascades(m: int, t: int = len(TARGETS)) -> int:
    return m + (m * t) * m + (m * t) * (m * t)


def _traced_peak(fn) -> int:
    """Traced numpy peak bytes of one run (memory is measured in a
    SEPARATE run from timing: tracemalloc inflates python-heavy code)."""
    tracemalloc.start()
    fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return int(peak)


def bench_dense(grid) -> dict:
    def evaluate():
        return evaluate_cascades(
            grid["scores"], grid["truth"], grid["p_low"], grid["p_high"],
            grid["reps"], grid["infer"], grid["profile"], "CAMERA",
            trusted=grid["trusted"])
    t0 = time.perf_counter()
    sp = evaluate()
    t_eval = time.perf_counter() - t0
    fr = pareto_indices(sp.acc, sp.throughput)
    dt = time.perf_counter() - t0
    peak = _traced_peak(evaluate)
    # the select pass adds the lexsort key/order arrays over all N
    peak += 3 * 8 * len(sp)
    return {
        "n_cascades": int(sp.evaluated),
        "eval_s": round(t_eval, 3),
        "pareto_select_s": round(dt - t_eval, 3),
        "total_s": round(dt, 3),
        "cascades_per_s": round(sp.evaluated / dt),
        "peak_bytes": int(peak),
        "frontier": sorted((int(sp.kind[i]), int(sp.i1[i]), int(sp.i2[i]))
                           for i in fr),
    }


def bench_streaming(grid, chunk: int) -> dict:
    def run():
        return evaluate_cascades_streaming(
            grid["scores"], grid["truth"], grid["p_low"], grid["p_high"],
            grid["reps"], grid["infer"], grid["profile"], "CAMERA",
            trusted=grid["trusted"], chunk=chunk)
    t0 = time.perf_counter()
    st = run()
    dt = time.perf_counter() - t0
    peak = _traced_peak(run)
    m = len(grid["reps"])
    a_dim = m * len(TARGETS)
    n_img = grid["scores"].shape[1]
    # device buffers tracemalloc cannot see: the (A,I)/(M,I) constants
    # plus the in-flight (chunk, B) blocks — analytic, conservative
    device_bytes = (3 * a_dim * n_img + 2 * m * n_img) * 4 \
        + 6 * chunk * a_dim * 4
    return {
        "n_cascades": int(st.evaluated),
        "chunk": chunk,
        "total_s": round(dt, 3),
        "cascades_per_s": round(st.evaluated / dt),
        "peak_traced_bytes": int(peak),
        "peak_bytes": int(peak + device_bytes),
        "frontier": sorted((int(st.kind[i]), int(st.i1[i]), int(st.i2[i]))
                           for i in range(len(st))),
    }


def bench_transforms(n_img: int = 192, base_hw: int = 64,
                     repeats: int = 5) -> dict:
    """Pyramid (one progressive pass for ALL reps) vs the seed per-rep
    from-base path, on the jnp compute path both use in-core."""
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.integers(0, 256, (n_img, base_hw, base_hw, 3))
                       .astype(np.float32) / 256.0)
    reps = representation_space([base_hw // 8, base_hw // 4, base_hw // 2])

    def per_rep():
        return [apply_transform(imgs, r) for r in reps]

    def pyramid():
        return materialize_representations(imgs, reps)

    for fn in (per_rep, pyramid):        # warm the jit caches
        jax.block_until_ready(fn())
    t_naive = min(_time(per_rep) for _ in range(repeats))
    t_pyr = min(_time(pyramid) for _ in range(repeats))
    naive_bytes = sum(transform_cost(r, base_hw)["bytes"] for r in reps)
    pyr_bytes = pyramid_bytes_moved(reps, base_hw)
    return {
        "n_images": n_img, "base_hw": base_hw, "n_reps": len(reps),
        "per_rep_s": round(t_naive, 4),
        "pyramid_s": round(t_pyr, 4),
        "per_rep_images_per_s": round(n_img / t_naive),
        "pyramid_images_per_s": round(n_img / t_pyr),
        "speedup": round(t_naive / t_pyr, 2),
        "bytes_moved_per_image_naive": naive_bytes,
        "bytes_moved_per_image_pyramid": pyr_bytes,
        "bytes_moved_ratio": round(naive_bytes / pyr_bytes, 2),
    }


def _time(fn) -> float:
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def _frontiers_match(a, b, tol=1e-5) -> bool:
    return set(map(tuple, a)) == set(map(tuple, b))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grids (CI smoke)")
    args = ap.parse_args()
    m_base = 120 if args.quick else 360
    m_large = 380 if args.quick else 1140   # ~10x the cascade space

    import jax
    report = {"backend": jax.default_backend(),
              "targets_per_model": len(TARGETS)}

    print(f"[bench] dense vs streaming on base grid (M={m_base}, "
          f"N={n_cascades(m_base):,}) ...")
    grid = make_grid(m_base)
    dense = bench_dense(grid)
    stream = bench_streaming(grid, chunk=512)
    same_frontier = _frontiers_match(dense["frontier"], stream["frontier"])
    speedup = stream["cascades_per_s"] / dense["cascades_per_s"]
    print(f"  dense   : {dense['total_s']}s "
          f"({dense['cascades_per_s']:,}/s, eval {dense['eval_s']}s + "
          f"select {dense['pareto_select_s']}s)")
    print(f"  stream  : {stream['total_s']}s "
          f"({stream['cascades_per_s']:,}/s) "
          f"frontier match={same_frontier}")
    print(f"  end-to-end speedup: {speedup:.2f}x")

    print(f"[bench] streaming on ~10x space (M={m_large}, "
          f"N={n_cascades(m_large):,}) ...")
    grid_l = make_grid(m_large, seed=1)
    stream_l = bench_streaming(grid_l, chunk=256)
    scale = stream_l["n_cascades"] / dense["n_cascades"]
    under_dense_peak = (stream_l["peak_bytes"]
                        <= dense["peak_bytes"])
    print(f"  {stream_l['total_s']}s ({stream_l['cascades_per_s']:,}/s), "
          f"{scale:.1f}x space, peak {stream_l['peak_bytes']/1e6:.0f}MB "
          f"vs dense base peak {dense['peak_bytes']/1e6:.0f}MB "
          f"(under: {under_dense_peak})")

    print("[bench] transform pyramid vs per-rep ...")
    tf = bench_transforms()
    print(f"  per-rep {tf['per_rep_images_per_s']:,} img/s, pyramid "
          f"{tf['pyramid_images_per_s']:,} img/s -> {tf['speedup']}x "
          f"(bytes ratio {tf['bytes_moved_ratio']}x)")

    dense.pop("frontier")
    stream.pop("frontier")
    stream_l.pop("frontier")
    report.update({
        "eval": {
            "grid_base": {"models": m_base, "images": 1000,
                          "n_cascades": n_cascades(m_base)},
            "dense_evaluate_select": dense,
            "streaming_same_grid": stream,
            "frontier_matches_dense": same_frontier,
            "end_to_end_speedup_x": round(speedup, 2),
            "grid_large": {"models": m_large, "images": 1000,
                           "n_cascades": n_cascades(m_large)},
            "streaming_large_grid": stream_l,
            "space_scale_x": round(scale, 1),
            "large_space_under_dense_base_peak": under_dense_peak,
        },
        "transform": tf,
    })
    # --quick is a CI smoke: small grids are jit-compile-dominated and
    # not the perf trajectory — never clobber the canonical artifact
    out = OUT.with_suffix(".quick.json") if args.quick else OUT
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
