"""Relational query algebra benchmark (engine/algebra.py, DESIGN.md
§15): what do cost-based predicate pushdown, short-circuit child
ordering, and join window pushdown buy on a boolean expression-tree
query — and do the rewrites stay exact? Writes ``BENCH_algebra.json``
at the repo root.

  PYTHONPATH=src python -m benchmarks.bench_algebra [--quick]

Protocol: one TAHOMA system per concept; the tree query

  SELECT frames WHERE cam = 0
                  AND contains(A) AND (NOT contains(B) OR contains(C))

runs three ways on fresh engines (timings warm, best of ``repeats``):

  optimized     — normalize -> cost-ordered children -> short-circuit
                  execution (positive-leaf runs share one pyramid,
                  AND/OR thread survivor sets, NOT reads decided-0
                  virtual columns);
  unoptimized   — the SAME tree, user child order, every child
                  evaluated on its node's full input (no
                  short-circuiting) — the algebra baseline;
  naive         — per-concept full scans + per-row mask algebra, no
                  metadata pushdown (the oracle).

All three row sets must be bit-identical (SystemExit otherwise — the
CI exactness gate). The join block times the cross-camera temporal
join with and without window pushdown on a correlated two-camera
corpus; pair sets must match each other and the nested-loop
reference."""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from bench_query_engine import build_systems  # noqa: E402

from repro.data.synthetic import (DEFAULT_PREDICATES,  # noqa: E402
                                  make_multi_corpus,
                                  make_two_camera_corpus)
from repro.engine import (And, Join, Not, Or, Pred, QuerySpec,  # noqa: E402
                          ScanEngine, execute_join, execute_tree,
                          naive_join_pairs, naive_tree_rows, plan_query)

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_algebra.json"
QUICK_DIR = ROOT / "artifacts" / "bench"


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_tree(systems, names, n_query: int, *, chunk: int,
               repeats: int, log=print) -> dict:
    a, b, c = names
    where = And(Pred(a), Or(Not(Pred(b)), Pred(c)))
    specs = [s for s in DEFAULT_PREDICATES if s.name in names]
    qx, _ = make_multi_corpus(specs, n_query, hw=32, seed=7,
                              positive_rate=0.4)
    metadata = {"cam": np.arange(n_query) % 2}
    spec_q = QuerySpec(metadata_eq={"cam": 0}, where=where)
    plan = plan_query(systems, spec_q, scenario="CAMERA",
                      metadata=metadata)
    plan_un = plan_query(systems, QuerySpec(metadata_eq={"cam": 0},
                                            where=where),
                         scenario="CAMERA", metadata=metadata)
    log(plan.explain(n_rows=n_query))

    def run(p, opt):
        eng = ScanEngine(qx, metadata, chunk=chunk)
        return execute_tree(eng, p, optimize=opt)

    res_opt = run(plan, True)                         # warm the jit
    res_un = run(plan_un, False)
    t_opt = _best(lambda: run(plan, True), repeats)
    t_un = _best(lambda: run(plan_un, False), repeats)
    t0 = time.perf_counter()
    ref = naive_tree_rows(qx, where, plan.cascade_map(), metadata,
                          plan.metadata_eq, chunk=chunk)
    t_naive = time.perf_counter() - t0

    if not (np.array_equal(res_opt.indices, ref)
            and np.array_equal(res_un.indices, ref)):
        raise SystemExit(
            "[bench] EXACTNESS GATE FAILED: optimized / unoptimized "
            "tree row sets diverged from the per-row naive oracle")
    log(f"[bench] tree: optimized {t_opt:.2f}s "
        f"({res_opt.rows_evaluated} rows evaluated) | unoptimized "
        f"{t_un:.2f}s ({res_un.rows_evaluated}) | naive {t_naive:.2f}s "
        f"| {len(ref)} rows, identical: True")
    return {
        "query": f"cam=0 AND contains({a}) AND "
                 f"(NOT contains({b}) OR contains({c}))",
        "rows": int(n_query),
        "matches": int(len(ref)),
        "est_cost_per_row_us": round(
            plan.estimated_cost_per_row() * 1e6, 1),
        "optimized_s": round(t_opt, 4),
        "unoptimized_s": round(t_un, 4),
        "naive_s": round(t_naive, 4),
        "rows_evaluated_optimized": int(res_opt.rows_evaluated),
        "rows_evaluated_unoptimized": int(res_un.rows_evaluated),
        "engine_calls_optimized": int(res_opt.engine_calls),
        "engine_calls_unoptimized": int(res_un.engine_calls),
        "speedup_vs_unoptimized_x": round(t_un / t_opt, 2),
        "speedup_vs_naive_x": round(t_naive / t_opt, 2),
        "rows_identical": True,
    }


def bench_join(systems, names, n_each: int, *, chunk: int, delta: float,
               repeats: int, log=print) -> dict:
    specs = [s for s in DEFAULT_PREDICATES if s.name in names]
    (xa, _, ta), (xb, _, tb) = make_two_camera_corpus(
        specs, n_each, hw=32, seed=11, corr=0.6, dt_max=int(delta))
    meta_a, meta_b = {"t": ta}, {"t": tb}
    tree = Join(Pred(names[0]),
                And(Pred(names[0]), Pred(names[1])), delta_t=delta)
    plan = plan_query(systems, QuerySpec(where=tree), scenario="CAMERA",
                      metadata=(meta_a, meta_b))
    log(plan.explain(n_rows=(n_each, n_each)))

    def run(opt):
        engines = (ScanEngine(xa, meta_a, chunk=chunk),
                   ScanEngine(xb, meta_b, chunk=chunk))
        return execute_join(engines, plan, optimize=opt)

    res = run(True)                                   # warm the jit
    kept = plan.window_kept          # before run(False) resets it
    res_un = run(False)
    t_push = _best(lambda: run(True), repeats)
    t_full = _best(lambda: run(False), repeats)
    ref = naive_join_pairs((res_un.left.indices, ta),
                           (res_un.right.indices, tb), delta)
    if not (np.array_equal(res.pairs, ref)
            and np.array_equal(res_un.pairs, ref)):
        raise SystemExit(
            "[bench] EXACTNESS GATE FAILED: join pair sets diverged "
            "from the nested-loop reference")
    log(f"[bench] join: pushdown {t_push:.2f}s (probe pruned to "
        f"{kept}/{n_each}) | full {t_full:.2f}s | {len(ref)} pairs, "
        f"identical: True")
    return {
        "query": f"contains({names[0]})@camA JOIN "
                 f"(contains({names[0]}) AND contains({names[1]}))@camB "
                 f"ON |t_A - t_B| <= {delta:g}",
        "rows_per_side": int(n_each),
        "pairs": int(len(ref)),
        "build_side": ["left", "right"][plan.build_side],
        "window_kept_rows": int(kept),
        "window_kept_frac": round(kept / n_each, 3),
        "pushdown_s": round(t_push, 4),
        "full_s": round(t_full, 4),
        "speedup_pushdown_x": round(t_full / t_push, 2),
        "pairs_identical": True,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpus/training (CI smoke); writes "
                         "under artifacts/bench/, never the headline")
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--delta", type=float, default=2.0)
    args = ap.parse_args()

    import jax
    specs = DEFAULT_PREDICATES[:3]
    names = [s.name for s in specs]
    systems = build_systems(specs, steps=30 if args.quick else 60,
                            n_train=160 if args.quick else 240, hw=32)
    n_query = 384 if args.quick else 1024
    n_each = 192 if args.quick else 512
    repeats = 2 if args.quick else 3

    report = {
        "backend": jax.default_backend(),
        "metric": "same expression tree, three executions (cost-ordered "
                  "short-circuit vs full-evaluation vs naive per-row "
                  "oracle) — row/pair sets must be bit-identical",
        "tree": bench_tree(systems, names, n_query, chunk=args.chunk,
                           repeats=repeats),
        "join": bench_join(systems, names[:2], n_each, chunk=args.chunk,
                           delta=args.delta, repeats=repeats),
    }
    if args.quick:
        QUICK_DIR.mkdir(parents=True, exist_ok=True)
        out = QUICK_DIR / OUT.with_suffix(".quick.json").name
    else:
        out = OUT
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}  (tree: "
          f"{report['tree']['speedup_vs_unoptimized_x']}x vs "
          f"unoptimized, {report['tree']['speedup_vs_naive_x']}x vs "
          f"naive; join pushdown: "
          f"{report['join']['speedup_pushdown_x']}x)")


if __name__ == "__main__":
    main()
