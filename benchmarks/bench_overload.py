"""Overload benchmark: the hardened AsyncCascadeService (DESIGN.md §12
— admission control, Pareto degradation ladder, request deadlines)
under OPEN-LOOP Poisson arrivals past saturation. Writes
``BENCH_overload.json`` at the repo root (``--quick``:
artifacts/bench/BENCH_overload.quick.json).

  PYTHONPATH=src python -m benchmarks.bench_overload [--quick]

Protocol: saturation throughput is first measured closed-loop (submit
as fast as the service absorbs, fresh rows only — no store hits inflate
it). Each load point then replays a pre-drawn Poisson arrival schedule
at ``multiplier x saturation`` offered rate: the driver submits every
request whose arrival time has passed (open loop — arrivals never slow
down because the service is behind, which is exactly what a closed-loop
driver gets wrong about overload) and polls between arrivals. The
hardened service runs with bounded per-(shard, concept) queues (typed
``Shed`` when full), a one-rung degradation ladder per concept (the
cheap single-level cascade from each concept's frontier, stepped into
under queue depth and back out on recovery), and an in-queue request
deadline (typed ``TimedOut``).

Headline claims checked by the numbers:

* past saturation the UNHARDENED service has no stationary behavior —
  queues and p99 grow with run length without bound; the hardened
  service keeps delivered-label p99 bounded (admission + deadline put a
  ceiling on time-in-system) while goodput stays near saturation;
* shed rate and degraded fraction engage at >= 2x and grow with load;
* below saturation the hardening is inert: the 0.5x point runs the
  identical schedule through hardened and unhardened services and the
  labels must match request-for-request (``subsat_identical``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# 8 simulated host devices, before the repro imports pull jax in
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8)

import jax  # noqa: E402

from repro.configs.base import TahomaCNNConfig  # noqa: E402
from repro.core.transforms import Representation  # noqa: E402
from repro.data.synthetic import DEFAULT_PREDICATES  # noqa: E402
from repro.engine.scan import CompiledCascade  # noqa: E402
from repro.models.cnn import cnn_predict_proba, init_cnn  # noqa: E402
from repro.serve import (AsyncCascadeService, DegradeConfig,  # noqa: E402
                         Request, Shed, TimedOut, is_label)

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_overload.json"
QUICK = ROOT / "artifacts" / "bench" / "BENCH_overload.quick.json"

BATCH = 32
MAX_WAIT_S = 0.002
SHARDS = 8
# per-(shard, concept) queue bounds, sized to each policy's latency
# story: the degrade policy can afford deeper queues (its ladder raises
# service rate under pressure); the shed-only policy's ONLY overload
# tool is admission, so its bound must be tight enough that a burst
# actually trips it before the dispatch path's natural backpressure
# drains it (below batch_size: overload flushes are all deadline-paced)
QUEUE_LIMIT_DEGRADE = 64
QUEUE_LIMIT_SHED = 16
REQUEST_DEADLINE_S = 0.25   # in-queue ceiling -> bounded time-in-system
DEGRADE = DegradeConfig(high_depth=3 * BATCH, low_depth=16,
                        recover_after=4)


def build_cascades(hw: int = 32, seed: int = 0) -> tuple[dict, dict]:
    """Two concepts, each a 2-level cascade (gray@16 -> rgb@hw) with
    random-init CNNs, plus a one-rung ladder per concept: the cheap
    single-level gray@16 cascade (the strictly-cheaper frontier point
    the load controller steps into under pressure)."""
    cascades, ladders = {}, {}
    for i, spec in enumerate(DEFAULT_PREDICATES[:2]):
        rep_fast = Representation(16, "gray")
        rep_full = Representation(hw, "rgb")
        fast = TahomaCNNConfig(1, 8, 16, input_hw=16, input_channels=1)
        full = TahomaCNNConfig(2, 16, 32, input_hw=hw, input_channels=3)
        p_fast = init_cnn(jax.random.PRNGKey(seed + 2 * i), fast)
        p_full = init_cnn(jax.random.PRNGKey(seed + 2 * i + 1), full)
        fn_fast = lambda z, p=p_fast: cnn_predict_proba(p, z)  # noqa: E731
        fn_full = lambda z, p=p_full: cnn_predict_proba(p, z)  # noqa: E731
        cascades[spec.name] = CompiledCascade(
            concept=spec.name, cascade_id=("overload-2level", spec.name),
            reps=[rep_fast, rep_full], model_fns=[fn_fast, fn_full],
            thresholds=[(0.3, 0.7), (None, None)])
        ladders[spec.name] = [CompiledCascade(
            concept=spec.name, cascade_id=("overload-1level", spec.name),
            reps=[rep_fast], model_fns=[fn_fast],
            thresholds=[(None, None)])]
    return cascades, ladders


def make_stream(n: int, n_corpus: int, concepts) -> list:
    """Fresh rows only (each (concept, row) pair distinct while
    n <= len(concepts) * n_corpus): store hits answer in zero time and
    would hide the overload behavior this bench prices."""
    return [(concepts[i % len(concepts)], (i // len(concepts)) % n_corpus)
            for i in range(n)]


def _service(corpus, cascades, fn_cache, **hardening):
    return AsyncCascadeService(corpus, cascades, shards=SHARDS,
                               batch_size=BATCH, max_wait_s=MAX_WAIT_S,
                               fn_cache=fn_cache, **hardening)


def run_closed(corpus, cascades, fn_cache, stream) -> float:
    """Closed-loop saturation probe: submit back-to-back, drain, return
    requests/s — the service's zero-headroom absorption rate."""
    svc = _service(corpus, cascades, fn_cache)
    t0 = time.perf_counter()
    for i, (c, row) in enumerate(stream):
        svc.submit(c, Request(i, row))
        svc.poll()
    svc.drain()
    return len(stream) / (time.perf_counter() - t0)


def run_open(corpus, cascades, fn_cache, stream, arrivals,
             **hardening) -> tuple:
    """Open-loop run: submit every request whose pre-drawn arrival time
    has passed, poll between arrivals, then poll out the tail. Arrival
    times never stretch because the service is behind."""
    svc = _service(corpus, cascades, fn_cache, **hardening)
    reqs = []
    n = len(stream)
    i = 0
    t0 = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            c, row = stream[i]
            r = Request(i, row)
            svc.submit(c, r)
            reqs.append(r)
            i += 1
        svc.poll()
        if i < n:
            rem = arrivals[i] - (time.perf_counter() - t0)
            if rem > 0:
                time.sleep(min(rem, 0.001))
    horizon = time.perf_counter() + 2 * REQUEST_DEADLINE_S + 2.0
    while svc.busy() and time.perf_counter() < horizon:
        svc.poll()
        time.sleep(0.0005)
    svc.drain()
    wall = time.perf_counter() - t0
    return svc, reqs, wall


def measure(reqs, wall, offered_rps) -> dict:
    lab = [r for r in reqs if is_label(r.result)]
    lat = np.array([r.t_done - r.t_arrival for r in lab]) * 1e3 \
        if lab else np.array([0.0])
    n = len(reqs)
    return {
        "offered_rps": round(offered_rps, 1),
        "requests": n,
        "wall_s": round(wall, 3),
        "goodput_rps": round(len(lab) / wall, 1),
        "goodput_fraction": round(len(lab) / n, 4),
        "shed_rate": round(sum(isinstance(r.result, Shed)
                               for r in reqs) / n, 4),
        "expired_rate": round(sum(isinstance(r.result, TimedOut)
                                  for r in reqs) / n, 4),
        "p50_ms": round(float(np.percentile(lat, 50)), 2),
        "p99_ms": round(float(np.percentile(lat, 99)), 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller runs (CI smoke), writes under "
                         "artifacts/bench/")
    ap.add_argument("--duration", type=float, default=None,
                    help="target seconds of arrivals per load point")
    args = ap.parse_args()
    duration = args.duration or (1.5 if args.quick else 4.0)
    n_cap = 1024 if args.quick else 8192
    n_sat = 256 if args.quick else 1024
    multipliers = (0.5, 1.0, 2.0, 4.0)

    cascades, ladders = build_cascades()
    concepts = list(cascades)
    n_corpus = n_cap // len(concepts)
    corpus = np.ascontiguousarray(
        (np.random.default_rng(7).integers(0, 256, (n_corpus, 32, 32, 3))
         .astype(np.float32) / 256.0))
    print(f"[bench] corpus {n_corpus} rows, batch={BATCH}, "
          f"shards={SHARDS}, {jax.device_count()} devices")

    # one shared fn cache across every service below; warm the primary
    # AND the ladder rungs so no run pays a compile stall
    fns: dict = {}
    svc = _service(corpus, cascades, fns, ladders=ladders)
    t0 = time.perf_counter()
    n = svc.warmup()
    print(f"  warmup: {n} executables in {time.perf_counter() - t0:.1f}s")

    sat = run_closed(corpus, cascades, fns,
                     make_stream(n_sat, n_corpus, concepts))
    sat = run_closed(corpus, cascades, fns,      # second pass, warm paths
                     make_stream(n_sat, n_corpus, concepts))
    print(f"  saturation (closed loop, fresh rows): {sat:.0f} req/s")

    # two hardened configurations: 'degrade' steps each concept onto
    # its cheap frontier rung under pressure (accuracy for latency);
    # 'shed' has no ladder — admission control + deadlines alone carry
    # the overload, so this curve is where Shed/TimedOut engage
    policies = {
        "degrade": dict(queue_limit=QUEUE_LIMIT_DEGRADE,
                        overload="degrade", ladders=ladders,
                        degrade=DEGRADE,
                        request_deadline_s=REQUEST_DEADLINE_S),
        "shed": dict(queue_limit=QUEUE_LIMIT_SHED,
                     request_deadline_s=REQUEST_DEADLINE_S),
    }
    rng = np.random.default_rng(29)
    curves: dict[str, list] = {}
    subsat_identical = None
    for policy, hardening in policies.items():
        curve = curves[policy] = []
        print(f"  -- policy: {policy}")
        for m in multipliers:
            rate = m * sat
            n = int(min(n_cap, max(256, rate * duration)))
            stream = make_stream(n, n_corpus, concepts)
            arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
            svc, reqs, wall = run_open(corpus, cascades, fns, stream,
                                       arrivals, **hardening)
            entry = measure(reqs, wall, rate)
            summ = svc.summary()
            entry["load_x"] = m
            entry["degraded_fraction"] = round(
                summ["degraded_fraction"], 4)
            entry["degrade_steps"] = summ["degrade_steps"]
            entry["recover_steps"] = summ["recover_steps"]
            entry["queue_depth_max"] = summ["queue_depth"]["max"]
            curve.append(entry)
            print(f"  {m:3.1f}x ({entry['offered_rps']:7.0f} rps "
                  f"offered): goodput {entry['goodput_rps']:7.0f} rps "
                  f"({entry['goodput_fraction']:.0%})  "
                  f"shed {entry['shed_rate']:.0%}  "
                  f"degraded {entry['degraded_fraction']:.0%}  "
                  f"p50/p99 {entry['p50_ms']:.0f}/"
                  f"{entry['p99_ms']:.0f} ms")

            if policy == "degrade" and m == 0.5:
                # identical schedule through the UNHARDENED service:
                # below saturation the hardening must be inert — same
                # labels, request for request
                svc2, reqs2, _ = run_open(corpus, cascades, fns,
                                          stream, arrivals)
                ok = (all(is_label(r.result) for r in reqs)
                      and all(is_label(r.result) for r in reqs2)
                      and [r.result for r in reqs]
                      == [r.result for r in reqs2])
                subsat_identical = bool(ok)
                print(f"        sub-saturation labels identical to "
                      f"unhardened: {subsat_identical}")

    past = [c for cv in curves.values() for c in cv
            if c["load_x"] >= 2.0]
    report = {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "physical_cores": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "protocol":
            "closed-loop saturation probe, then open-loop Poisson "
            "arrivals at 0.5/1/2/4x saturation through two hardened "
            "configurations: 'degrade' (bounded queues + one-rung "
            "degradation ladder under the depth controller + 250ms "
            "in-queue request deadline) and 'shed' (bounded queues + "
            "deadline only — admission control carries the overload). "
            "Fresh rows only — no store-hit inflation. The 0.5x "
            "schedule is replayed through the unhardened service and "
            "labels compared request-for-request.",
        "batch_size": BATCH,
        "shards": SHARDS,
        "queue_limit": {"degrade": QUEUE_LIMIT_DEGRADE,
                        "shed": QUEUE_LIMIT_SHED},
        "request_deadline_s": REQUEST_DEADLINE_S,
        "degrade": {"high_depth": DEGRADE.high_depth,
                    "low_depth": DEGRADE.low_depth,
                    "recover_after": DEGRADE.recover_after},
        "saturation_rps": round(sat, 1),
        "curves": curves,
        "subsat_identical": subsat_identical,
        "overload_goodput_fraction_min": round(
            min(c["goodput_fraction"] for c in past), 4),
        "overload_p99_ms_max": round(
            max(c["p99_ms"] for c in past), 2),
        "overload_engaged": bool(all(
            c["shed_rate"] + c["degraded_fraction"] > 0 for c in past)),
    }
    out = QUICK if args.quick else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    top = curves["degrade"][-1]
    print(f"wrote {out}  (degrade policy at 4x saturation: "
          f"p99 {top['p99_ms']:.0f} ms, goodput "
          f"{top['goodput_rps']:.0f} rps)")


if __name__ == "__main__":
    main()
