"""Fused pyramid+stage-0 hot path benchmark (DESIGN.md §13): the 2x2
grid of {eager, lazy} level materialization x {unfused, fused} chunk
ingest, per-chunk hot-path wall time and per-level materialization
counters. Writes ``BENCH_fused_scan.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.bench_fused_hotpath [--quick]

Two sections:

* ``planned`` — the trained 3-predicate query through the joint
  planner, exactly like bench_query_engine: integration truth (row
  sets, counters, EXPLAIN) on whatever plan the optimizer picks. At
  the repo's reduced 32px base the hw=32 CNN compute dominates and the
  planner often picks base-only cascades, so this section is NOT where
  the hot-path mechanism shows — it pins exactness.
* ``hotpath_stress`` — the HEADLINE per-chunk measurement: the same
  engines end-to-end on a 3-predicate multi-level cascade layout
  (stage-0 at {16,8} gray, predicate 2 first-touching {hw/2}) over a
  256px dyadic corpus at the 2304-row config — the
  data-handling-bound, paper-resolution regime (Tahoma's corpora are
  224px-class). Models are real CNN forward passes
  (`models/cnn.init_cnn`); weights are synthetic but
  logit-standardized against a probe batch (see ``_stress_cascades``)
  so predicate 1 is a realistic rare-concept filter with a nonzero
  survivor stream and result set. Synthetic weights change labels but
  not the data movement or program structure being measured, and
  every exactness differential (naive reference, shards {1,8},
  counter/schedule agreement) still applies verbatim. Timed repeats
  are round-robined across the four configs so shared-box load bursts
  don't bias any one cell.

Also checked/recorded, per the §13 acceptance list:
* row sets bit-identical across all four configs, the naive reference,
  and the sharded engine at ``--shards`` counts (default 1,8);
* the engine-costing contract: the ``level_schedule`` first-touch
  prediction (``PhysicalPlan.materialization_schedule`` on the planned
  section) matches the measured ``ScanStats.level_rows`` counters
  EXACTLY on a cold scan;
* kernel-vs-reference stage-0 labels (interpret-mode Pallas vs the
  unfused jnp composition) — a mismatch exits nonzero (the CI gate);
* int8-vs-f32 stage-0 score deviation, pinned to
  ``benchmarks/calibrated_int8_stage0.json`` (written if missing, or
  with ``--recalibrate``) — the tolerance tests and serving admit
  against.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# the sharded differential simulates a multi-chip host; must land before
# the repro imports below pull jax in
from repro.launch.devsim import force_host_devices  # noqa: E402

force_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import TahomaCNNConfig                     # noqa: E402
from repro.core.executor import Stage0, make_fused_ingest          # noqa: E402
from repro.core.transforms import (Representation,                 # noqa: E402
                                   apply_transform)
from repro.data.synthetic import DEFAULT_PREDICATES, make_multi_corpus  # noqa: E402
from repro.engine import (PredicateClause, QuerySpec, ScanEngine,  # noqa: E402
                          ShardedScanEngine, naive_scan, plan_query)
from repro.engine.scan import CompiledCascade, level_schedule      # noqa: E402
from repro.kernels.image_transform import fused_pyramid_stage0     # noqa: E402
from repro.models.cnn import (cnn_forward, cnn_predict_proba,      # noqa: E402
                              init_cnn, quantize_cnn)

from benchmarks.bench_query_engine import build_systems            # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_fused_scan.json"
QUICK_DIR = ROOT / "artifacts" / "bench"
CALIBRATION = Path(__file__).resolve().parent / \
    "calibrated_int8_stage0.json"
# safety margin over the measured deviation: int8 rounding error varies
# with the drawn weights, and the pinned tolerance must hold for future
# trained models, not just the calibration run's
CAL_MARGIN = 4.0
CAL_FLOOR = 5e-3


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _plan(systems, specs, metadata):
    for floor in (0.8, None):
        spec_q = QuerySpec(
            metadata_eq={"cam": 0},
            predicates=[PredicateClause(s.name, min_accuracy=floor)
                        for s in specs])
        try:
            return plan_query(systems, spec_q, scenario="CAMERA",
                              metadata=metadata, joint=True,
                              costing="engine")
        except ValueError:
            print(f"[bench] no cascade clears min_accuracy={floor}; "
                  f"relaxing")
    raise SystemExit("planning failed even unconstrained")


def check_kernel_labels(cascades, images, chunk: int) -> dict:
    """The CI gate: interpret-mode Pallas kernel ingest vs the unfused
    jnp composition must produce IDENTICAL stage-0 labels on a real
    chunk. Returns the comparison record; the caller exits nonzero on
    mismatch."""
    casc = next((c for c in cascades if c.stage0 is not None), None)
    if casc is None:
        return {"checked": False, "reason": "no stage0 cascade in plan"}
    imgs = jnp.asarray(images[:chunk])
    caps = [chunk] * (len(casc.model_fns) - 1)
    out_res = tuple(r for r in casc.resolutions
                    if r != images.shape[1])
    mk = lambda uk: make_fused_ingest(  # noqa: E731
        casc.model_fns, casc.thresholds, casc.reps, caps, out_res,
        stage0=casc.stage0, use_kernel=uk, jit=False)
    lab_k, lev_k = mk(True)(imgs)    # Pallas (interpret off-TPU)
    lab_r, lev_r = mk(False)(imgs)   # unfused reference composition
    labels_equal = bool(np.array_equal(np.asarray(lab_k),
                                       np.asarray(lab_r)))
    levels_equal = all(
        np.array_equal(np.asarray(lev_k[r]), np.asarray(lev_r[r]))
        for r in out_res)
    return {"checked": True, "concept": casc.concept,
            "rows": int(chunk), "labels_identical": labels_equal,
            "levels_bit_identical": bool(levels_equal)}


def calibrate_int8(cascades, images, chunk: int,
                   recalibrate: bool) -> dict:
    """Measure the int8-vs-f32 stage-0 score deviation on a real chunk
    for every planned stage-0 model; pin the tolerance (measured max x
    CAL_MARGIN, floored at CAL_FLOOR) to calibrated_int8_stage0.json
    if missing or --recalibrate."""
    imgs = jnp.asarray(images[:chunk])
    base = images.shape[1]
    per = {}
    for casc in cascades:
        s0 = casc.stage0
        if s0 is None or s0.qparams is None:
            continue
        out_res = [r for r in casc.resolutions if r != base]
        _, f32 = fused_pyramid_stage0(imgs, out_res, s0.params, s0.rep)
        _, i8 = fused_pyramid_stage0(imgs, out_res, s0.params, s0.rep,
                                     qparams=s0.qparams)
        per[casc.concept] = float(np.max(np.abs(
            np.asarray(i8) - np.asarray(f32))))
    measured = max(per.values()) if per else 0.0
    if CALIBRATION.exists() and not recalibrate:
        cal = json.loads(CALIBRATION.read_text())
    else:
        cal = {"score_abs_tol": max(measured * CAL_MARGIN, CAL_FLOOR),
               "measured_max_abs_dev": measured,
               "margin_x": CAL_MARGIN,
               "per_concept": per}
        CALIBRATION.write_text(json.dumps(cal, indent=2) + "\n")
        print(f"[bench] wrote {CALIBRATION}")
    return {"measured_max_abs_dev": measured,
            "per_concept": per,
            "pinned_tol": cal["score_abs_tol"],
            "within_pinned_tol": measured <= cal["score_abs_tol"]}


_TARGET_LOGIT_STD = 4.0


def _stress_cascades(hw: int, probe, s1_rate: float = 0.02):
    """3-predicate multi-level layout over real (randomly initialized)
    CNNs: stage-0 a 2-level cheap cascade at {16,8} gray, predicate 2
    first-touching {hw/2} (plus a base-level tail), predicate 3
    first-touching {16}-shared + base. Lazy schedule: ingest {16,8},
    later stages derive {hw/2} at first touch; eager materializes
    {hw/2,16,8} for every scanned row. Stage0 carries params + int8
    qparams, so the fused engines take the same code paths the
    planner's cascades do.

    A freshly initialized CNN is a degenerate one-class labeler (its
    logits saturate on one side of every threshold), which would empty
    the survivor stream after predicate 1 and make the row-set
    differentials trivially empty-vs-empty. Each model's output layer
    is therefore rescaled against ``probe`` so its logit distribution
    straddles the stage threshold: stage-0 of predicate 1 labels
    ``s1_rate`` of rows true (~2% by default — the selective
    rare-concept regime the paper's cascades target), later stages
    ~50%, giving a realistic selective scan with a nonzero result set
    and survivors that actually first-touch the lazy {hw/2} level."""
    def model(res, color, conv=8, dense=16, seed=0):
        cfg = TahomaCNNConfig(1, conv, dense, input_hw=res,
                              input_channels=1 if color != "rgb" else 3)
        return init_cnn(jax.random.PRNGKey(seed + res), cfg)

    def standardize(params, rep, true_rate, threshold_logit):
        # logits are linear in the output layer: z' = k(z - mean) + mu
        # is exactly out_w *= k, out_b -> k*out_b + (mu - k*mean)
        x = apply_transform(probe, rep)
        z = np.asarray(cnn_forward(params, x)).ravel()
        k = _TARGET_LOGIT_STD / max(float(z.std()), 1e-6)
        zc = k * (z - float(z.mean()))
        mu = threshold_logit - float(np.quantile(zc, 1.0 - true_rate))
        params["out_w"] = params["out_w"] * k
        params["out_b"] = params["out_b"] * k + (
            mu - k * float(np.mean(z)))

    def casc(concept, seed, spec, thresholds, cost_s, sel, rates,
             conv=8, dense=16):
        reps = [Representation(r, c) for r, c in spec]
        params = [model(r, c, conv=conv, dense=dense, seed=seed)
                  for r, c in spec]
        for p, rep, (_, hi), q in zip(params, reps, thresholds, rates):
            thr = 0.0 if hi is None else float(np.log(hi / (1.0 - hi)))
            standardize(p, rep, q, thr)
        fns = [(lambda x, p=p: cnn_predict_proba(p, x)) for p in params]
        s0 = Stage0(params=params[0], rep=reps[0],
                    qparams=quantize_cnn(params[0]))
        return CompiledCascade(concept, ("stress", seed), reps, fns,
                               list(thresholds), cost_s=cost_s,
                               selectivity=sel, stage0=s0)

    # predicate 1 is a rare-concept filter (~4% true — the selective
    # regime the paper's cascades target), so predicates 2/3 see a thin
    # survivor stream; their models are deliberately small because the
    # engine classifies the full chunk width whenever a chunk has any
    # survivor, and the quantity under test is the per-chunk
    # ingest/materialization path, not later-stage CNN throughput.
    return [
        casc("s1", 1, [(16, "gray"), (8, "gray")],
             [(0.45, 0.55), (None, None)], 1e-4, 0.5, [s1_rate, 0.5]),
        casc("s2", 2, [(hw // 2, "gray"), (hw, "rgb")],
             [(0.45, 0.55), (None, None)], 2e-4, 0.5, [0.5, 0.5],
             conv=2, dense=8),
        casc("s3", 3, [(16, "gray"), (hw, "rgb")],
             [(0.45, 0.55), (None, None)], 2e-4, 0.5, [0.5, 0.5],
             conv=2, dense=8),
    ]


def bench_grid(cascades, metadata_eq, qx, metadata, chunk: int,
               repeats: int, sched, est=None, log=print) -> dict:
    """The 2x2 {eager,lazy} x {unfused,fused} grid on one corpus. Every
    config's row set must equal the naive reference; per-chunk hot-path
    time is cold-scan wall time / ingest chunks. ``sched`` is the
    first-touch schedule {resolution: stage} the lazy counters must
    match exactly."""
    ref = naive_scan(qx, cascades, metadata, metadata_eq, chunk=chunk)
    configs = [(f"{'lazy' if lazy else 'eager'}_"
                f"{'fused' if fused else 'unfused'}", lazy, fused)
               for lazy in (False, True) for fused in (False, True)]
    engines, results, times = {}, {}, {}
    for name, lazy, fused in configs:
        eng = ScanEngine(qx, metadata, chunk=chunk, lazy=lazy,
                         fused=fused)
        results[name] = eng.execute(cascades, metadata_eq)     # warm
        engines[name] = eng
        times[name] = []
    # round-robin the timed repeats so a transient load burst (shared
    # single-core box) lands on every config, not whichever one was
    # running; per-config min then discards the burst entirely
    for _ in range(repeats):
        for name, _, _ in configs:
            eng = engines[name]
            times[name].append(_time(lambda e=eng: (
                e.reset_cache(), e.execute(cascades, metadata_eq))))
    grid = {}
    for name, _, _ in configs:
        res, t = results[name], min(times[name])
        nchunks = max(res.stats.chunks, 1)
        grid[name] = {
            "scan_s": round(t, 4),
            "chunks": int(res.stats.chunks),
            "per_chunk_ms": round(t / nchunks * 1e3, 3),
            "levels_materialized_rows": {
                str(r): int(n)
                for r, n in sorted(res.stats.level_rows.items())},
            "level_rows_total": int(sum(
                res.stats.level_rows.values())),
            "identical_rows": bool(np.array_equal(res.indices, ref)),
        }
        log(f"  {name}: {t:.3f}s "
            f"({grid[name]['per_chunk_ms']}ms/chunk, "
            f"{grid[name]['level_rows_total']} level-rows)")
    stats = results["lazy_fused"].stats
    # engine-costing contract on the lazy engine: measured counters ==
    # the first-touch schedule, exactly
    want = {r: (stats.rows_scanned if s == 0
                else stats.stages[s].rows_evaluated)
            for r, s in sched.items()}
    # a derive level whose owning stage never saw a survivor is
    # (correctly) never built: zero predicted touches match an absent
    # counter
    exact = ({r: v for r, v in want.items() if v}
             == {r: v for r, v in stats.level_rows.items() if v})
    hot = grid["eager_unfused"]["per_chunk_ms"] \
        / grid["lazy_fused"]["per_chunk_ms"]
    out = {
        "grid": grid,
        "hotpath_speedup_x": round(hot, 2),
        "lazy_level_rows_saved_x": round(
            grid["eager_unfused"]["level_rows_total"]
            / max(grid["lazy_fused"]["level_rows_total"], 1), 2),
        "schedule": {str(r): ("ingest" if s == 0 else f"stage{s + 1}")
                     for r, s in sorted(sched.items())},
        "measured_level_rows": {str(r): int(n) for r, n
                                in sorted(stats.level_rows.items())},
        "estimate_matches_measured_exactly": bool(exact),
    }
    if est is not None:
        out["estimated_level_rows"] = {str(r): round(v, 1)
                                       for r, v in sorted(est.items())}
    return out


def _schedule_of(cascades, base_hw: int) -> dict:
    ingest, _, derive = level_schedule(cascades, base_hw, True)
    sched = {r: 0 for r in ingest}
    for s, levels in enumerate(derive):
        for r in levels:
            sched[r] = s
    return sched


def bench_sharded_differential(cascades, metadata_eq, qx, metadata,
                               chunk: int, shard_counts,
                               log=print) -> list:
    """Lazy+fused sharded engines vs the serial engine: bit-identical
    row sets and (cold-scan) identical cross-shard level counters."""
    ref = ScanEngine(qx, metadata, chunk=chunk).execute(
        cascades, metadata_eq)
    out = []
    for k in shard_counts:
        eng = ShardedScanEngine(qx, metadata, shards=k, chunk=chunk)
        res = eng.execute(cascades, metadata_eq)
        entry = {
            "shards": k,
            "identical_rows": bool(np.array_equal(res.indices,
                                                  ref.indices)),
            "level_rows_match_serial": bool(
                res.stats.level_rows == ref.stats.level_rows),
        }
        out.append(entry)
        log(f"  shards={k}: identical={entry['identical_rows']}, "
            f"counters match={entry['level_rows_match_serial']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora/training (CI smoke)")
    ap.add_argument("--shards", default="1,8",
                    help="shard counts for the sharded differential")
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--recalibrate", action="store_true",
                    help="re-measure and rewrite "
                         "benchmarks/calibrated_int8_stage0.json")
    args = ap.parse_args()

    specs = DEFAULT_PREDICATES[:3]
    sizes = (256,) if args.quick else (768, 2304)
    repeats = 2 if args.quick else 3
    systems = build_systems(specs, steps=30 if args.quick else 60,
                            n_train=160 if args.quick else 240, hw=32)

    qx, _ = make_multi_corpus(specs, sizes[-1], hw=32, seed=7,
                              positive_rate=0.4)
    metadata_full = {"cam": np.arange(sizes[-1]) % 2}
    plan = _plan(systems, specs, metadata_full)

    kernel = check_kernel_labels(plan.cascades, qx, args.chunk)
    print(f"[bench] kernel-vs-ref: {kernel}")
    int8 = calibrate_int8(plan.cascades, qx, args.chunk,
                          args.recalibrate)
    print(f"[bench] int8 deviation {int8['measured_max_abs_dev']:.2e} "
          f"(pinned tol {int8['pinned_tol']:.2e})")

    shard_counts = [int(s) for s in args.shards.split(",")]
    base_hw = qx.shape[1]
    corpora = []
    for n in sizes:
        metadata = {"cam": np.arange(n) % 2}
        print(f"[bench] planned rows={n}")
        entry = {"rows": n, "chunk": args.chunk}
        entry.update(bench_grid(
            plan.cascades, plan.metadata_eq, qx[:n], metadata,
            args.chunk, repeats, plan.materialization_schedule(base_hw),
            est=plan.expected_level_rows(n // 2, base_hw)))
        entry["sharded"] = bench_sharded_differential(
            plan.cascades, plan.metadata_eq, qx[:n], metadata,
            args.chunk, shard_counts)
        corpora.append(entry)
    print(plan.explain(n_rows=sizes[-1], base_hw=base_hw))

    # headline: the data-handling-bound stress layout at the largest
    # config (64px dyadic corpus; 32px in --quick)
    stress_hw = 32 if args.quick else 256
    stress_n = sizes[-1]
    rng = np.random.default_rng(11)
    sx = (rng.integers(0, 256, (stress_n, stress_hw, stress_hw, 3))
          .astype(np.float32) / 256.0)
    smeta = {"cam": np.arange(stress_n) % 2}
    scascades = _stress_cascades(stress_hw, sx[:128])
    print(f"[bench] hotpath stress rows={stress_n} hw={stress_hw}")
    stress = {"rows": stress_n, "base_hw": stress_hw,
              "chunk": args.chunk}
    stress.update(bench_grid(scascades, {"cam": 0}, sx, smeta,
                             args.chunk, repeats + 2,
                             _schedule_of(scascades, stress_hw)))
    stress["sharded"] = bench_sharded_differential(
        scascades, {"cam": 0}, sx, smeta, args.chunk, shard_counts)

    report = {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "query": "SELECT frames WHERE cam=0 AND "
                 + " AND ".join(f"contains({s.name})" for s in specs),
        "costing": plan.costing,
        "kernel_check": kernel,
        "int8": int8,
        "planned": corpora,
        "hotpath_stress": stress,
        "hotpath_speedup_x": stress["hotpath_speedup_x"],
        "all_identical": all(
            all(g["identical_rows"] for g in c["grid"].values())
            and all(s["identical_rows"] for s in c["sharded"])
            for c in corpora + [stress]),
        "estimate_matches_measured_exactly": all(
            c["estimate_matches_measured_exactly"]
            for c in corpora + [stress]),
    }
    if args.quick:
        QUICK_DIR.mkdir(parents=True, exist_ok=True)
        out = QUICK_DIR / "BENCH_fused_scan.quick.json"
    else:
        out = OUT
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}  (hot-path {report['hotpath_speedup_x']}x, "
          f"identical={report['all_identical']}, exact-match="
          f"{report['estimate_matches_measured_exactly']})")
    if kernel.get("checked") and not (kernel["labels_identical"]
                                      and kernel["levels_bit_identical"]):
        raise SystemExit("kernel-vs-reference label mismatch")
    if not report["all_identical"]:
        raise SystemExit("row-set divergence")


if __name__ == "__main__":
    main()
