"""Shared benchmark harness: builds (and caches) TAHOMA systems for K
synthetic predicates at reduced scale. The cache stores only the
*evaluation state* (scores, thresholds, measured costs) — everything the
cascade evaluator needs — so repeated benchmark runs skip CNN training.
"""
from __future__ import annotations

import sys
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import TahomaCNNConfig                   # noqa: E402
from repro.core.cascade import evaluate_cascades                 # noqa: E402
from repro.core.costs import CostProfile                         # noqa: E402
from repro.core.thresholds import PRECISION_TARGETS, compute_thresholds_batch  # noqa: E402
from repro.core.transforms import Representation, representation_space  # noqa: E402
from repro.data.synthetic import DEFAULT_PREDICATES, make_corpus, three_way_split  # noqa: E402

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
ART.mkdir(parents=True, exist_ok=True)

BASE_HW = 32
RESOLUTIONS = (8, 16, 32)
ARCHS = [TahomaCNNConfig(l, c, 16) for l in (1, 2) for c in (8, 16)]
STEPS = 150
N_IMAGES = 480


# Deployment-regime calibration (EXPERIMENTS.md §Paper-claims):
# the reduced 32px stand-in corpus is priced at the paper's 224px regime
# (byte costs x (224/32)^2) with v5e analytic inference. Tiny convs run
# far below MXU peak; trusted stands in for fine-tuned ResNet50 and is
# priced at its published 3.9 GFLOPs/image.
COST_SCALE = (224 / BASE_HW) ** 2
MXU_EFF = 0.2
TPU_PEAK = 197e12
INFER_OVERHEAD_S = 1e-6
RESNET50_FLOPS = 3.9e9


def analytic_infer_s(flops: float) -> float:
    return INFER_OVERHEAD_S + flops * COST_SCALE / (TPU_PEAK * MXU_EFF)


@dataclass
class EvalState:
    """Minimal state for cascade evaluation under any scenario."""
    names: list
    reps: list                    # list[Representation]
    trusted: int
    eval_scores: np.ndarray
    eval_truth: np.ndarray
    p_low: np.ndarray
    p_high: np.ndarray
    infer_s: np.ndarray
    base_hw: int

    def profile(self, reps=None) -> CostProfile:
        return CostProfile.modeled(
            dict(zip(self.names, self.infer_s)),
            list(set(reps if reps is not None else self.reps)),
            self.base_hw, scale=COST_SCALE)

    def subset(self, rep_filter) -> "EvalState":
        """Restrict the MODEL POOL (all cascade positions) to reps passing
        the filter (+ the trusted model) — paper §VII-D subsets."""
        keep = [i for i, r in enumerate(self.reps)
                if rep_filter(r) or i == self.trusted]
        import dataclasses
        return dataclasses.replace(
            self, names=[self.names[i] for i in keep],
            reps=[self.reps[i] for i in keep],
            trusted=keep.index(self.trusted),
            eval_scores=self.eval_scores[keep],
            p_low=self.p_low[keep], p_high=self.p_high[keep],
            infer_s=self.infer_s[keep])

    def space(self, scenario: str, *, max_level: int = 3,
              first_level_models=None, rep_filter=None):
        st = self if rep_filter is None else self.subset(rep_filter)
        return evaluate_cascades(
            st.eval_scores, st.eval_truth, st.p_low, st.p_high,
            st.reps, st.infer_s, st.profile(), scenario,
            st.trusted, max_level=max_level,
            first_level_models=first_level_models)


def _cache_path(pred_name: str) -> Path:
    return ART / f"state_v2_{pred_name}.npz"


def _analytic_from_name(name: str) -> float:
    """Names encode the arch: cnn_l{L}_c{C}_d{D}_{res}x{res}_{color}."""
    from repro.models.cnn import cnn_flops
    if name.startswith("trusted"):
        return analytic_infer_s(RESNET50_FLOPS / COST_SCALE)
    parts = name.split("_")
    l, c, d = (int(parts[1][1:]), int(parts[2][1:]), int(parts[3][1:]))
    res = int(parts[4].split("x")[0])
    ch = 3 if parts[5] == "rgb" else 1
    return analytic_infer_s(cnn_flops(TahomaCNNConfig(
        l, c, d, input_hw=res, input_channels=ch)))


def build_state(pred, *, force: bool = False, log=print) -> EvalState:
    path = _cache_path(pred.name)
    old = ART / f"state_{pred.name}.npz"
    if not path.exists() and old.exists() and not force:
        z = np.load(old, allow_pickle=True)   # migrate v1 -> v2 pricing
        np.savez(path, **{k: z[k] for k in z.files if k != "infer_s"},
                 infer_s=np.array([_analytic_from_name(str(n))
                                   for n in z["names"]]))
    if path.exists() and not force:
        z = np.load(path, allow_pickle=True)
        reps = [Representation(int(r), str(c))
                for r, c in zip(z["rep_res"], z["rep_color"])]
        return EvalState(list(z["names"]), reps, int(z["trusted"]),
                         z["eval_scores"], z["eval_truth"], z["p_low"],
                         z["p_high"], z["infer_s"], int(z["base_hw"]))
    from repro.core.pipeline import initialize_system
    from repro.models.cnn import cnn_flops
    log(f"[bench] training model grid for predicate '{pred.name}' ...")
    x, y = make_corpus(pred, N_IMAGES, hw=BASE_HW, seed=0)
    splits = three_way_split(x, y, seed=1)
    reps = representation_space(RESOLUTIONS)
    t0 = time.time()
    sys_ = initialize_system(*splits, ARCHS, reps, steps=STEPS)
    log(f"[bench] trained {len(sys_.bank.entries)} models in "
        f"{time.time() - t0:.0f}s")
    infer = np.array([
        analytic_infer_s(RESNET50_FLOPS / COST_SCALE) if e.trusted
        else analytic_infer_s(cnn_flops(e.arch))
        for e in sys_.bank.entries])
    st = EvalState(
        names=sys_.bank.names, reps=sys_.bank.reps,
        trusted=sys_.bank.trusted_index, eval_scores=sys_.eval_scores,
        eval_truth=sys_.eval_truth, p_low=sys_.p_low, p_high=sys_.p_high,
        infer_s=infer, base_hw=BASE_HW)
    np.savez(path, names=np.array(st.names),
             rep_res=np.array([r.resolution for r in st.reps]),
             rep_color=np.array([r.color for r in st.reps]),
             trusted=st.trusted, eval_scores=st.eval_scores,
             eval_truth=st.eval_truth, p_low=st.p_low, p_high=st.p_high,
             infer_s=st.infer_s, base_hw=st.base_hw)
    return st


def get_states(n_predicates: int = 3, force: bool = False,
               log=print) -> dict[str, EvalState]:
    return {p.name: build_state(p, force=force, log=log)
            for p in DEFAULT_PREDICATES[:n_predicates]}


class Csv:
    """Collects ``name,us_per_call,derived`` rows (benchmarks/run.py
    contract) and pretty-prints."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.2f},{derived}")

    def write(self, path: Path):
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in self.rows:
                f.write(f"{n},{u:.2f},{d}\n")
