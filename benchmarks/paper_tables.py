"""One benchmark per paper table/figure (deliverable d). Each function
takes the shared EvalStates and the Csv collector and reproduces the
paper artifact's structure at container scale, asserting the paper's
qualitative claim where one exists."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv, EvalState
from repro.core.alc import alc, average_throughput, best_matching, speedup
from repro.core.cascade import KIND_SINGLE, KIND_TWO, evaluate_cascades
from repro.core.pareto import pareto_indices
from repro.core.selector import pareto_set, select

SCENARIOS = ("INFER_ONLY", "ARCHIVE", "ONGOING", "CAMERA")


def _full_rep_filter(state: EvalState):
    full = max(r.resolution for r in state.reps)
    return lambda r: r.resolution == full and r.color == "rgb"


def _baseline_space(state: EvalState, scenario: str):
    """Paper §VII-B Baseline: two-level cascades with full-color full-res
    first levels terminating in the trusted model (+ trusted alone)."""
    sub = state.subset(_full_rep_filter(state))
    sp = sub.space(scenario, max_level=2)
    keep = ((sp.kind == KIND_TWO) & (sp.i2 == sub.trusted)) \
        | ((sp.kind == KIND_SINGLE) & (sp.i1 == sub.trusted))
    import dataclasses
    return dataclasses.replace(
        sp, acc=sp.acc[keep], time_s=sp.time_s[keep], kind=sp.kind[keep],
        i1=sp.i1[keep], i2=sp.i2[keep])


def bench_speedups(states, csv: Csv):
    """Fig. 6 + Fig. 7: TAHOMA speedups over the trusted model and the
    Baseline cascades, per deployment scenario."""
    for scen in SCENARIOS:
        vs_trusted, vs_base_avg, fastest = [], [], []
        t0 = time.perf_counter()
        for name, st in states.items():
            sp = st.space(scen)
            tr_acc = sp.acc[st.trusted]
            tr_thr = sp.throughput[st.trusted]
            j = best_matching(sp.acc, sp.throughput, tr_acc)
            if j is not None:
                vs_trusted.append(sp.throughput[j] / tr_thr)
            base = _baseline_space(st, scen)
            vs_base_avg.append(speedup(sp.acc, sp.throughput,
                                       base.acc, base.throughput))
            fastest.append(sp.throughput.max() / tr_thr)   # Fig. 7
        dt = (time.perf_counter() - t0) * 1e6 / max(len(states), 1)
        csv.add(f"fig6_speedup_vs_trusted[{scen}]", dt,
                f"{np.mean(vs_trusted):.1f}x")
        csv.add(f"fig6_speedup_vs_baseline_avg[{scen}]", dt,
                f"{np.mean(vs_base_avg):.1f}x")
        csv.add(f"fig7_fastest_vs_trusted[{scen}]", dt,
                f"{np.mean(fastest):.1f}x")
        # paper claim: TAHOMA >= 1x vs both baselines in every scenario
        assert np.mean(vs_trusted) >= 1.0 and np.mean(vs_base_avg) >= 1.0


def bench_scenarios(states, csv: Csv):
    """Table III: scenario-aware vs scenario-oblivious selection at 2/5/10%
    permissible accuracy loss; gain must be >= 0 (within fp noise)."""
    for scen in ("ARCHIVE", "CAMERA", "ONGOING"):
        for loss in (0.02, 0.05, 0.10):
            gains, aware_fps = [], []
            t0 = time.perf_counter()
            for st in states.values():
                aware = st.space(scen)
                obliv = st.space("INFER_ONLY")
                floor = aware.acc.max() - loss
                aw = select(aware, min_accuracy=floor)
                ob = select(obliv, min_accuracy=floor)
                ob_fps = aware.throughput[ob.index]
                gains.append((aw.throughput - ob_fps) / ob_fps * 100)
                aware_fps.append(aw.throughput)
                assert aw.throughput >= ob_fps - 1e-9
            dt = (time.perf_counter() - t0) * 1e6 / len(states)
            csv.add(f"table3[{scen},loss={int(loss*100)}%]", dt,
                    f"aware={np.mean(aware_fps):.0f}fps "
                    f"gain=+{np.mean(gains):.1f}%")


def bench_transforms(states, csv: Csv):
    """Fig. 9: ALC average throughput for transform subsets
    None / ColorVariations / Resizing / Full (CAMERA scenario)."""
    results = {k: [] for k in ("none", "color", "resize", "full")}
    full_res = None
    for st in states.values():
        full_res = max(r.resolution for r in st.reps)
        filters = {
            "none": lambda r: r.resolution == full_res and r.color == "rgb",
            "color": lambda r: r.resolution == full_res,
            "resize": lambda r: r.color == "rgb",
            "full": None,
        }
        spaces = {k: st.space("CAMERA", rep_filter=f)
                  for k, f in filters.items()}
        lo = max(sp.acc.min() for sp in spaces.values())
        hi = min(sp.acc.max() for sp in spaces.values())
        for k, sp in spaces.items():
            results[k].append(average_throughput(sp.acc, sp.throughput,
                                                 lo, hi))
    for k, v in results.items():
        csv.add(f"fig9_transforms[{k}]", 0.0, f"{np.mean(v):.0f}fps")
    # paper claims: full >= every subset; transforms matter (full >> none).
    # resize vs color: strictly ordered on the paper-matched 3-predicate
    # set; comparable (within 10%) over all 10 synthetic predicates, where
    # several signals are strongly channel-coded (EXPERIMENTS.md).
    assert np.mean(results["full"]) >= 0.95 * max(
        np.mean(results[k]) for k in ("none", "color", "resize"))
    assert np.mean(results["resize"]) > 0.9 * np.mean(results["color"])
    assert np.mean(results["full"]) > 1.5 * np.mean(results["none"])


def bench_depth(states, csv: Csv):
    """Fig. 10: Pareto frontier evolution with cascade depth — diminishing
    returns beyond 2 levels (+trusted)."""
    avg = {}
    for depth in (1, 2, 3):
        fps, times = [], []
        for st in states.values():
            t0 = time.perf_counter()
            sp = st.space("CAMERA", max_level=depth)
            times.append((time.perf_counter() - t0) * 1e6)
            fps.append(average_throughput(sp.acc, sp.throughput,
                                          sp.acc.min(), sp.acc.max()))
        avg[depth] = np.mean(fps)
        csv.add(f"fig10_depth[{depth}]", np.mean(times),
                f"{np.mean(fps):.0f}fps n={len(sp)}")
    gain12 = avg[2] / max(avg[1], 1e-9)
    gain23 = avg[3] / max(avg[2], 1e-9)
    csv.add("fig10_gain_2v1", 0.0, f"{gain12:.2f}x")
    csv.add("fig10_gain_3v2", 0.0, f"{gain23:.2f}x")
    assert gain23 < max(gain12, 1.15)  # diminishing returns


def bench_cascade_space(states, csv: Csv):
    """Fig. 5: TAHOMA's cascade space vs the Baseline's."""
    for name, st in states.items():
        sp = st.space("CAMERA")
        base = _baseline_space(st, "CAMERA")
        par = pareto_set(sp)
        csv.add(f"fig5_space[{name}]", 0.0,
                f"tahoma={len(sp)} baseline={len(base)} "
                f"pareto={len(par)} max_acc={sp.acc.max():.3f}")
        assert len(sp) > 20 * len(base)


def bench_fig8_frontier_shift(states, csv: Csv):
    """Fig. 8: the INFER_ONLY-optimal cascades, re-costed under CAMERA,
    form a non-frontier (dominated, non-convex) set — scenario choice
    changes WHICH cascades are optimal, not just their throughput.
    Frontier point dumps are written to artifacts/bench/fig8_*.csv."""
    import numpy as np
    from benchmarks.common import ART
    for name, st in states.items():
        cam = st.space("CAMERA")
        inf = st.space("INFER_ONLY")
        cam_front = pareto_indices(cam.acc, cam.throughput)
        inf_front = pareto_indices(inf.acc, inf.throughput)
        # identical enumeration order: re-cost INFER_ONLY picks under CAMERA
        recost = cam.throughput[inf_front]
        dominated = sum(
            1 for j, t in zip(inf_front, recost)
            if any(cam.acc[i] >= cam.acc[j] and cam.throughput[i] > t
                   for i in cam_front))
        with open(ART / f"fig8_{name}.csv", "w") as f:
            f.write("set,accuracy,throughput\n")
            for i in cam_front:
                f.write(f"camera,{cam.acc[i]},{cam.throughput[i]}\n")
            for j, t in zip(inf_front, recost):
                f.write(f"infer_only_recosted,{cam.acc[j]},{t}\n")
        csv.add(f"fig8_frontier_shift[{name}]", 0.0,
                f"{dominated}/{len(inf_front)} oblivious picks dominated "
                f"under CAMERA")
        overlap = len(set(map(int, cam_front)) & set(map(int, inf_front)))
        assert overlap < len(cam_front) or dominated >= 0


def bench_eval_speed(csv: Csv):
    """§V-E: the paper evaluates 1.3M cascades in ~1 minute. Our
    closed-form matmul evaluation at full paper scale (360 models x 5
    targets, 1000 eval images)."""
    from repro.core.costs import CostProfile
    from repro.core.transforms import Representation
    rng = np.random.default_rng(0)
    m, t, i = 360, 5, 1000
    truth = rng.integers(0, 2, i)
    scores = np.clip(truth[None] * 0.4 + rng.normal(0.3, 0.25, (m, i)),
                     0, 1).astype(np.float32)
    from repro.core.thresholds import compute_thresholds_batch
    p_low, p_high = compute_thresholds_batch(
        scores, truth, [0.91, 0.93, 0.95, 0.97, 0.99])
    reps = [Representation([30, 60, 120, 224][j % 4],
                           ["rgb", "r", "g", "b", "gray"][j % 5])
            for j in range(m)]
    infer = rng.uniform(1e-5, 1e-2, m)
    profile = CostProfile.modeled({}, list(set(reps)), 224)
    t0 = time.perf_counter()
    sp = evaluate_cascades(scores, truth, p_low, p_high, reps, infer,
                           profile, "CAMERA", trusted=m - 1)
    dt = time.perf_counter() - t0
    csv.add("v_e_eval_speed", dt * 1e6 / len(sp),
            f"{len(sp)/1e6:.2f}M cascades in {dt:.1f}s "
            f"({len(sp)/dt/1e6:.2f}M/s; paper: 1.3M in ~60s)")
    assert len(sp) / dt > 1.3e6 / 60  # beat the paper's rate


def bench_executor(csv: Csv):
    """Batched TPU-native cascade executor micro-benchmark (per image)."""
    import jax
    import jax.numpy as jnp
    from repro.core.executor import run_cascade_batch
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.random((256, 32, 32, 3), np.float32))
    w1 = jnp.asarray(rng.standard_normal((64, 1), np.float32)) * 0.1
    w2 = jnp.asarray(rng.standard_normal((1024, 1), np.float32)) * 0.1

    def small(x):
        f = x.reshape(x.shape[0], -1)[:, :64]
        return jax.nn.sigmoid(f @ w1)[:, 0]

    def big(x):
        f = x.reshape(x.shape[0], -1)[:, :1024]
        return jax.nn.sigmoid(f @ w2)[:, 0]

    fn = jax.jit(lambda im: run_cascade_batch(
        im, [small, big], [(0.4, 0.6), (None, None)],
        [lambda x: x, lambda x: x], capacities=[64])[0])
    fn(imgs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        fn(imgs).block_until_ready()
    dt = (time.perf_counter() - t0) / 10
    csv.add("executor_batch256", dt * 1e6 / 256,
            f"{256/dt:.0f} img/s (batched two-phase compaction)")


def bench_transform_kernel(csv: Csv):
    """t_transform measurement feeding the cost model: fused-op reference
    path per image (interpret-mode Pallas is not timed — CPU container)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.random((64, 32, 32, 3), np.float32))
    fn = jax.jit(lambda im: ops.transform_op(im, res=8, color="gray",
                                             backend="ref"))
    fn(imgs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        fn(imgs).block_until_ready()
    dt = (time.perf_counter() - t0) / 20
    csv.add("transform_32to8_gray", dt * 1e6 / 64,
            f"{64/dt:.0f} img/s")
